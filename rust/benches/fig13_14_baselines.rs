//! Bench/regenerator for Figs. 13-14 (three-prototype comparison).
use accnoc::sim::experiments::fig13_14::{run_fig13, run_fig14};
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let mut b = Bench::new(sim_config());
    let mut f13 = None;
    b.run("fig13 3x3 grid", || f13 = Some(run_fig13(3, 15)));
    f13.unwrap().table().print();
    let mut f14 = None;
    b.run("fig14 loaded latency", || f14 = Some(run_fig14()));
    f14.unwrap().table().print();
    b.report("fig13_14_baselines");
}
