//! Bench/regenerator for Figs. 13-14 (three-prototype comparison). The
//! 36 fig13 rate points and 3 fig14 latency scenarios are sweep grids;
//! both reports merge into `BENCH_fig13_14.json`.
use std::path::Path;

use accnoc::sim::experiments::fig13_14::{run_fig13, run_fig14};
use accnoc::sweep::SweepReport;
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let mut b = Bench::new(sim_config());
    let mut f13 = None;
    b.run("fig13 3x3 grid", || f13 = Some(run_fig13(3, 15)));
    let f13 = f13.unwrap();
    f13.table().print();
    let mut f14 = None;
    b.run("fig14 loaded latency", || f14 = Some(run_fig14()));
    let f14 = f14.unwrap();
    f14.table().print();
    b.report("fig13_14_baselines");
    let mut scenarios = f13.report.scenarios;
    scenarios.extend(f14.report.scenarios);
    let merged = SweepReport {
        name: "fig13_14".to_string(),
        scenarios,
    };
    let out = Path::new("BENCH_fig13_14.json");
    merged.write_json(out).expect("write BENCH_fig13_14.json");
    println!("wrote {}", out.display());
}
