//! Regenerator for Table 2 (component latencies; cycle expressions are
//! enforced structurally and verified by rust/tests/table2.rs).
use accnoc::sim::experiments::tables;

fn main() {
    tables::table2().print();
    println!("verification: cargo test --test table2");
}
