//! Bench/regenerator for Fig. 9 (GSM/JPEG partition latency breakdown).
//! The ten partitions run as one sweep grid; the per-partition breakdown
//! lands in `BENCH_fig9.json`.
use std::path::Path;

use accnoc::sim::experiments::fig9;
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let mut b = Bench::new(sim_config());
    let mut fig = None;
    b.run("fig9 all partitions", || fig = Some(fig9::run()));
    let fig = fig.unwrap();
    fig.table().print();
    b.report("fig9_latency_breakdown");
    let out = Path::new("BENCH_fig9.json");
    fig.report.write_json(out).expect("write BENCH_fig9.json");
    println!("wrote {}", out.display());
}
