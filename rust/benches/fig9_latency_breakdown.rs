//! Bench/regenerator for Fig. 9 (GSM/JPEG partition latency breakdown).
use accnoc::sim::experiments::fig9;
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let mut b = Bench::new(sim_config());
    let mut fig = None;
    b.run("fig9 all partitions", || fig = Some(fig9::run()));
    fig.unwrap().table().print();
    b.report("fig9_latency_breakdown");
}
