//! Bench/regenerator for Fig. 8 (a/b/c): injection vs throughput sweeps.
use accnoc::sim::experiments::fig8::{run, Workload};
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let (warm, win) = (3, 15);
    let mut b = Bench::new(sim_config());
    for wl in [Workload::IzigzagHwa, Workload::EightHwa, Workload::DfdivHwa] {
        let mut s = None;
        b.run(wl.name(), || s = Some(run(wl, warm, win)));
        let s = s.unwrap();
        s.table().print();
        println!(
            "{}: max injection {:.2}, max throughput {:.2} flits/µs\n",
            wl.name(),
            s.max_injection(),
            s.max_throughput()
        );
    }
    b.report("fig8_throughput");
}
