//! Bench/regenerator for Fig. 8 (a/b/c): injection vs throughput sweeps.
//! All 24 rate points run as ONE sweep grid across every host core;
//! the combined report lands in `BENCH_fig8.json`.
use std::path::Path;

use accnoc::sim::experiments::fig8::run_all;
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let (warm, win) = (3, 15);
    let mut b = Bench::new(sim_config());
    let mut out = None;
    b.run("fig8 full grid (3 workloads x 8 rates)", || {
        out = Some(run_all(warm, win))
    });
    let (series, report) = out.unwrap();
    for s in &series {
        s.table().print();
        println!(
            "{}: max injection {:.2}, max throughput {:.2} flits/µs\n",
            s.workload.name(),
            s.max_injection(),
            s.max_throughput()
        );
    }
    b.report("fig8_throughput");
    let path = Path::new("BENCH_fig8.json");
    report.write_json(path).expect("write BENCH_fig8.json");
    println!("wrote {}", path.display());
}
