//! Bench/regenerator for Fig. 6 (task-buffer sweep). Prints the paper-style
//! table, wall-clock cost of the simulation, and writes the
//! machine-readable `BENCH_fig6.json` sweep report.
use std::path::Path;

use accnoc::sim::experiments::fig6;
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let mut b = Bench::new(sim_config());
    let mut fig = None;
    b.run("fig6 full sweep", || fig = Some(fig6::run()));
    let fig = fig.unwrap();
    fig.table().print();
    b.report("fig6_task_buffers");
    let out = Path::new("BENCH_fig6.json");
    fig.report.write_json(out).expect("write BENCH_fig6.json");
    println!("wrote {}", out.display());
}
