//! Bench/regenerator for Fig. 6 (task-buffer sweep). Prints the paper-style
//! table and wall-clock cost of the simulation itself.
use accnoc::sim::experiments::fig6;
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let mut b = Bench::new(sim_config());
    let mut fig = None;
    b.run("fig6 full sweep", || fig = Some(fig6::run()));
    fig.unwrap().table().print();
    b.report("fig6_task_buffers");
}
