//! Bench/regenerator for Fig. 7 (fmax across PR/PS strategies).
use accnoc::sim::experiments::fig7;
use accnoc::util::bench::{Bench, BenchConfig};

fn main() {
    let mut b = Bench::new(BenchConfig::default());
    let mut fig = None;
    b.run("fig7 synthesis model", || fig = Some(fig7::run()));
    let fig = fig.unwrap();
    fig.table().print();
    fig.component_table().print();
    let (pr, ps, f) = fig.best();
    println!("best strategy: {pr}-{ps} at {f:.0} MHz");
    b.report("fig7_fmax");
}
