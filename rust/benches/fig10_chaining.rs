//! Bench/regenerator for Fig. 10 (chaining-depth speedup).
use accnoc::sim::experiments::fig10;
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let mut b = Bench::new(sim_config());
    let mut fig = None;
    b.run("fig10 depths 0..3", || fig = Some(fig10::run()));
    fig.unwrap().table().print();
    b.report("fig10_chaining");
}
