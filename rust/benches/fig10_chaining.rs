//! Bench/regenerator for Fig. 10 (chaining-depth speedup). The four
//! depths run concurrently as a sweep grid -> `BENCH_fig10.json`.
use std::path::Path;

use accnoc::sim::experiments::fig10;
use accnoc::util::bench::{sim_config, Bench};

fn main() {
    let mut b = Bench::new(sim_config());
    let mut fig = None;
    b.run("fig10 depths 0..3", || fig = Some(fig10::run()));
    let fig = fig.unwrap();
    fig.table().print();
    b.report("fig10_chaining");
    let out = Path::new("BENCH_fig10.json");
    fig.report.write_json(out).expect("write BENCH_fig10.json");
    println!("wrote {}", out.display());
}
