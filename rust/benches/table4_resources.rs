//! Regenerator for Tables 3-4 (resource accounting) plus the §6.6
//! chaining overhead numbers.
use accnoc::fpga::iface::pr::PrStrategy;
use accnoc::fpga::iface::ps::PsStrategy;
use accnoc::sim::experiments::tables;
use accnoc::synth::resource::{channel_cost, interface_cost, lut_pct};

fn main() {
    tables::table3_table().print();
    tables::table4().print();
    let with = channel_cost(true);
    let without = channel_cost(false);
    println!(
        "chaining overhead per channel: +{} LUT ({:.2}%), +{} BRAM (paper: 526 / 0.12% / 2)",
        with.lut - without.lut,
        100.0 * (with.lut - without.lut) as f64 / 433_200.0,
        with.bram - without.bram
    );
    let total = interface_cost(
        PrStrategy::distributed(4),
        PsStrategy::hierarchical(4),
        32,
        false,
    );
    println!(
        "32-channel interface: {} LUTs = {:.2}% (paper: ~10.63%), {:.2}%/channel (paper: 0.33%)",
        total.lut,
        lut_pct(&total),
        lut_pct(&total) / 32.0
    );
}
