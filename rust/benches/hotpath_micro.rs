//! Hot-path micro-benchmarks for the perf pass (docs/EXPERIMENTS.md §Perf):
//! flit codec, router allocation, mesh stepping, channel stepping, and
//! whole-system step rate.
//!
//! Emits `BENCH_hotpath.json` (name -> ns/iter) next to the text report so
//! CI can upload the perf trajectory as an artifact. Set
//! `ACCNOC_BENCH_FAST=1` (the `make bench-smoke` target) for a short
//! measurement budget.
use accnoc::clock::PS_PER_US;
use accnoc::flit::{HeadFields, PacketBuilder};
use accnoc::fpga::hwa::{spec_by_name, table3};
use accnoc::noc::mesh::{Mesh, MeshConfig};
use accnoc::sim::system::{System, SystemConfig};
use accnoc::util::bench::{Bench, BenchConfig};
use accnoc::util::rng::Pcg32;

fn main() {
    let fast = std::env::var_os("ACCNOC_BENCH_FAST").is_some();
    let config = if fast {
        BenchConfig {
            warmup: std::time::Duration::from_millis(20),
            min_time: std::time::Duration::from_millis(80),
            min_iters: 3,
        }
    } else {
        BenchConfig::default()
    };
    let mut b = Bench::new(config);

    // Flit codec.
    let h = HeadFields {
        routing: 88,
        hwa_id: 13,
        start_addr: 0xDEAD_BEEF,
        data_size: 256,
        ..HeadFields::default()
    };
    b.run("flit encode+decode", || {
        let raw = std::hint::black_box(&h).encode();
        HeadFields::decode(&raw)
    });

    // Packet build: 64-word payload (17 flits).
    let words: Vec<u32> = (0..64).collect();
    let mut builder = PacketBuilder::new(1);
    b.run("payload packet build (64w)", || {
        builder.payload(h, std::hint::black_box(&words)).len()
    });

    // Mesh under uniform random traffic: cost of 1000 cycles.
    b.run("mesh 3x3: 1000 cycles @ load", || {
        let mut mesh = Mesh::new(MeshConfig::default());
        let mut rng = Pcg32::seeded(5);
        let mut bld = PacketBuilder::new(2);
        for _ in 0..1000 {
            let src = rng.range(0, 9);
            let dst = rng.range(0, 9);
            if src != dst {
                let p = bld.command(HeadFields {
                    routing: dst as u8,
                    ..HeadFields::default()
                });
                mesh.try_inject(src, p.flits[0]);
            }
            mesh.step();
            for n in 0..9 {
                while mesh.eject_pop(n).is_some() {}
            }
        }
        mesh.cycles
    });

    // Active-set headline: stepping cost scales with traffic, not mesh
    // size. A 9x9 mesh (81 routers) carrying one flit per ~30 cycles
    // should step at nearly the cost of an empty mesh.
    b.run("mesh 9x9: 1000 cycles @ 1 flit/30cy", || {
        let cfg = MeshConfig {
            width: 9,
            height: 9,
            ..MeshConfig::default()
        };
        let mut mesh = Mesh::new(cfg);
        let mut rng = Pcg32::seeded(6);
        let mut bld = PacketBuilder::new(3);
        // Track in-flight destinations so the drain probe is O(activity)
        // too — an 81-queue scan per cycle would mask exactly the
        // structure-size term this metric isolates.
        let mut pending_dsts: Vec<usize> = Vec::new();
        for cycle in 0..1000u64 {
            if cycle % 30 == 0 {
                let src = rng.range(0, 81);
                let dst = rng.range(0, 81);
                if src != dst {
                    let p = bld.command(HeadFields {
                        routing: dst as u8,
                        ..HeadFields::default()
                    });
                    if mesh.try_inject(src, p.flits[0]) {
                        pending_dsts.push(dst);
                    }
                }
            }
            mesh.step();
            pending_dsts.retain(|&d| mesh.eject_pop(d).is_none());
        }
        mesh.cycles
    });

    // Batched whole-packet injection on a saturating mesh: every node
    // offers a multi-flit payload every cycle, the zero-copy hot path's
    // worst case. `try_inject_packet` is all-or-nothing on credits, so
    // no wormhole is ever left half-injected under this load.
    b.run("mesh 3x3: 1000 cycles saturating, batched inject", || {
        let mut mesh = Mesh::new(MeshConfig::default());
        let mut rng = Pcg32::seeded(9);
        let mut bld = PacketBuilder::new(4);
        let words: Vec<u32> = (0..8).collect();
        let mut injected = 0u64;
        for _ in 0..1000 {
            for src in 0..9 {
                let dst = rng.range(0, 9);
                if src != dst {
                    let p = bld.payload(
                        HeadFields {
                            routing: dst as u8,
                            ..HeadFields::default()
                        },
                        &words,
                    );
                    if mesh.try_inject_packet(src, &p.flits) {
                        injected += 1;
                    }
                }
            }
            mesh.step();
            for n in 0..9 {
                while mesh.eject_pop(n).is_some() {}
            }
        }
        injected
    });

    // Full system: simulated µs per wall second (the sim-rate headline).
    b.run("system: simulate 20 µs izigzag saturation", || {
        let cfg = SystemConfig::paper(vec![spec_by_name("izigzag").unwrap(); 8]);
        let mut sys = System::new(cfg);
        sys.set_open_loop(16.0, 3);
        sys.run_for(20 * PS_PER_US);
        sys.fabric().tasks_executed()
    });

    b.run("system: simulate 20 µs eight-hwa", || {
        let cfg = SystemConfig::paper(table3().into_iter().take(8).collect());
        let mut sys = System::new(cfg);
        sys.set_open_loop(8.0, 3);
        sys.run_for(20 * PS_PER_US);
        sys.fabric().tasks_executed()
    });

    // Event-horizon scheduler headline: a low-injection fig8-style open
    // loop (0.25 req/µs, mostly idle) stepped naively vs event-driven.
    let low_injection_run = |idle_skip: bool| {
        let cfg = SystemConfig::paper(vec![spec_by_name("izigzag").unwrap(); 8]);
        let mut sys = System::new(cfg);
        sys.set_idle_skip(idle_skip);
        sys.set_open_loop(0.25, 11);
        sys.run_for(200 * PS_PER_US);
        let latencies: Vec<Vec<u64>> = sys
            .open_sources
            .iter()
            .flatten()
            .map(|s| s.latencies_ps.clone())
            .collect();
        (latencies, sys.edges_stepped)
    };
    let naive_mean = b
        .run("fig8 open loop 0.25/µs: per-edge stepping", || {
            low_injection_run(false)
        })
        .mean;
    let skip_mean = b
        .run("fig8 open loop 0.25/µs: idle-skipping", || {
            low_injection_run(true)
        })
        .mean;

    // Arena allocation-rate metrics: deterministic counters from a
    // fixed-seed saturation run, emitted into the schema-3 "counters"
    // object so CI tracks pooling behaviour as a trajectory.
    let arena_metrics = || {
        let cfg =
            SystemConfig::paper(vec![spec_by_name("izigzag").unwrap(); 8]);
        let mut sys = System::new(cfg);
        sys.set_open_loop(16.0, 3);
        sys.run_for(20 * PS_PER_US);
        (sys.arena_stats(), sys.fabric().tasks_executed())
    };
    let (ar, tasks_a) = arena_metrics();
    let (ar2, tasks_b) = arena_metrics();
    assert_eq!(ar, ar2, "arena counters must be run-to-run deterministic");
    assert_eq!(tasks_a, tasks_b, "task count must be deterministic");
    // Pool invariants: slab growth only happens at a new live high-water
    // mark, and a saturating run recycles far more than it grows.
    assert_eq!(
        ar.packet_allocs, ar.packet_high_water,
        "fresh packet slots only at high-water marks"
    );
    assert_eq!(
        ar.words_allocs, ar.words_high_water,
        "fresh word buffers only at high-water marks"
    );
    assert!(
        ar.words_reuses > 0,
        "saturation run must recycle word buffers (got {ar:?})"
    );
    b.counter("arena_packet_allocs", ar.packet_allocs as f64);
    b.counter("arena_packet_reuses", ar.packet_reuses as f64);
    b.counter("arena_packet_frees", ar.packet_frees as f64);
    b.counter("arena_packet_high_water", ar.packet_high_water as f64);
    b.counter("arena_words_allocs", ar.words_allocs as f64);
    b.counter("arena_words_reuses", ar.words_reuses as f64);
    b.counter("arena_words_frees", ar.words_frees as f64);
    b.counter("arena_words_high_water", ar.words_high_water as f64);

    b.report("hotpath_micro");

    // Machine-readable trajectory artifact (uploaded by CI).
    let json_path = std::path::Path::new("BENCH_hotpath.json");
    b.write_json("hotpath_micro", json_path)
        .expect("write BENCH_hotpath.json");
    println!("wrote {}", json_path.display());

    // Determinism check: identical per-task latency records either way.
    let (lat_naive, edges_naive) = low_injection_run(false);
    let (lat_skip, edges_skip) = low_injection_run(true);
    assert_eq!(
        lat_naive, lat_skip,
        "idle skipping changed per-task latency records"
    );
    let speedup = naive_mean.as_secs_f64() / skip_mean.as_secs_f64().max(1e-12);
    let edge_ratio = edges_naive as f64 / edges_skip.max(1) as f64;
    println!(
        "idle-skip: {speedup:.1}x wall-clock speedup on the low-injection \
         open loop ({edges_naive} -> {edges_skip} dispatched edges, \
         {edge_ratio:.1}x); per-task latency records identical"
    );
    // The deterministic gate (runs in CI's short-budget bench-smoke too):
    // dispatched-edge counts are noise-free, so the >=3x scheduler floor
    // can't flake on a loaded runner.
    assert!(
        edge_ratio >= 3.0,
        "per-domain event horizons must cut dispatched edges >=3x on the \
         low-injection open loop (ISSUE 4 acceptance), got {edge_ratio:.2}x"
    );
    // Wall-clock floor only under the full measurement budget: timing on
    // shared CI runners is too noisy for a hard gate.
    if !fast {
        assert!(
            speedup >= 3.0,
            "per-domain event horizons must be >=3x wall-clock on the \
             low-injection open loop (ISSUE 4 acceptance), got {speedup:.2}x"
        );
    }
    // Derived sim-rate metric for §Perf.
    if let Some(m) = b
        .results()
        .iter()
        .find(|m| m.name.contains("izigzag saturation"))
    {
        let sim_us = 20.0;
        let rate = sim_us / m.mean.as_secs_f64() / 1e6;
        println!("sim rate: {rate:.3} simulated-seconds/wall-second x1e-6 (20µs in {:?})", m.mean);
    }
}
