//! Quickstart: build the paper's default system (3x3 mesh NoC, FPGA with
//! eight HWAs at PR4-PS4/2-TB), run one accelerated invocation through
//! the typed driver API, and print the receipt's latency breakdown.
//!
//!     cargo run --release --example quickstart

use accnoc::accel::{AccelRuntime, Job};
use accnoc::clock::PS_PER_US;
use accnoc::fpga::hwa::table3;
use accnoc::runtime::NativeCompute;
use accnoc::sim::SystemConfig;

fn main() {
    // 1. Driver runtime over the paper-default system with the first
    // eight Table 3 HWAs. Functional compute is the native golden model
    // (swap in PjrtCompute for artifact-backed math — see
    // examples/end_to_end.rs).
    let cfg = SystemConfig::paper(table3().into_iter().take(8).collect());
    let mut rt = AccelRuntime::new(cfg);
    rt.set_compute(Box::new(NativeCompute::default()));

    // 2. Discover the GSM autocorrelation accelerator and program core
    // 0's session: some software work, one D_HWA_invoke, more software.
    // GSM samples travel as f32 bit patterns on the wire.
    let gsm = rt.accel_named("gsm").expect("gsm HWA configured");
    let frame: Vec<u32> = (0..8).map(|i| (i as f32 * 100.0).to_bits()).collect();
    let receipt = {
        let mut session = rt.session(0).expect("core 0 exists");
        session.compute(2_000);
        let receipt = session
            .submit(Job::on(gsm).direct(frame))
            .expect("valid job");
        session.compute(1_000);
        receipt
    };

    // 3. Run until the program finishes and resolve the receipt.
    assert!(rt.run_until_done(10_000 * PS_PER_US), "system finished");
    let done = rt.poll(receipt).expect("invocation completed");

    // 4. Report.
    let r = done.record();
    let b = done.breakdown();
    println!("quickstart: one GSM invocation through the full system");
    println!("  request sent        @ {:>8} ps", r.t_request);
    println!(
        "  grant received      @ {:>8} ps  (+{} ns)",
        r.t_grant,
        b.grant_ps / 1000
    );
    println!(
        "  payload delivered   @ {:>8} ps  (+{} ns)",
        r.t_payload_done,
        b.payload_ps / 1000
    );
    println!(
        "  result complete     @ {:>8} ps  (+{} ns)",
        r.t_result_last,
        b.execute_ps / 1000
    );
    println!(
        "  total invocation latency: {:.3} µs",
        done.total_ps() as f64 / PS_PER_US as f64
    );
    let autocorr: Vec<f32> = rt
        .last_result(0)
        .iter()
        .map(|w| f32::from_bits(*w))
        .collect();
    println!("  autocorrelation lags: {autocorr:?}");
    println!(
        "  tasks executed on FPGA: {}",
        rt.system().fabric().tasks_executed()
    );
}
