//! Quickstart: build the paper's default system (3x3 mesh NoC, FPGA with
//! eight HWAs at PR4-PS4/2-TB), run one accelerated invocation from a
//! processor, and print the latency breakdown.
//!
//!     cargo run --release --example quickstart

use accnoc::clock::PS_PER_US;
use accnoc::cmp::core::{InvokeSpec, Segment};
use accnoc::fpga::hwa::table3;
use accnoc::runtime::NativeCompute;
use accnoc::sim::system::{System, SystemConfig};

fn main() {
    // 1. System: paper defaults + the first eight Table 3 HWAs.
    let cfg = SystemConfig::paper(table3().into_iter().take(8).collect());
    let mut sys = System::new(cfg);
    // Functional compute (swap in PjrtCompute for artifact-backed math —
    // see examples/end_to_end.rs).
    sys.fabric.set_compute(Box::new(NativeCompute::default()));

    // 2. Program processor 0: some software work, then a D_HWA_invoke of
    // the GSM autocorrelation HWA (id 5), then more software.
    // GSM samples travel as f32 bit patterns on the wire.
    let frame: Vec<u32> = (0..8).map(|i| (i as f32 * 100.0).to_bits()).collect();
    sys.load_program(
        0,
        vec![
            Segment::Compute(2_000),
            Segment::Invoke(InvokeSpec::direct(5, frame, 8)),
            Segment::Compute(1_000),
        ],
    );

    // 3. Run until the program finishes.
    assert!(sys.run_until_done(10_000 * PS_PER_US), "system finished");

    // 4. Report.
    let r = sys.procs[0].records[0];
    println!("quickstart: one GSM invocation through the full system");
    println!("  request sent        @ {:>8} ps", r.t_request);
    println!(
        "  grant received      @ {:>8} ps  (+{} ns)",
        r.t_grant,
        (r.t_grant - r.t_request) / 1000
    );
    println!(
        "  payload delivered   @ {:>8} ps  (+{} ns)",
        r.t_payload_done,
        (r.t_payload_done - r.t_grant) / 1000
    );
    println!(
        "  result complete     @ {:>8} ps  (+{} ns)",
        r.t_result_last,
        (r.t_result_last - r.t_payload_done) / 1000
    );
    println!(
        "  total invocation latency: {:.3} µs",
        r.total() as f64 / PS_PER_US as f64
    );
    let autocorr: Vec<f32> = sys.procs[0]
        .last_result
        .iter()
        .map(|w| f32::from_bits(*w))
        .collect();
    println!("  autocorrelation lags: {autocorr:?}");
    println!("  tasks executed on FPGA: {}", sys.fabric.tasks_executed());
}
