//! HWA chaining demo (paper §4.2 B.3 / §6.6): decode real JPEG coefficient
//! blocks through the four-HWA chain at every chaining depth and verify
//! the decoded pixels against the native golden model.
//!
//! Programs come from `cmp::apps::jpeg_chain_block_program` (typed
//! driver phases) and are submitted through `accel::AccelRuntime`.
//!
//!     cargo run --release --example jpeg_chaining

use accnoc::accel::{AccelRuntime, Program};
use accnoc::clock::PS_PER_US;
use accnoc::cmp::apps::jpeg_chain_block_program;
use accnoc::fpga::hwa::spec_by_name;
use accnoc::runtime::native::{jpeg_chain, DEFAULT_QTABLE};
use accnoc::runtime::NativeCompute;
use accnoc::sim::SystemConfig;
use accnoc::workload::jpeg::BlockImage;

fn main() {
    let n_blocks = 8;
    let img = BlockImage::synthetic(n_blocks, 2026);
    let coeffs = img.encode();

    println!("JPEG chaining: {n_blocks} blocks, depths 0..=3\n");
    let mut base_us = 0.0;
    for depth in 0..=3u8 {
        let mut cfg = SystemConfig::paper(vec![
            spec_by_name("izigzag").unwrap(),
            spec_by_name("iquantize").unwrap(),
            spec_by_name("idct").unwrap(),
            spec_by_name("shiftbound").unwrap(),
        ]);
        cfg.fabrics[0].chain_groups = vec![vec![0, 1, 2, 3]];
        let mut rt = AccelRuntime::new(cfg);
        rt.set_compute(Box::new(NativeCompute::default()));
        // Per block: one chained invocation covering `depth` hops plus
        // separate invocations for the remaining stages.
        let mut prog = Program::new();
        for scan in &coeffs {
            let block: Vec<u32> = scan.iter().map(|c| *c as u32).collect();
            prog.extend(jpeg_chain_block_program(depth, block));
        }
        rt.load(0, prog).expect("valid chain programs");
        assert!(rt.run_until_done(500_000 * PS_PER_US));
        let total_us = rt.system().procs[0].finished_at.unwrap() as f64
            / PS_PER_US as f64;
        if depth == 0 {
            base_us = total_us;
        }
        println!(
            "  depth {depth}: {total_us:8.2} µs   speedup {:.2}x   (invocations per block: {})",
            base_us / total_us,
            4 - depth
        );
        // Functional check at full depth: simulated pixels == golden.
        if depth == 3 {
            let want = jpeg_chain(coeffs.last().unwrap(), &DEFAULT_QTABLE);
            let got: Vec<i32> = rt
                .last_result(0)
                .iter()
                .map(|w| *w as i32)
                .collect();
            assert_eq!(got, want.to_vec());
            println!("\n  depth-3 output verified against golden decoder OK");
        }
    }
    println!("\n(The paper's Fig. 10: speedup grows with chaining depth.)");
}
