//! Dynamic partial reconfiguration tour: build a system with a
//! reconfigurable slot, swap its accelerator mid-run, and show the typed
//! `SlotReconfiguring` rejection while the fence is up plus the handle
//! re-resolution once the new core lands.
//!
//! The same scenario runs inside `accnoc selftest`, so this example and
//! the CLI smoke stay in lockstep (see `accel::reconfig_demo`).
//!
//!     cargo run --release --example reconfig

fn main() {
    match accnoc::accel::reconfig_demo() {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("reconfig: {e}");
            std::process::exit(1);
        }
    }
}
