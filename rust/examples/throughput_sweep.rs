//! Throughput sweep (paper §6.4 / Fig. 8): open-loop request-rate sweep
//! over a chosen workload, printing injection rate, throughput and FPGA
//! busy fraction per rate point.
//!
//!     cargo run --release --example throughput_sweep -- [izigzag|eight|dfdiv] [window_us]

use accnoc::sim::experiments::fig8::{run, Workload};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workload = match args.first().map(|s| s.as_str()) {
        Some("eight") => Workload::EightHwa,
        Some("dfdiv") => Workload::DfdivHwa,
        _ => Workload::IzigzagHwa,
    };
    let window: u64 = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let series = run(workload, 5, window);
    series.table().print();
    println!(
        "max injection {:.2} flits/µs, max throughput {:.2} flits/µs \
         ({:.1}% below injection)",
        series.max_injection(),
        series.max_throughput(),
        100.0 * (1.0 - series.max_throughput() / series.max_injection())
    );
}
