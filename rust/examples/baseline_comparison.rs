//! Baseline comparison (paper §6.7/§6.8, Figs. 13-14): the proposed
//! NoC + distributed-buffer design vs. AXI bus integration vs. shared
//! FPGA cache, on max throughput and loaded communication latency.
//!
//!     cargo run --release --example baseline_comparison -- [window_us]

use accnoc::sim::experiments::fig13_14::{run_fig13, run_fig14};

fn main() {
    let window: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(15);
    println!("running three prototypes x three workloads...");
    run_fig13(3, window).table().print();
    println!("\nrunning loaded-latency comparison...");
    run_fig14().table().print();
    println!(
        "\n(Paper: AXI loses 27%/53%, cache 22.5%/28.2% max throughput;\n\
         NoC communication latency 2.42x better than AXI, 1.63x than cache.\n\
         See docs/EXPERIMENTS.md for measured-vs-paper discussion.)"
    );
}
