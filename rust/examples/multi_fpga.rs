//! Floorplanned multi-FPGA demo: a 3x3 mesh carrying TWO FPGA interface
//! tiles (`F0 P P / P M P / P P F1`) — the scalability scenario the
//! paper argues the NoC integration enables and the old hardcoded
//! "FPGA at the last node" construction could not express.
//!
//! Fabric 0 carries the four JPEG-chain accelerators (one chained job),
//! fabric 1 carries two floating-point accelerators (direct jobs from
//! two other cores); the demo prints each receipt's latency breakdown
//! and the per-fabric counters, then shows the driver rejecting a
//! cross-fabric chain with a typed error.
//!
//!     cargo run --release --example multi_fpga

fn main() {
    match accnoc::accel::multi_fpga_demo() {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("multi_fpga demo failed: {e}");
            std::process::exit(1);
        }
    }
}
