//! Driver-API tour: build a 2-core + 3-accelerator system, submit a
//! chained Job and a direct Job through `accel::AccelRuntime`, and print
//! each Receipt's per-stage latency breakdown.
//!
//! The same scenario runs inside `accnoc selftest`, so this example and
//! the CLI smoke stay in lockstep (see `accel::driver_api_demo`).
//!
//!     cargo run --release --example driver_api

fn main() {
    match accnoc::accel::driver_api_demo() {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("driver_api: {e}");
            std::process::exit(1);
        }
    }
}
