//! END-TO-END VALIDATION DRIVER (docs/ARCHITECTURE.md / EXPERIMENTS.md).
//!
//! Proves all layers compose: a synthetic image is JPEG-encoded natively,
//! its coefficient blocks are driven through the **simulated full system**
//! (CMP cores -> mesh NoC -> request/grant -> task buffers -> chained
//! HWAs -> packet sender -> NoC -> cores), where every HWA execution runs
//! the **AOT-compiled JAX/Pallas artifacts through PJRT** (L1/L2), and the
//! decoded pixels are checked block-by-block against the native golden
//! decoder. All work is submitted through the `accel` driver API; the
//! paper's headline metrics (throughput, invocation latency, chaining
//! speedup) come from its completion receipts.
//!
//!     make artifacts && cargo run --release --example end_to_end

use accnoc::accel::{AccelRuntime, Chain, Job};
use accnoc::clock::PS_PER_US;
use accnoc::fpga::hwa::spec_by_name;
use accnoc::runtime::native::{jpeg_chain, DEFAULT_QTABLE};
use accnoc::runtime::{PjrtCompute, Runtime};
use accnoc::sim::SystemConfig;

use accnoc::workload::jpeg::BlockImage;

const N_BLOCKS: usize = 48;

fn build_runtime(chained: bool) -> AccelRuntime {
    let mut cfg = SystemConfig::paper(vec![
        spec_by_name("izigzag").unwrap(),
        spec_by_name("iquantize").unwrap(),
        spec_by_name("idct").unwrap(),
        spec_by_name("shiftbound").unwrap(),
    ]);
    if chained {
        cfg.fabrics[0].chain_groups = vec![vec![0, 1, 2, 3]];
    }
    let mut rt = AccelRuntime::new(cfg);
    let runtime = Runtime::load_default().unwrap_or_else(|e| {
        eprintln!("artifacts missing — run `make artifacts` first\n{e:#}");
        std::process::exit(1);
    });
    rt.set_compute(Box::new(PjrtCompute::new(runtime)));
    rt
}

fn main() {
    println!("end-to-end: {N_BLOCKS} JPEG blocks through the simulated");
    println!("full system with PJRT-executed Pallas kernels\n");

    let img = BlockImage::synthetic(N_BLOCKS, 0xE2E);
    let coeffs = img.encode();

    // ---- Pass 1: chained decode (depth 3), blocks spread over cores ----
    let mut rt = build_runtime(true);
    let n_procs = rt.n_cores();
    let accels = rt.accels();
    for (b, scan) in coeffs.iter().enumerate() {
        let core = b % n_procs;
        let chain = Chain::of(accels[0])
            .then(accels[1])
            .then(accels[2])
            .then(accels[3]);
        let words: Vec<u32> = scan.iter().map(|c| *c as u32).collect();
        rt.submit(core, Job::chained(chain).direct(words))
            .expect("valid chained job");
    }
    let t0 = std::time::Instant::now();
    assert!(
        rt.run_until_done(2_000_000 * PS_PER_US),
        "chained decode finished"
    );
    let wall = t0.elapsed();
    let sim_us = rt.now() as f64 / PS_PER_US as f64;

    // ---- Verify EVERY core's last block against the golden decoder ----
    // (per-processor state keeps only the final result; full per-block
    // history is checked in rust/tests/integration.rs with smaller
    // counts).
    let mut verified = 0usize;
    let mut max_err = 0i32;
    for (b, scan) in coeffs.iter().enumerate() {
        let core = b % n_procs;
        let is_last_for_core = (b + n_procs) >= coeffs.len();
        if !is_last_for_core {
            continue;
        }
        let want = jpeg_chain(scan, &DEFAULT_QTABLE);
        let got: Vec<i32> = rt
            .last_result(core)
            .iter()
            .map(|w| *w as i32)
            .collect();
        assert_eq!(got.len(), 64, "core {core} result size");
        for i in 0..64 {
            let err = (got[i] - want[i]).abs();
            max_err = max_err.max(err);
            assert!(err <= 1, "block {b} pixel {i}: {} vs {}", got[i], want[i]);
        }
        verified += 1;
    }
    let completions = rt.completions();
    let mean_latency_us = completions
        .iter()
        .map(|c| c.total_ps() as f64 / PS_PER_US as f64)
        .sum::<f64>()
        / completions.len() as f64;

    println!("chained (depth-3) pass:");
    println!("  blocks decoded      : {N_BLOCKS}");
    println!(
        "  HWA tasks executed  : {}",
        rt.system().fabric().tasks_executed()
    );
    println!("  simulated time      : {sim_us:.2} µs");
    println!(
        "  block throughput    : {:.2} blocks/µs (simulated)",
        N_BLOCKS as f64 / sim_us
    );
    println!("  mean invocation lat : {mean_latency_us:.3} µs");
    println!("  wall-clock          : {wall:?}");
    println!(
        "  verified blocks     : {verified} (last per core), max |err| = {max_err} (<= 1)"
    );

    // ---- Pass 2: unchained (depth 0) for the speedup headline ----
    let mut rt0 = build_runtime(false);
    let accels0 = rt0.accels();
    for (b, scan) in coeffs.iter().enumerate() {
        let core = b % n_procs;
        let words: Vec<u32> = scan.iter().map(|c| *c as u32).collect();
        rt0.submit(core, Job::on(accels0[0]).direct(words))
            .expect("valid job");
        for stage in &accels0[1..] {
            rt0.submit(core, Job::on(*stage).direct(vec![0; 64]))
                .expect("valid job");
        }
    }
    assert!(rt0.run_until_done(4_000_000 * PS_PER_US));
    let sim0_us = rt0.now() as f64 / PS_PER_US as f64;
    println!("\nunchained (depth-0) pass: {sim0_us:.2} µs simulated");
    println!(
        "chaining speedup (paper Fig. 10 headline): {:.2}x",
        sim0_us / sim_us
    );
    println!("\nEND-TO-END OK: L1 Pallas -> L2 JAX -> HLO -> PJRT -> L3 fabric");
}
