//! END-TO-END VALIDATION DRIVER (DESIGN.md / EXPERIMENTS.md §End-to-end).
//!
//! Proves all layers compose: a synthetic image is JPEG-encoded natively,
//! its coefficient blocks are driven through the **simulated full system**
//! (CMP cores -> mesh NoC -> request/grant -> task buffers -> chained
//! HWAs -> packet sender -> NoC -> cores), where every HWA execution runs
//! the **AOT-compiled JAX/Pallas artifacts through PJRT** (L1/L2), and the
//! decoded pixels are checked block-by-block against the native golden
//! decoder. Reports the paper's headline metrics (throughput, invocation
//! latency, chaining speedup) for the run.
//!
//!     make artifacts && cargo run --release --example end_to_end

use accnoc::clock::PS_PER_US;
use accnoc::cmp::core::{InvokeSpec, Segment};
use accnoc::fpga::hwa::spec_by_name;
use accnoc::runtime::native::{jpeg_chain, DEFAULT_QTABLE};
use accnoc::runtime::{PjrtCompute, Runtime};
use accnoc::sim::system::{System, SystemConfig};
use accnoc::workload::jpeg::BlockImage;

const N_BLOCKS: usize = 48;

fn build_system(chained: bool) -> System {
    let mut cfg = SystemConfig::paper(vec![
        spec_by_name("izigzag").unwrap(),
        spec_by_name("iquantize").unwrap(),
        spec_by_name("idct").unwrap(),
        spec_by_name("shiftbound").unwrap(),
    ]);
    if chained {
        cfg.chain_groups = vec![vec![0, 1, 2, 3]];
    }
    let mut sys = System::new(cfg);
    let rt = Runtime::load_default().unwrap_or_else(|e| {
        eprintln!("artifacts missing — run `make artifacts` first\n{e:#}");
        std::process::exit(1);
    });
    sys.fabric.set_compute(Box::new(PjrtCompute::new(rt)));
    sys
}

fn main() {
    println!("end-to-end: {N_BLOCKS} JPEG blocks through the simulated");
    println!("full system with PJRT-executed Pallas kernels\n");

    let img = BlockImage::synthetic(N_BLOCKS, 0xE2E);
    let coeffs = img.encode();

    // ---- Pass 1: chained decode (depth 3), blocks spread over cores ----
    let mut sys = build_system(true);
    let n_procs = sys.n_procs();
    for (b, scan) in coeffs.iter().enumerate() {
        let proc = b % n_procs;
        sys.procs[proc].enqueue(Segment::Invoke(
            InvokeSpec::direct(0, scan.iter().map(|c| *c as u32).collect(), 64)
                .chained(3, [1, 2, 3]),
        ));
    }
    let t0 = std::time::Instant::now();
    assert!(
        sys.run_until_done(2_000_000 * PS_PER_US),
        "chained decode finished"
    );
    let wall = t0.elapsed();
    let sim_us = sys.now() as f64 / PS_PER_US as f64;

    // ---- Verify EVERY block against the native golden decoder ----
    let mut verified = 0usize;
    let mut max_err = 0i32;
    let mut by_proc: Vec<Vec<Vec<u32>>> = vec![Vec::new(); n_procs];
    for (i, p) in sys.procs.iter().enumerate() {
        // Results arrive in program order per processor.
        assert_eq!(p.records.len(), p.invocations_done());
        by_proc[i] = vec![p.last_result.clone()];
    }
    // The per-processor last_result only keeps the final block; verify the
    // last block of each processor (full per-block history is checked in
    // rust/tests/integration.rs with smaller counts).
    for (b, scan) in coeffs.iter().enumerate() {
        let proc = b % n_procs;
        let is_last_for_proc =
            (b + n_procs) >= coeffs.len();
        if !is_last_for_proc {
            continue;
        }
        let want = jpeg_chain(scan, &DEFAULT_QTABLE);
        let got: Vec<i32> = sys.procs[proc]
            .last_result
            .iter()
            .map(|w| *w as i32)
            .collect();
        assert_eq!(got.len(), 64, "proc {proc} result size");
        for i in 0..64 {
            let err = (got[i] - want[i]).abs();
            max_err = max_err.max(err);
            assert!(err <= 1, "block {b} pixel {i}: {} vs {}", got[i], want[i]);
        }
        verified += 1;
    }
    let total_invocations: usize =
        sys.procs.iter().map(|p| p.records.len()).sum();
    let mean_latency_us = sys
        .procs
        .iter()
        .flat_map(|p| p.records.iter())
        .map(|r| r.total() as f64 / PS_PER_US as f64)
        .sum::<f64>()
        / total_invocations as f64;

    println!("chained (depth-3) pass:");
    println!("  blocks decoded      : {N_BLOCKS}");
    println!("  HWA tasks executed  : {}", sys.fabric.tasks_executed());
    println!("  simulated time      : {sim_us:.2} µs");
    println!(
        "  block throughput    : {:.2} blocks/µs (simulated)",
        N_BLOCKS as f64 / sim_us
    );
    println!("  mean invocation lat : {mean_latency_us:.3} µs");
    println!("  wall-clock          : {wall:?}");
    println!(
        "  verified blocks     : {verified} (last per core), max |err| = {max_err} (<= 1)"
    );

    // ---- Pass 2: unchained (depth 0) for the speedup headline ----
    let mut sys0 = build_system(false);
    for (b, scan) in coeffs.iter().enumerate() {
        let proc = b % n_procs;
        let words: Vec<u32> = scan.iter().map(|c| *c as u32).collect();
        sys0.procs[proc].enqueue(Segment::Invoke(InvokeSpec::direct(0, words, 64)));
        for hwa in 1..4u8 {
            sys0.procs[proc].enqueue(Segment::Invoke(InvokeSpec::direct(
                hwa,
                vec![0; 64],
                64,
            )));
        }
    }
    assert!(sys0.run_until_done(4_000_000 * PS_PER_US));
    let sim0_us = sys0.now() as f64 / PS_PER_US as f64;
    println!("\nunchained (depth-0) pass: {sim0_us:.2} µs simulated");
    println!(
        "chaining speedup (paper Fig. 10 headline): {:.2}x",
        sim0_us / sim_us
    );
    println!("\nEND-TO-END OK: L1 Pallas -> L2 JAX -> HLO -> PJRT -> L3 fabric");
}
