//! Fault-injection and recovery tour: a two-fabric system with `dfadd`
//! on both, fabric 0's slot deterministically dead (what a landed
//! configuration upset does). One job rides the full recovery ladder —
//! channel-watchdog kills, driver-watchdog timeouts, bounded retries,
//! failover to the equivalent accelerator on fabric 1 — and a second
//! job under the no-recovery policy surfaces the typed
//! `AccelError::PermanentFailure` instead.
//!
//! The same scenario runs inside `accnoc selftest`, so this example and
//! the CLI smoke stay in lockstep (see `accel::fault_recovery_demo`).
//!
//!     cargo run --release --example fault_recovery

fn main() {
    match accnoc::accel::fault_recovery_demo() {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("fault_recovery: {e}");
            std::process::exit(1);
        }
    }
}
