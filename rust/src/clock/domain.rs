//! Clock domains over a global picosecond timeline.
//!
//! The paper's prototype runs the NoC/CMP at 1 GHz (modelled), the
//! interface block at 300 MHz and every HWA at its own Vivado-reported
//! fmax (§6.1). We reproduce that with explicit clock domains: global time
//! is in picoseconds (u64 — ~213 days of 1 GHz time, far beyond any run),
//! and each domain ticks on its own rising edges.

pub type Ps = u64;

pub const PS_PER_US: u64 = 1_000_000;

/// Convert a frequency in MHz to a period in ps (rounded to nearest).
pub fn mhz_to_period_ps(mhz: f64) -> u64 {
    assert!(mhz > 0.0, "frequency must be positive");
    (1_000_000.0 / mhz).round() as u64
}

#[derive(Debug, Clone)]
pub struct ClockDomain {
    pub name: String,
    pub period_ps: u64,
    /// Offset of the first rising edge.
    pub phase_ps: u64,
}

impl ClockDomain {
    pub fn from_mhz(name: &str, mhz: f64) -> Self {
        Self {
            name: name.to_string(),
            period_ps: mhz_to_period_ps(mhz),
            phase_ps: 0,
        }
    }

    pub fn freq_mhz(&self) -> f64 {
        1_000_000.0 / self.period_ps as f64
    }

    /// First rising edge at time strictly greater than `now`.
    pub fn next_edge_after(&self, now: Ps) -> Ps {
        if now < self.phase_ps {
            return self.phase_ps;
        }
        let k = (now - self.phase_ps) / self.period_ps + 1;
        self.phase_ps + k * self.period_ps
    }

    /// Number of whole cycles elapsed at `now` (edges at or before `now`).
    pub fn cycles_at(&self, now: Ps) -> u64 {
        if now < self.phase_ps {
            0
        } else {
            (now - self.phase_ps) / self.period_ps + 1
        }
    }

    pub fn cycles_to_ps(&self, cycles: u64) -> Ps {
        cycles * self.period_ps
    }
}

/// Identifier of a registered domain in a [`MultiClock`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DomainId(pub usize);

/// A set of clock domains advanced together; `advance` moves global time
/// to the earliest next edge and reports every domain ticking then.
/// Same-instant ticks are reported in registration order (deterministic).
#[derive(Debug, Default)]
pub struct MultiClock {
    domains: Vec<ClockDomain>,
    next_edges: Vec<Ps>,
    now: Ps,
}

impl MultiClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, domain: ClockDomain) -> DomainId {
        let id = DomainId(self.domains.len());
        // First edge at or after time zero (phase).
        self.next_edges.push(if domain.phase_ps == 0 {
            domain.period_ps
        } else {
            domain.phase_ps
        });
        self.domains.push(domain);
        id
    }

    pub fn add_mhz(&mut self, name: &str, mhz: f64) -> DomainId {
        self.add(ClockDomain::from_mhz(name, mhz))
    }

    pub fn now(&self) -> Ps {
        self.now
    }

    pub fn domain(&self, id: DomainId) -> &ClockDomain {
        &self.domains[id.0]
    }

    /// Advance to the earliest pending edge; returns (time, ticking ids).
    pub fn advance(&mut self, ticking: &mut Vec<DomainId>) -> Ps {
        debug_assert!(!self.domains.is_empty(), "no domains registered");
        let t = *self.next_edges.iter().min().expect("nonempty");
        ticking.clear();
        for (i, edge) in self.next_edges.iter_mut().enumerate() {
            if *edge == t {
                ticking.push(DomainId(i));
                *edge += self.domains[i].period_ps;
            }
        }
        self.now = t;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mhz_conversion() {
        assert_eq!(mhz_to_period_ps(1000.0), 1000);
        assert_eq!(mhz_to_period_ps(300.0), 3333);
        assert_eq!(mhz_to_period_ps(100.0), 10_000);
    }

    #[test]
    fn next_edge_progresses() {
        let d = ClockDomain::from_mhz("x", 1000.0);
        assert_eq!(d.next_edge_after(0), 1000);
        assert_eq!(d.next_edge_after(999), 1000);
        assert_eq!(d.next_edge_after(1000), 2000);
    }

    #[test]
    fn multiclock_interleaves_domains() {
        let mut mc = MultiClock::new();
        let fast = mc.add_mhz("fast", 1000.0); // every 1000 ps
        let slow = mc.add_mhz("slow", 500.0); // every 2000 ps
        let mut ticks = Vec::new();
        let mut log: Vec<(Ps, Vec<DomainId>)> = Vec::new();
        for _ in 0..4 {
            let t = mc.advance(&mut ticks);
            log.push((t, ticks.clone()));
        }
        assert_eq!(log[0], (1000, vec![fast]));
        assert_eq!(log[1], (2000, vec![fast, slow]));
        assert_eq!(log[2], (3000, vec![fast]));
        assert_eq!(log[3], (4000, vec![fast, slow]));
    }

    #[test]
    fn cycles_at_counts_edges() {
        let d = ClockDomain::from_mhz("x", 1000.0);
        // Edges at 0(phase), then every 1000 ps; phase 0 counts as edge.
        assert_eq!(d.cycles_at(0), 1);
        assert_eq!(d.cycles_at(999), 1);
        assert_eq!(d.cycles_at(1000), 2);
        assert_eq!(d.cycles_at(5500), 6);
    }

    #[test]
    fn simulated_rate_ratio() {
        // A 1 GHz and a 300 MHz domain over 1 µs tick ~1000 and ~300 times.
        let mut mc = MultiClock::new();
        let fast = mc.add_mhz("ghz", 1000.0);
        let slow = mc.add_mhz("iface", 300.0);
        let (mut nf, mut ns) = (0u64, 0u64);
        let mut ticks = Vec::new();
        loop {
            let t = mc.advance(&mut ticks);
            if t > PS_PER_US {
                break;
            }
            for id in &ticks {
                if *id == fast {
                    nf += 1;
                } else if *id == slow {
                    ns += 1;
                }
            }
        }
        assert_eq!(nf, 1000);
        assert!((299..=301).contains(&ns), "ns={ns}");
    }
}
