//! Clock domains over a global picosecond timeline.
//!
//! The paper's prototype runs the NoC/CMP at 1 GHz (modelled), the
//! interface block at 300 MHz and every HWA at its own Vivado-reported
//! fmax (§6.1). We reproduce that with explicit clock domains: global time
//! is in picoseconds (u64 — ~213 days of 1 GHz time, far beyond any run),
//! and each domain ticks on its own rising edges.
//!
//! Edge convention (reconciled across the module): a domain's processed
//! rising edges are `phase + k*period` for `k >= 1` when `phase == 0`
//! (simulated time starts *just after* zero, so the t=0 edge is never
//! stepped) and for `k >= 0` when `phase > 0`. `MultiClock::add`,
//! [`ClockDomain::next_edge_after`] and [`ClockDomain::cycles_at`] all
//! follow this convention; `clock_edge_cycle_conventions_agree` pins it.
//!
//! [`MultiClock`] is the event-driven scheduler core: a binary heap of
//! next-edge events (lazily invalidated), with [`MultiClock::skip_until`]
//! letting the simulator fast-forward fully-idle stretches to the next
//! injection/wakeup instead of ticking every domain edge.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

pub type Ps = u64;

pub const PS_PER_US: u64 = 1_000_000;

/// Convert a frequency in MHz to a period in ps (rounded to nearest).
pub fn mhz_to_period_ps(mhz: f64) -> u64 {
    assert!(mhz > 0.0, "frequency must be positive");
    (1_000_000.0 / mhz).round() as u64
}

#[derive(Debug, Clone)]
pub struct ClockDomain {
    pub name: String,
    pub period_ps: u64,
    /// Offset of the first rising edge.
    pub phase_ps: u64,
}

impl ClockDomain {
    pub fn from_mhz(name: &str, mhz: f64) -> Self {
        Self {
            name: name.to_string(),
            period_ps: mhz_to_period_ps(mhz),
            phase_ps: 0,
        }
    }

    pub fn freq_mhz(&self) -> f64 {
        1_000_000.0 / self.period_ps as f64
    }

    /// First rising edge at time strictly greater than `now`.
    pub fn next_edge_after(&self, now: Ps) -> Ps {
        if now < self.phase_ps {
            return self.phase_ps;
        }
        let k = (now - self.phase_ps) / self.period_ps + 1;
        self.phase_ps + k * self.period_ps
    }

    /// First rising edge at or after `now` (the edge the scheduler would
    /// process next if every earlier edge were already consumed).
    pub fn first_edge_at_or_after(&self, now: Ps) -> Ps {
        let first = if self.phase_ps == 0 {
            self.period_ps
        } else {
            self.phase_ps
        };
        if now <= first {
            return first;
        }
        let k = (now - self.phase_ps).div_ceil(self.period_ps);
        self.phase_ps + k * self.period_ps
    }

    /// Number of whole cycles elapsed at `now`: edges at or before `now`,
    /// under the module's edge convention (a phase-0 domain has NO edge at
    /// t = 0 — see the module docs; this was the t=0 off-by-one).
    pub fn cycles_at(&self, now: Ps) -> u64 {
        if self.phase_ps == 0 {
            now / self.period_ps
        } else if now < self.phase_ps {
            0
        } else {
            (now - self.phase_ps) / self.period_ps + 1
        }
    }

    pub fn cycles_to_ps(&self, cycles: u64) -> Ps {
        cycles * self.period_ps
    }
}

/// What a clock-domain-resident component needs from the scheduler — the
/// `next_event_at` contract (docs/ARCHITECTURE.md §Activity tracking).
/// Every probe is a **lower bound** on when the component can next change
/// state, given that everything outside its domain stays frozen; the
/// scheduler (`System::skip_idle`) combines the probes into a skip target
/// that never crosses any dispatched edge of a `Busy` domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activity {
    /// Mid-work: every edge of the domain must be dispatched.
    Busy,
    /// Purely event-driven right now: the component cannot act until some
    /// other domain hands it work (no self-scheduled future event).
    Idle,
    /// Nothing can happen before this instant (a DMA completion, a
    /// Poisson arrival, an HWA pipeline stage's `done_at`, a TB's CDC
    /// visibility edge); edges strictly before it are provable no-ops.
    NextEventAt(Ps),
}

impl Activity {
    /// Combine two probes: the earlier need wins.
    pub fn join(self, other: Activity) -> Activity {
        match (self, other) {
            (Activity::Busy, _) | (_, Activity::Busy) => Activity::Busy,
            (Activity::Idle, x) | (x, Activity::Idle) => x,
            (Activity::NextEventAt(a), Activity::NextEventAt(b)) => {
                Activity::NextEventAt(a.min(b))
            }
        }
    }
}

/// Identifier of a registered domain in a [`MultiClock`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DomainId(pub usize);

/// A set of clock domains advanced together; `advance` moves global time
/// to the earliest next edge and reports every domain ticking then.
/// Same-instant ticks are reported in registration order (deterministic).
///
/// Internally a min-heap of `(edge_time, domain)` events with lazy
/// deletion: `next_edges` is the authoritative next edge per domain, and
/// heap entries that no longer match it (because [`MultiClock::skip_until`]
/// fast-forwarded the domain) are discarded on pop.
#[derive(Debug, Default)]
pub struct MultiClock {
    domains: Vec<ClockDomain>,
    next_edges: Vec<Ps>,
    heap: BinaryHeap<Reverse<(Ps, usize)>>,
    now: Ps,
}

impl MultiClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, domain: ClockDomain) -> DomainId {
        let id = DomainId(self.domains.len());
        // First processed edge per the module's convention: a phase-0
        // domain's t=0 edge is not simulated.
        let first = if domain.phase_ps == 0 {
            domain.period_ps
        } else {
            domain.phase_ps
        };
        self.next_edges.push(first);
        self.heap.push(Reverse((first, id.0)));
        self.domains.push(domain);
        id
    }

    pub fn add_mhz(&mut self, name: &str, mhz: f64) -> DomainId {
        self.add(ClockDomain::from_mhz(name, mhz))
    }

    pub fn now(&self) -> Ps {
        self.now
    }

    pub fn domain(&self, id: DomainId) -> &ClockDomain {
        &self.domains[id.0]
    }

    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }

    /// The next scheduled (not yet dispatched) edge of `id` — the
    /// earliest instant a `Busy` domain can act, and therefore the bound
    /// per-domain idle skipping must never cross.
    pub fn next_edge_of(&self, id: DomainId) -> Ps {
        self.next_edges[id.0]
    }

    /// Advance to the earliest pending edge; returns (time, ticking ids).
    pub fn advance(&mut self, ticking: &mut Vec<DomainId>) -> Ps {
        debug_assert!(!self.domains.is_empty(), "no domains registered");
        ticking.clear();
        // Pop the earliest valid event, discarding stale (skipped) ones.
        let t = loop {
            let Reverse((t, i)) = self.heap.pop().expect("a valid event per domain");
            if self.next_edges[i] == t {
                ticking.push(DomainId(i));
                break t;
            }
        };
        // Gather every other domain ticking at the same instant.
        while let Some(&Reverse((tt, i))) = self.heap.peek() {
            if tt > t {
                break;
            }
            self.heap.pop();
            if self.next_edges[i] == tt {
                ticking.push(DomainId(i));
            }
        }
        // Same-instant ticks are reported in registration order.
        ticking.sort_unstable();
        ticking.dedup();
        for d in ticking.iter() {
            let next = self.next_edges[d.0] + self.domains[d.0].period_ps;
            self.next_edges[d.0] = next;
            self.heap.push(Reverse((next, d.0)));
        }
        self.now = t;
        t
    }

    /// Fast-forward every domain whose next edge falls strictly before `t`
    /// so that its next processed edge is the first on-grid edge at or
    /// after `t`. Global time (`now`) is unchanged — the next `advance`
    /// lands on the first surviving edge. Per-domain skipped edge counts
    /// are written into `skipped` (indexed by domain id) so callers can
    /// keep cycle statistics consistent with naive per-edge stepping.
    ///
    /// Soundness is the caller's obligation: every skipped edge must be a
    /// provable no-op (see `System::skip_idle`'s per-domain horizons).
    pub fn skip_until(&mut self, t: Ps, skipped: &mut Vec<u64>) {
        skipped.clear();
        skipped.resize(self.domains.len(), 0);
        for (i, d) in self.domains.iter().enumerate() {
            let old = self.next_edges[i];
            if old >= t {
                continue;
            }
            // `old` lies on the domain's grid, so the distance to the
            // first edge >= t is a whole number of periods.
            let new = d.first_edge_at_or_after(t).max(old);
            if new == old {
                continue;
            }
            skipped[i] = (new - old) / d.period_ps;
            self.next_edges[i] = new;
            self.heap.push(Reverse((new, i)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mhz_conversion() {
        assert_eq!(mhz_to_period_ps(1000.0), 1000);
        assert_eq!(mhz_to_period_ps(300.0), 3333);
        assert_eq!(mhz_to_period_ps(100.0), 10_000);
    }

    #[test]
    fn next_edge_progresses() {
        let d = ClockDomain::from_mhz("x", 1000.0);
        assert_eq!(d.next_edge_after(0), 1000);
        assert_eq!(d.next_edge_after(999), 1000);
        assert_eq!(d.next_edge_after(1000), 2000);
    }

    #[test]
    fn multiclock_interleaves_domains() {
        let mut mc = MultiClock::new();
        let fast = mc.add_mhz("fast", 1000.0); // every 1000 ps
        let slow = mc.add_mhz("slow", 500.0); // every 2000 ps
        let mut ticks = Vec::new();
        let mut log: Vec<(Ps, Vec<DomainId>)> = Vec::new();
        for _ in 0..4 {
            let t = mc.advance(&mut ticks);
            log.push((t, ticks.clone()));
        }
        assert_eq!(log[0], (1000, vec![fast]));
        assert_eq!(log[1], (2000, vec![fast, slow]));
        assert_eq!(log[2], (3000, vec![fast]));
        assert_eq!(log[3], (4000, vec![fast, slow]));
    }

    #[test]
    fn cycles_at_counts_edges() {
        let d = ClockDomain::from_mhz("x", 1000.0);
        // Edges at 1000, 2000, ... — a phase-0 domain has NO edge at t=0
        // (the reconciled convention; this was the t=0 off-by-one).
        assert_eq!(d.cycles_at(0), 0);
        assert_eq!(d.cycles_at(999), 0);
        assert_eq!(d.cycles_at(1000), 1);
        assert_eq!(d.cycles_at(5500), 5);
    }

    /// Regression for the t=0 off-by-one: `MultiClock::add`'s first
    /// scheduled edge and `cycles_at`'s count now agree for both phase-0
    /// and phased domains.
    #[test]
    fn clock_edge_cycle_conventions_agree() {
        let plain = ClockDomain::from_mhz("plain", 1000.0);
        let phased = ClockDomain {
            name: "phased".into(),
            period_ps: 1000,
            phase_ps: 400,
        };
        let mut mc = MultiClock::new();
        let a = mc.add(plain.clone());
        let b = mc.add(phased.clone());
        let mut ticks = Vec::new();
        // First edge overall: the phased domain at 400 ps.
        let t = mc.advance(&mut ticks);
        assert_eq!((t, ticks.clone()), (400, vec![b]));
        assert_eq!(phased.cycles_at(t), 1, "one phased edge at/before 400");
        assert_eq!(plain.cycles_at(t), 0, "no phase-0 edge yet");
        // Next: the phase-0 domain's first edge, one full period in.
        let t = mc.advance(&mut ticks);
        assert_eq!((t, ticks.clone()), (1000, vec![a]));
        assert_eq!(plain.cycles_at(t), 1);
        // Phased cadence continues on its own grid.
        let t = mc.advance(&mut ticks);
        assert_eq!((t, ticks.clone()), (1400, vec![b]));
        assert_eq!(phased.cycles_at(t), 2);
        // cycles_at at any edge equals the number of advances that ticked
        // that domain — the two conventions are reconciled.
    }

    #[test]
    fn simulated_rate_ratio() {
        // A 1 GHz and a 300 MHz domain over 1 µs tick ~1000 and ~300 times.
        let mut mc = MultiClock::new();
        let fast = mc.add_mhz("ghz", 1000.0);
        let slow = mc.add_mhz("iface", 300.0);
        let (mut nf, mut ns) = (0u64, 0u64);
        let mut ticks = Vec::new();
        loop {
            let t = mc.advance(&mut ticks);
            if t > PS_PER_US {
                break;
            }
            for id in &ticks {
                if *id == fast {
                    nf += 1;
                } else if *id == slow {
                    ns += 1;
                }
            }
        }
        assert_eq!(nf, 1000);
        assert!((299..=301).contains(&ns), "ns={ns}");
    }

    #[test]
    fn skip_until_lands_on_grid_edges() {
        let mut mc = MultiClock::new();
        let a = mc.add_mhz("a", 1000.0); // 1000 ps grid
        let b = mc.add_mhz("b", 300.0); // 3333 ps grid
        let mut ticks = Vec::new();
        assert_eq!(mc.advance(&mut ticks), 1000); // a's first edge
        let mut skipped = Vec::new();
        mc.skip_until(10_500, &mut skipped);
        // a: 2000 -> 11000 (9 edges skipped); b: 3333 -> 13332 (3 skipped).
        assert_eq!(skipped[a.0], 9);
        assert_eq!(skipped[b.0], 3);
        let t = mc.advance(&mut ticks);
        assert_eq!((t, ticks.clone()), (11_000, vec![a]));
        assert_eq!(mc.advance(&mut ticks), 12_000);
        let t = mc.advance(&mut ticks);
        assert_eq!((t, ticks.clone()), (13_000, vec![a]));
        let t = mc.advance(&mut ticks);
        assert_eq!((t, ticks.clone()), (13_332, vec![b]), "b stays on grid");
    }

    #[test]
    fn skip_until_past_target_is_a_noop() {
        let mut mc = MultiClock::new();
        let a = mc.add_mhz("a", 1000.0);
        let mut ticks = Vec::new();
        let mut skipped = Vec::new();
        mc.skip_until(500, &mut skipped); // before the first edge
        assert_eq!(skipped[a.0], 0);
        assert_eq!(mc.advance(&mut ticks), 1000);
    }
}
