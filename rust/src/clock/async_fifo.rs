//! Asynchronous FIFO for clock-domain crossing (paper §4, Fig. 2: the
//! router input/output buffers and all channel buffers bridging the NoC,
//! interface and per-HWA frequencies).
//!
//! Model: an element written at time `t_w` becomes visible to the reader
//! only at the **second** read-domain rising edge after `t_w` — the
//! two-stage synchronizer latency the paper implements with registers
//! (§4.2 B.1). Occupancy for backpressure is exact (a mild idealization of
//! the gray-code pointer synchronizers; it errs by <= 2 producer cycles of
//! conservatism in the paper's design and none here, noted in DESIGN.md).

use std::collections::VecDeque;

use super::domain::{ClockDomain, Ps};

#[derive(Debug)]
pub struct AsyncFifo<T> {
    /// (visible_at, element)
    items: VecDeque<(Ps, T)>,
    capacity: usize,
    /// Read-side clock, used to compute visibility edges.
    read_period_ps: u64,
    read_phase_ps: u64,
    /// Synchronizer depth in read edges (2 = two-stage, the paper's).
    sync_stages: u64,
    /// Statistics.
    pub pushed: u64,
    pub popped: u64,
    pub high_water: usize,
}

impl<T> AsyncFifo<T> {
    pub fn new(capacity: usize, read_clock: &ClockDomain) -> Self {
        Self::with_stages(capacity, read_clock, 2)
    }

    pub fn with_stages(capacity: usize, read_clock: &ClockDomain, sync_stages: u64) -> Self {
        assert!(capacity > 0);
        Self {
            items: VecDeque::with_capacity(capacity),
            capacity,
            read_period_ps: read_clock.period_ps,
            read_phase_ps: read_clock.phase_ps,
            sync_stages,
            pushed: 0,
            popped: 0,
            high_water: 0,
        }
    }

    /// A same-domain FIFO (no CDC): visible on the next read edge.
    pub fn synchronous(capacity: usize, clock: &ClockDomain) -> Self {
        Self::with_stages(capacity, clock, 1)
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn can_push(&self) -> bool {
        self.items.len() < self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn visible_at(&self, now: Ps) -> Ps {
        // k-th read edge strictly after `now`.
        let first = if now < self.read_phase_ps {
            self.read_phase_ps
        } else {
            let k = (now - self.read_phase_ps) / self.read_period_ps + 1;
            self.read_phase_ps + k * self.read_period_ps
        };
        first + (self.sync_stages - 1) * self.read_period_ps
    }

    /// Write at time `now`; returns false (rejecting) when full.
    pub fn push(&mut self, now: Ps, item: T) -> bool {
        if !self.can_push() {
            return false;
        }
        let vis = self.visible_at(now);
        self.items.push_back((vis, item));
        self.pushed += 1;
        self.high_water = self.high_water.max(self.items.len());
        true
    }

    /// True when the front element is visible to a read at `now`.
    pub fn front_visible(&self, now: Ps) -> bool {
        self.items.front().map(|(v, _)| *v <= now).unwrap_or(false)
    }

    pub fn peek(&self, now: Ps) -> Option<&T> {
        match self.items.front() {
            Some((v, item)) if *v <= now => Some(item),
            _ => None,
        }
    }

    /// Read at time `now` (call on read-domain edges).
    pub fn pop(&mut self, now: Ps) -> Option<T> {
        if self.front_visible(now) {
            self.popped += 1;
            self.items.pop_front().map(|(_, item)| item)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::domain::ClockDomain;

    #[test]
    fn two_stage_sync_latency() {
        let rd = ClockDomain::from_mhz("rd", 100.0); // 10_000 ps period
        let mut f: AsyncFifo<u32> = AsyncFifo::new(4, &rd);
        assert!(f.push(2_500, 7));
        // First read edge after 2500 is 10_000; second is 20_000.
        assert!(f.pop(10_000).is_none());
        assert!(f.pop(19_999).is_none());
        assert_eq!(f.pop(20_000), Some(7));
    }

    #[test]
    fn synchronous_visible_next_edge() {
        let rd = ClockDomain::from_mhz("rd", 100.0);
        let mut f: AsyncFifo<u32> = AsyncFifo::synchronous(4, &rd);
        assert!(f.push(2_500, 7));
        assert_eq!(f.pop(10_000), Some(7));
    }

    #[test]
    fn capacity_backpressure() {
        let rd = ClockDomain::from_mhz("rd", 100.0);
        let mut f: AsyncFifo<u32> = AsyncFifo::new(2, &rd);
        assert!(f.push(0, 1));
        assert!(f.push(0, 2));
        assert!(!f.can_push());
        assert!(!f.push(0, 3));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn fifo_order_preserved() {
        let rd = ClockDomain::from_mhz("rd", 1000.0);
        let mut f: AsyncFifo<u32> = AsyncFifo::new(8, &rd);
        for i in 0..5 {
            f.push(i * 10, i as u32);
        }
        let mut out = Vec::new();
        let mut t = 0;
        while out.len() < 5 {
            t += 1000;
            if let Some(v) = f.pop(t) {
                out.push(v);
            }
        }
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn high_water_tracks() {
        let rd = ClockDomain::from_mhz("rd", 100.0);
        let mut f: AsyncFifo<u32> = AsyncFifo::new(4, &rd);
        f.push(0, 1);
        f.push(0, 2);
        f.push(0, 3);
        assert_eq!(f.high_water, 3);
        assert_eq!(f.pushed, 3);
    }
}
