//! Multi-clock-domain timing substrate: domains, the edge scheduler and
//! CDC asynchronous FIFOs (paper §4.2 B.1).

pub mod async_fifo;
pub mod domain;

pub use async_fifo::AsyncFifo;
pub use domain::{
    mhz_to_period_ps, Activity, ClockDomain, DomainId, MultiClock, Ps,
    PS_PER_US,
};
