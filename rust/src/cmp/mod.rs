//! CMP cores (MicroBlaze-class timing model) and the Fig. 9 partitioned
//! applications; the software interface semantics of Fig. 4.

pub mod apps;
pub mod core;

pub use apps::{gsm_app, jpeg_app, jpeg_chain_app, jpeg_chain_depth_program, App, AppFunction};
pub use core::{
    mmu_payload_packet, InvokeRecord, InvokeSpec, Processor, Segment,
    INVOKE_OVERHEAD_CYCLES, RECV_CYCLES_PER_FLIT, SEND_CYCLES_PER_FLIT,
};
