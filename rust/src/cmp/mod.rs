//! CMP cores (MicroBlaze-class timing model) and the Fig. 9 partitioned
//! applications; the software interface semantics of Fig. 4.
//!
//! `core` is the compilation target of the typed driver layer
//! ([`crate::accel`]): applications describe work as `accel::Program`s,
//! which the driver validates and lowers to `Segment` streams.

pub mod apps;
pub mod core;

pub use apps::{
    gsm_app, jpeg_app, jpeg_chain_app, jpeg_chain_block_program,
    jpeg_chain_depth_program, App, AppFunction,
};
pub use core::{
    mmu_payload_packet, InvokeRecord, InvokeSpec, Processor, Segment,
    INVOKE_OVERHEAD_CYCLES, RECV_CYCLES_PER_FLIT, SEND_CYCLES_PER_FLIT,
};
