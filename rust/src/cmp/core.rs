//! Processor (CMP core) timing model.
//!
//! The paper's prototype uses MicroBlaze soft cores — classic 5-stage
//! in-order RISC — at a modelled 1 GHz (§6.1), invoking HWAs through the
//! C functions of Fig. 4 over FSL links. We model a core as a program of
//! [`Segment`]s executed in order: pure software compute (a cycle count)
//! and HWA invocations (request → grant → payload → result), with
//! calibrated per-flit software send/receive overheads — the paper's §6.6
//! observation that "the most time-consuming part is the packet sending
//! and receiving operations of the processors" is this constant.

use std::collections::VecDeque;

use crate::clock::{Activity, Ps};
use crate::flit::{
    Direction, Flit, FlitKind, HeadFields, Packet, PacketBuilder, PacketType,
};

use crate::fpga::channel::task::CommandKind;

/// Software cycles a core spends pushing one flit into the FSL (marshal +
/// `put` loop). Calibrated constant (DESIGN.md substitution 3).
pub const SEND_CYCLES_PER_FLIT: u64 = 6;
/// Software cycles per received flit (FSL `get` + demarshal).
pub const RECV_CYCLES_PER_FLIT: u64 = 6;
/// Fixed software overhead per `*_HWA_invoke` call (argument setup).
pub const INVOKE_OVERHEAD_CYCLES: u64 = 40;

/// One HWA invocation request (the Fig. 4 function arguments), in wire
/// terms. This is the **compiled form** that [`crate::accel::Job`] lowers
/// to after validation — application code should build jobs through the
/// typed driver API rather than packing these fields by hand (the raw
/// constructors remain for wire-level tests: nothing here checks that
/// `chain_index` lanes name real accelerators).
#[derive(Debug, Clone)]
pub struct InvokeSpec {
    pub hwa_id: u8,
    pub words: Vec<u32>,
    pub chain_depth: u8,
    pub chain_index: [u8; 3],
    pub priority: u8,
    /// Direct access (Fig. 5a) or memory access (Fig. 5b).
    pub direction: Direction,
    pub start_addr: u32,
    /// Bytes the MMU should fetch (memory-access scenario; 0 = derive
    /// from `words`).
    pub mem_bytes: u16,
    /// Result words expected back (for completion detection).
    pub expect_words: usize,
    /// NoC node of the owning fabric's interface tile (floorplanned
    /// systems address jobs per fabric). `None` — every raw constructor
    /// — falls back to the core's default fabric node; the driver's
    /// compiled jobs always carry the resolved tile.
    pub dest_node: Option<u8>,
}

impl InvokeSpec {
    pub fn direct(hwa_id: u8, words: Vec<u32>, expect_words: usize) -> Self {
        Self {
            hwa_id,
            words,
            chain_depth: 0,
            chain_index: [0; 3],
            priority: 0,
            direction: Direction::ProcToHwa,
            start_addr: 0,
            mem_bytes: 0,
            expect_words,
            dest_node: None,
        }
    }

    /// Memory-access invocation (Fig. 5b): the MMU DMAs `bytes` from
    /// `start_addr` and the result is written back to memory.
    pub fn memory(hwa_id: u8, start_addr: u32, bytes: u16) -> Self {
        Self {
            hwa_id,
            words: Vec::new(),
            chain_depth: 0,
            chain_index: [0; 3],
            priority: 0,
            direction: Direction::MemToHwa,
            start_addr,
            mem_bytes: bytes,
            expect_words: 0,
            dest_node: None,
        }
    }

    pub fn chained(mut self, depth: u8, index: [u8; 3]) -> Self {
        self.chain_depth = depth;
        self.chain_index = index;
        self
    }

    pub fn with_priority(mut self, p: u8) -> Self {
        self.priority = p;
        self
    }
}

/// One step of a core's program — the stream [`crate::accel::Program`]
/// compiles down to.
#[derive(Debug, Clone)]
pub enum Segment {
    /// Pure software execution for this many core cycles.
    Compute(u64),
    /// Invoke an HWA and wait for its completion.
    Invoke(InvokeSpec),
}

/// Per-invocation latency breakdown (Fig. 9 / Fig. 14 measurements).
/// `PartialEq` so the event-driven scheduler's determinism tests can
/// compare whole record vectors bit-for-bit.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InvokeRecord {
    pub t_request: Ps,
    pub t_grant: Ps,
    pub t_payload_done: Ps,
    pub t_result_first: Ps,
    pub t_result_last: Ps,
}

impl InvokeRecord {
    /// Total communication + acceleration latency.
    pub fn total(&self) -> Ps {
        self.t_result_last.saturating_sub(self.t_request)
    }

    /// Request-to-grant handshake latency.
    pub fn grant_latency(&self) -> Ps {
        self.t_grant.saturating_sub(self.t_request)
    }
}

#[derive(Debug)]
enum CoreState {
    Computing { cycles_left: u64 },
    /// Marshalling/sending flits: one flit leaves every
    /// SEND_CYCLES_PER_FLIT cycles.
    Sending { flits: VecDeque<Flit>, cooldown: u64, awaiting: Awaiting },
    AwaitGrant,
    AwaitResult { words_left: usize },
    /// Draining receive overhead cycles after the last result flit.
    RecvOverhead { cycles_left: u64 },
    Done,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Awaiting {
    Grant,
    Result,
    /// Fire-and-forget send (reserved; no current program uses it).
    #[allow(dead_code)]
    Nothing,
}

/// A CMP core bound to a NoC node.
pub struct Processor {
    pub id: u8,
    pub node: u8,
    fpga_node: u8,
    program: VecDeque<Segment>,
    state: CoreState,
    builder: PacketBuilder,
    current: Option<InvokeSpec>,
    record: InvokeRecord,
    pub records: Vec<InvokeRecord>,
    /// Result payload words of the last completed invocation.
    pub last_result: Vec<u32>,
    result_accum: Vec<u32>,
    pub sw_cycles: u64,
    pub total_cycles: u64,
    pub finished_at: Option<Ps>,
}

impl Processor {
    pub fn new(id: u8, node: u8, fpga_node: u8, program: Vec<Segment>) -> Self {
        let mut p = Self {
            id,
            node,
            fpga_node,
            program: program.into(),
            state: CoreState::Done,
            builder: PacketBuilder::new(((id as u32) << 20) | 1),
            current: None,
            record: InvokeRecord::default(),
            records: Vec::new(),
            last_result: Vec::new(),
            result_accum: Vec::new(),
            sw_cycles: 0,
            total_cycles: 0,
            finished_at: None,
        };
        p.next_segment(0);
        p
    }

    pub fn done(&self) -> bool {
        matches!(self.state, CoreState::Done) && self.program.is_empty()
    }

    /// Append a segment (rate-driven workloads feed programs on the fly).
    pub fn enqueue(&mut self, seg: Segment) {
        self.program.push_back(seg);
        self.finished_at = None;
    }

    /// Number of completed invocations.
    pub fn invocations_done(&self) -> usize {
        self.records.len()
    }

    /// Invocations accepted but not yet completed: the in-flight one (if
    /// any) plus queued `Invoke` segments. `invocations_done() +
    /// pending_invocations()` is the sequence number the next submitted
    /// invocation will complete at — the driver's receipt numbering.
    pub fn pending_invocations(&self) -> usize {
        self.current.is_some() as usize
            + self
                .program
                .iter()
                .filter(|s| matches!(s, Segment::Invoke(_)))
                .count()
    }

    /// True while the core needs clock edges to make progress (computing,
    /// sending, draining receive overhead, or with queued program). The
    /// await states are event-driven — progress comes from `deliver` — so
    /// the idle-skipping scheduler may fast-forward past them.
    pub fn needs_clock(&self) -> bool {
        match &self.state {
            CoreState::Computing { .. }
            | CoreState::Sending { .. }
            | CoreState::RecvOverhead { .. } => true,
            CoreState::AwaitGrant | CoreState::AwaitResult { .. } => false,
            CoreState::Done => !self.program.is_empty(),
        }
    }

    /// Scheduler probe (the [`Activity`] contract): a core is clock-driven
    /// while working and purely event-driven while awaiting a grant or
    /// result — it never self-schedules a future event, so the report is
    /// binary.
    pub fn activity(&self) -> Activity {
        if self.needs_clock() {
            Activity::Busy
        } else {
            Activity::Idle
        }
    }

    /// Fold `n` core cycles the idle-skipping scheduler fast-forwarded
    /// past (the core was awaiting/done, so `step` would only have bumped
    /// this counter); keeps `total_cycles` identical to per-edge stepping.
    pub fn account_idle_cycles(&mut self, n: u64) {
        debug_assert!(!self.needs_clock(), "skipped a working core");
        self.total_cycles += n;
    }

    fn next_segment(&mut self, now: Ps) {
        match self.program.pop_front() {
            None => {
                if self.finished_at.is_none() {
                    self.finished_at = Some(now);
                }
                self.state = CoreState::Done;
            }
            Some(Segment::Compute(c)) => {
                self.state = CoreState::Computing { cycles_left: c.max(1) };
            }
            Some(Segment::Invoke(spec)) => {
                self.record = InvokeRecord::default();
                let dest = spec.dest_node.unwrap_or(self.fpga_node);
                let req = self.builder.command(HeadFields {
                    routing: dest,
                    hwa_id: spec.hwa_id,
                    src_id: self.id,
                    direction: spec.direction,
                    chain_depth: spec.chain_depth,
                    chain_index: spec.chain_index,
                    priority: spec.priority,
                    start_addr: spec.start_addr,
                    data_size: if spec.mem_bytes > 0 {
                        spec.mem_bytes.min(1023)
                    } else {
                        ((spec.words.len() * 4).min(1023)) as u16
                    },
                    payload: CommandKind::Request.encode(),
                    ..HeadFields::default()
                });
                self.current = Some(spec);
                self.state = CoreState::Sending {
                    flits: req.flits.into(),
                    cooldown: INVOKE_OVERHEAD_CYCLES,
                    awaiting: Awaiting::Grant,
                };
            }
        }
    }

    /// One core cycle; returns at most one flit to inject into the NI.
    /// `can_inject` tells whether the NI accepts a flit this cycle.
    pub fn step(&mut self, now: Ps, can_inject: bool) -> Option<Flit> {
        self.total_cycles += 1;
        match std::mem::replace(&mut self.state, CoreState::Done) {
            CoreState::Computing { cycles_left } => {
                self.sw_cycles += 1;
                if cycles_left > 1 {
                    self.state = CoreState::Computing {
                        cycles_left: cycles_left - 1,
                    };
                } else {
                    self.next_segment(now);
                }
                None
            }
            CoreState::Sending {
                mut flits,
                cooldown,
                awaiting,
            } => {
                if cooldown > 0 {
                    self.sw_cycles += 1;
                    self.state = CoreState::Sending {
                        flits,
                        cooldown: cooldown - 1,
                        awaiting,
                    };
                    return None;
                }
                if !can_inject {
                    self.state = CoreState::Sending {
                        flits,
                        cooldown,
                        awaiting,
                    };
                    return None;
                }
                let flit = flits.pop_front();
                if let Some(f) = flit {
                    if f.is_head() && self.record.t_request == 0 {
                        self.record.t_request = now;
                    }
                }
                if flits.is_empty() {
                    match awaiting {
                        Awaiting::Grant => self.state = CoreState::AwaitGrant,
                        Awaiting::Result => {
                            self.record.t_payload_done = now;
                            let expect = self
                                .current
                                .as_ref()
                                .map(|s| s.expect_words)
                                .unwrap_or(0);
                            self.result_accum.clear();
                            self.state = CoreState::AwaitResult {
                                words_left: expect,
                            };
                        }
                        Awaiting::Nothing => self.next_segment(now),
                    }
                } else {
                    self.state = CoreState::Sending {
                        flits,
                        cooldown: SEND_CYCLES_PER_FLIT.saturating_sub(1),
                        awaiting,
                    };
                }
                flit
            }
            s @ CoreState::AwaitGrant | s @ CoreState::AwaitResult { .. } => {
                self.state = s;
                None
            }
            CoreState::RecvOverhead { cycles_left } => {
                self.sw_cycles += 1;
                if cycles_left > 1 {
                    self.state = CoreState::RecvOverhead {
                        cycles_left: cycles_left - 1,
                    };
                } else {
                    self.next_segment(now);
                }
                None
            }
            CoreState::Done => {
                if !self.program.is_empty() {
                    self.next_segment(now);
                }
                None
            }
        }
    }

    /// A flit ejected at this core's node is delivered.
    pub fn deliver(&mut self, flit: Flit, now: Ps) {
        match std::mem::replace(&mut self.state, CoreState::Done) {
            CoreState::AwaitGrant => {
                debug_assert!(flit.is_head());
                let h = flit.head_fields();
                debug_assert_eq!(h.pkt_type, PacketType::Command);
                match CommandKind::decode(h.payload) {
                    CommandKind::Grant => {
                        self.record.t_grant = now;
                        let spec = self.current.as_ref().expect("invoking");
                        if matches!(spec.direction, Direction::MemToHwa) {
                            // Memory scenario: the MMU sends the payload;
                            // we wait for the notify.
                            self.state = CoreState::AwaitResult { words_left: 0 };
                            return;
                        }
                        let dest =
                            spec.dest_node.unwrap_or(self.fpga_node);
                        let payload = self.builder.payload(
                            HeadFields {
                                routing: dest,
                                hwa_id: h.hwa_id,
                                src_id: self.id,
                                tb_id: h.tb_id,
                                task_head: true,
                                task_tail: true,
                                chain_depth: spec.chain_depth,
                                chain_index: spec.chain_index,
                                priority: spec.priority,
                                direction: spec.direction,
                                ..HeadFields::default()
                            },
                            &spec.words,
                        );
                        self.state = CoreState::Sending {
                            flits: payload.flits.into(),
                            cooldown: 0,
                            awaiting: Awaiting::Result,
                        };
                    }
                    CommandKind::Notify => {
                        // Memory-access scenario: the grant went to the
                        // MMU, so the first packet the processor sees is
                        // the completion notify (§5, Fig. 5b).
                        self.record.t_grant = now;
                        self.finish_invoke(now, 0);
                    }
                    _ => {
                        // Unexpected command while awaiting grant.
                        self.state = CoreState::AwaitGrant;
                    }
                }
            }
            CoreState::AwaitResult { words_left } => {
                if flit.is_head() {
                    let h = flit.head_fields();
                    if h.pkt_type == PacketType::Command {
                        // Notify (memory scenario): completion. Any other
                        // command here (e.g. a NACK raced by a fault) is
                        // ignored rather than acted on — the core keeps
                        // waiting and its caller's timeout recovers.
                        if CommandKind::decode(h.payload) == CommandKind::Notify {
                            self.finish_invoke(now, 0);
                        } else {
                            self.state = CoreState::AwaitResult { words_left };
                        }
                        return;
                    }
                    if self.record.t_result_first == 0 {
                        self.record.t_result_first = now;
                    }
                    self.state = CoreState::AwaitResult { words_left };
                    return;
                }
                // Data flit: 4 words.
                let [a, b] = flit.body_payload();
                for w in [a as u32, (a >> 32) as u32, b as u32, (b >> 32) as u32] {
                    if self.result_accum.len()
                        < self.current.as_ref().map(|s| s.expect_words).unwrap_or(0)
                    {
                        self.result_accum.push(w);
                    }
                }
                if flit.kind() == FlitKind::Tail {
                    let drained = words_left.saturating_sub(self.result_accum.len());
                    let _ = drained;
                    let n_flits = 1 + self.result_accum.len().div_ceil(4).max(1) as u64;
                    self.finish_invoke(now, n_flits * RECV_CYCLES_PER_FLIT);
                } else {
                    self.state = CoreState::AwaitResult { words_left };
                }
            }
            other => {
                // Late/unexpected flit (e.g. stale grant after reset):
                // ignore but keep state.
                self.state = other;
            }
        }
    }

    /// Abandon the in-flight invocation: the driver-side watchdog gave
    /// up waiting on it (hung task, lost completion). The partial
    /// timestamp record is pushed as a tombstone — `t_result_last`
    /// stays 0 — so receipt sequence numbering is preserved for every
    /// later submission; any late flit of the abandoned invocation is
    /// absorbed by `deliver`'s catch-all arm. Only the event-driven
    /// await states abort (a sending core is still making progress).
    /// Returns `false` when there was nothing to abort.
    pub fn abort_invocation(&mut self, now: Ps) -> bool {
        if self.current.is_none()
            || !matches!(
                self.state,
                CoreState::AwaitGrant | CoreState::AwaitResult { .. }
            )
        {
            return false;
        }
        self.current = None;
        self.records.push(self.record);
        self.record = InvokeRecord::default();
        self.result_accum.clear();
        self.next_segment(now);
        true
    }

    fn finish_invoke(&mut self, now: Ps, recv_cycles: u64) {
        self.record.t_result_last = now;
        self.records.push(self.record);
        self.last_result = std::mem::take(&mut self.result_accum);
        self.current = None;
        if recv_cycles > 0 {
            self.state = CoreState::RecvOverhead {
                cycles_left: recv_cycles,
            };
        } else {
            self.next_segment(now);
        }
    }

    /// Build a one-shot invocation program (Fig. 4's D_HWA_invoke).
    pub fn single_invoke(spec: InvokeSpec) -> Vec<Segment> {
        vec![Segment::Invoke(spec)]
    }
}

/// Convenience: packet the MMU sends on the processor's behalf; reused by
/// the memory-access tests.
pub fn mmu_payload_packet(
    builder: &mut PacketBuilder,
    fpga_node: u8,
    grant: &HeadFields,
    words: &[u32],
) -> Packet {
    builder.payload(
        HeadFields {
            routing: fpga_node,
            hwa_id: grant.hwa_id,
            src_id: grant.src_id,
            tb_id: grant.tb_id,
            task_head: true,
            task_tail: true,
            chain_depth: grant.chain_depth,
            chain_index: grant.chain_index,
            priority: grant.priority,
            direction: Direction::MemToHwa,
            start_addr: grant.start_addr,
            ..HeadFields::default()
        },
        words,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_segment_counts_cycles() {
        let mut p = Processor::new(0, 0, 5, vec![Segment::Compute(10)]);
        for i in 0..10 {
            assert!(!p.done(), "cycle {i}");
            p.step(i, true);
        }
        assert!(p.done());
        assert_eq!(p.sw_cycles, 10);
    }

    #[test]
    fn invoke_emits_request_after_overhead() {
        let spec = InvokeSpec::direct(3, vec![1, 2], 2);
        let mut p = Processor::new(1, 0, 5, Processor::single_invoke(spec));
        let mut sent = None;
        for c in 0..100 {
            if let Some(f) = p.step(c, true) {
                sent = Some((c, f));
                break;
            }
        }
        let (cycle, f) = sent.expect("request sent");
        assert_eq!(cycle, INVOKE_OVERHEAD_CYCLES);
        let h = f.head_fields();
        assert_eq!(h.hwa_id, 3);
        assert_eq!(h.src_id, 1);
        assert_eq!(h.routing, 5);
        assert_eq!(CommandKind::decode(h.payload), CommandKind::Request);
    }

    #[test]
    fn grant_triggers_payload_with_tb_id() {
        let spec = InvokeSpec::direct(3, vec![1, 2, 3, 4, 5], 2);
        let mut p = Processor::new(1, 0, 5, Processor::single_invoke(spec));
        let mut now = 0;
        while p.step(now, true).is_none() {
            now += 1;
        }
        // Deliver a grant for TB 2.
        let mut b = PacketBuilder::new(99);
        let grant = b.command(HeadFields {
            hwa_id: 3,
            src_id: 1,
            tb_id: 2,
            payload: CommandKind::Grant.encode(),
            ..HeadFields::default()
        });
        p.deliver(grant.flits[0], now);
        let mut flits = Vec::new();
        for _ in 0..200 {
            now += 1;
            if let Some(f) = p.step(now, true) {
                flits.push(f);
            }
        }
        // Payload: head + 2 data flits; head carries tb_id 2.
        assert_eq!(flits.len(), 3);
        assert_eq!(flits[0].head_fields().tb_id, 2);
        assert_eq!(flits[0].head_fields().task_tail, true);
        // Send pacing: ~SEND_CYCLES_PER_FLIT between flits.
        assert!(p.record.t_payload_done > 0);
    }

    #[test]
    fn result_completes_invocation_and_records() {
        let spec = InvokeSpec::direct(0, vec![7, 8], 4);
        let mut p = Processor::new(0, 0, 5, Processor::single_invoke(spec));
        let mut now = 0;
        while p.step(now, true).is_none() {
            now += 1;
        }
        let mut b = PacketBuilder::new(50);
        let grant = b.command(HeadFields {
            payload: CommandKind::Grant.encode(),
            ..HeadFields::default()
        });
        now += 5; // grant arrives after some NoC latency
        p.deliver(grant.flits[0], now);
        // Drain payload sends.
        for _ in 0..100 {
            now += 1;
            p.step(now, true);
        }
        // Deliver result: head + tail with 4 words.
        let result = b.payload(
            HeadFields {
                direction: Direction::HwaToProc,
                ..HeadFields::default()
            },
            &[11, 22, 33, 44],
        );
        for f in &result.flits {
            now += 1;
            p.deliver(*f, now);
        }
        // Receive overhead then done.
        for _ in 0..100 {
            now += 1;
            p.step(now, true);
        }
        assert!(p.done());
        assert_eq!(p.last_result, vec![11, 22, 33, 44]);
        assert_eq!(p.records.len(), 1);
        let r = p.records[0];
        assert!(r.t_request > 0);
        assert!(r.t_grant > r.t_request);
        assert!(r.t_result_last >= r.t_result_first);
    }

    #[test]
    fn backpressure_defers_send() {
        let spec = InvokeSpec::direct(0, vec![], 0);
        let mut p = Processor::new(0, 0, 5, Processor::single_invoke(spec));
        let mut now = 0;
        // Never allow injection: no flit should escape.
        for _ in 0..200 {
            assert!(p.step(now, false).is_none());
            now += 1;
        }
        // Allow: request appears.
        assert!(p.step(now, true).is_some());
    }
}
