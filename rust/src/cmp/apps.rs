//! Partitioned applications for the Fig. 9 latency-breakdown experiment:
//! GSM (3-flit payloads) and the JPEG decoder (large payloads), each split
//! into functions that can run in software on the core or as HWAs on the
//! FPGA.
//!
//! Programs are expressed in the typed driver layer ([`crate::accel`]):
//! every function is a [`Job`] on its accelerator's [`AccelHandle`], and
//! the chained variants build [`Chain`]s instead of hand-packing the
//! 2-bit chain-index lanes.
//!
//! Software cycle counts are calibrated constants (DESIGN.md substitution
//! 3): they reflect the relative cost of the C implementations on a
//! MicroBlaze-class in-order core (the paper's Fig. 9 shows FPGA
//! acceleration winning in every partition, most at the all-FPGA
//! partitions GSM.p3 / JPEG.p5 — these constants preserve exactly that
//! ordering, with software ~10-40x slower than the HWA datapath, typical
//! of HLS speedups for these kernels).

use crate::accel::{AccelHandle, Chain, Job, Phase, Program};
use crate::fpga::hwa::{spec_by_name, HwaSpec, Resources};

/// One application function: software cost vs. HWA offload.
#[derive(Debug, Clone)]
pub struct AppFunction {
    pub name: &'static str,
    /// Core cycles when executed in software.
    pub sw_cycles: u64,
    /// HWA id executing this function when offloaded.
    pub hwa_id: u8,
    /// Input words sent on offload.
    pub in_words: usize,
    /// Result words received back.
    pub out_words: usize,
}

impl AppFunction {
    /// Driver handle for this function's accelerator.
    pub fn handle(&self) -> AccelHandle {
        AccelHandle::new(self.hwa_id, self.in_words, self.out_words)
    }

    /// Synthetic input words (the Fig. 9 workloads are shape-driven).
    fn input_words(&self) -> Vec<u32> {
        (0..self.in_words as u32).collect()
    }
}

/// A partitioned application: functions 0..k run on the FPGA, the rest in
/// software ("partition k" = `k` leading functions offloaded; the paper's
/// GSM.p3 / JPEG.p5 all-FPGA cases are `k = functions.len()`).
#[derive(Debug, Clone)]
pub struct App {
    pub name: &'static str,
    pub functions: Vec<AppFunction>,
    /// When all functions are offloaded AND their HWAs share a chain
    /// group, the invocation can use the chaining mechanism.
    pub chainable: bool,
}

impl App {
    pub fn n_partitions(&self) -> usize {
        self.functions.len() + 1
    }

    /// Program for partition `k`: the first `k` functions offloaded as
    /// individual HWA invocations, the rest as software compute.
    pub fn partition_program(&self, k: usize) -> Program {
        assert!(k <= self.functions.len());
        let mut prog = Program::new();
        for (i, f) in self.functions.iter().enumerate() {
            if i < k {
                prog.push(Phase::Invoke(
                    Job::on(f.handle()).direct(f.input_words()),
                ));
            } else {
                prog.push(Phase::Compute(f.sw_cycles));
            }
        }
        prog
    }

    /// All-FPGA program using the chaining mechanism (one invocation).
    pub fn chained_program(&self) -> Option<Program> {
        if !self.chainable || self.functions.is_empty() {
            return None;
        }
        let mut chain = Chain::of(self.functions[0].handle());
        for f in &self.functions[1..] {
            chain = chain.then(f.handle());
        }
        let words = self.functions[0].input_words();
        Some(Program::new().invoke(Job::chained(chain).direct(words)))
    }

    /// Total software-only cycles (partition 0 baseline).
    pub fn sw_total_cycles(&self) -> u64 {
        self.functions.iter().map(|f| f.sw_cycles).sum()
    }
}

/// GSM LPC front-end: three functions (§6.5; 3-flit payloads => 8 words).
/// `hwa_id` values refer to the Fig. 9 scenario's channel layout — see
/// `sim::experiments::fig9`.
pub fn gsm_app(hwa_base: u8) -> App {
    App {
        name: "GSM",
        functions: vec![
            AppFunction {
                name: "autocorrelation",
                sw_cycles: 36_000,
                hwa_id: hwa_base,
                in_words: 8,
                out_words: 8,
            },
            AppFunction {
                name: "reflection_coeff",
                sw_cycles: 21_000,
                hwa_id: hwa_base + 1,
                in_words: 8,
                out_words: 8,
            },
            AppFunction {
                name: "lar_quantize",
                sw_cycles: 9_000,
                hwa_id: hwa_base + 2,
                in_words: 8,
                out_words: 8,
            },
        ],
        chainable: false,
    }
}

/// JPEG decoder: five functions (§6.5/§6.6; 18-flit payloads ~ 64+ words).
/// The last four map to the izigzag/iquantize/idct/shiftbound HWAs and are
/// chainable; entropy decode is a fifth (non-Table 3) HWA modelled after a
/// Huffman-decode HLS kernel.
pub fn jpeg_app(hwa_base: u8) -> App {
    App {
        name: "JPEG",
        functions: vec![
            AppFunction {
                name: "entropy_decode",
                sw_cycles: 42_000,
                hwa_id: hwa_base,
                in_words: 64,
                out_words: 64,
            },
            AppFunction {
                name: "izigzag",
                sw_cycles: 6_000,
                hwa_id: hwa_base + 1,
                in_words: 64,
                out_words: 64,
            },
            AppFunction {
                name: "iquantize",
                sw_cycles: 14_000,
                hwa_id: hwa_base + 2,
                in_words: 64,
                out_words: 64,
            },
            AppFunction {
                name: "idct",
                sw_cycles: 95_000,
                hwa_id: hwa_base + 3,
                in_words: 64,
                out_words: 64,
            },
            AppFunction {
                name: "shiftbound",
                sw_cycles: 10_000,
                hwa_id: hwa_base + 4,
                in_words: 64,
                out_words: 64,
            },
        ],
        // Chaining applies to the four-JPEG-HWA group, not the whole app
        // (five hops would exceed the depth field anyway); see fig10.
        chainable: false,
    }
}

/// The §6.6 chaining workload: just the four JPEG-chain HWAs (channel
/// indices 0..3 in the fig10 scenario, group indexes likewise).
pub fn jpeg_chain_app() -> App {
    App {
        name: "JPEG-chain",
        functions: vec![
            AppFunction {
                name: "izigzag",
                sw_cycles: 6_000,
                hwa_id: 0,
                in_words: 64,
                out_words: 64,
            },
            AppFunction {
                name: "iquantize",
                sw_cycles: 14_000,
                hwa_id: 1,
                in_words: 64,
                out_words: 64,
            },
            AppFunction {
                name: "idct",
                sw_cycles: 95_000,
                hwa_id: 2,
                in_words: 64,
                out_words: 64,
            },
            AppFunction {
                name: "shiftbound",
                sw_cycles: 10_000,
                hwa_id: 3,
                in_words: 64,
                out_words: 64,
            },
        ],
        chainable: true,
    }
}

/// Program that chains only the first `depth + 1` functions, running the
/// rest as separate invocations — the Fig. 10 sweep (chaining depth 0-3),
/// with the first stage fed `block` as input.
pub fn jpeg_chain_block_program(depth: u8, block: Vec<u32>) -> Program {
    let app = jpeg_chain_app();
    assert!((depth as usize) < app.functions.len());
    let mut chain = Chain::of(app.functions[0].handle());
    for f in &app.functions[1..=depth as usize] {
        chain = chain.then(f.handle());
    }
    let mut prog = Program::new().invoke(Job::chained(chain).direct(block));
    // Remaining functions invoked individually.
    for f in app.functions.iter().skip(depth as usize + 1) {
        prog.push(Phase::Invoke(Job::on(f.handle()).direct(f.input_words())));
    }
    prog
}

/// [`jpeg_chain_block_program`] with the default synthetic input.
pub fn jpeg_chain_depth_program(depth: u8) -> Program {
    let input = jpeg_chain_app().functions[0].input_words();
    jpeg_chain_block_program(depth, input)
}

/// HWA spec for an app function that has no Table 3 entry (JPEG entropy
/// decode and the GSM stages) — Huffman/LPC-class HLS kernels.
fn custom_spec(
    name: &'static str,
    exec: u64,
    words: usize,
    fmax: f64,
) -> HwaSpec {
    HwaSpec {
        name,
        exec_cycles: exec,
        in_words: words,
        out_words: words,
        fmax_mhz: fmax,
        resources: Resources::new(5000, 2, 8, 4000),
        artifact: None,
    }
}

/// HWA specs for an app's functions, `hwa_id` = function index (the
/// Fig. 9 scenario layout used by `sweep`'s `app_partition` workload).
pub fn app_specs(app: &App) -> Vec<HwaSpec> {
    app.functions
        .iter()
        .map(|f| match f.name {
            "izigzag" => spec_by_name("izigzag").unwrap(),
            "iquantize" => spec_by_name("iquantize").unwrap(),
            "idct" => spec_by_name("idct").unwrap(),
            "shiftbound" => spec_by_name("shiftbound").unwrap(),
            "autocorrelation" => custom_spec("autocorr", 180, 8, 260.0),
            "reflection_coeff" => custom_spec("reflect", 140, 8, 260.0),
            "lar_quantize" => custom_spec("larq", 60, 8, 300.0),
            "entropy_decode" => custom_spec("entropy", 500, 64, 250.0),
            other => panic!("no spec mapping for {other}"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_k_offloads_prefix() {
        let app = gsm_app(0);
        let p1 = app.partition_program(1);
        assert!(matches!(p1.phases()[0], Phase::Invoke(_)));
        assert!(matches!(p1.phases()[1], Phase::Compute(_)));
        assert!(matches!(p1.phases()[2], Phase::Compute(_)));
        let p3 = app.partition_program(3);
        assert!(p3
            .phases()
            .iter()
            .all(|s| matches!(s, Phase::Invoke(_))));
    }

    #[test]
    fn sw_total_is_sum() {
        let app = gsm_app(0);
        assert_eq!(app.sw_total_cycles(), 36_000 + 21_000 + 9_000);
    }

    #[test]
    fn chain_depth_programs_shrink() {
        // depth 3: one invocation; depth 0: four invocations.
        assert_eq!(jpeg_chain_depth_program(3).len(), 1);
        assert_eq!(jpeg_chain_depth_program(0).len(), 4);
        assert_eq!(jpeg_chain_depth_program(1).len(), 3);
    }

    #[test]
    fn chain_depth_program_targets_valid_chains() {
        for depth in 0..=3u8 {
            let prog = jpeg_chain_depth_program(depth);
            let Phase::Invoke(job) = &prog.phases()[0] else {
                panic!("first phase is the chained invocation");
            };
            assert_eq!(job.target().depth(), depth);
            assert!(job.target().validate().is_ok());
        }
    }

    #[test]
    fn chained_program_exists_for_chain_app() {
        assert!(jpeg_chain_app().chained_program().is_some());
        assert!(gsm_app(0).chained_program().is_none());
    }

    #[test]
    fn jpeg_has_five_functions_gsm_three() {
        assert_eq!(jpeg_app(0).functions.len(), 5);
        assert_eq!(gsm_app(0).functions.len(), 3);
    }
}
