//! The closed-loop search engine: exhaustive for small spaces, seeded
//! hill-climb with random restarts for large ones.
//!
//! Determinism contract: for a fixed spec and seed the search visits
//! the same candidates, in the same order, on any `--threads` — the
//! batch evaluator ([`crate::sweep::SweepRunner::run_each`]) returns
//! results in input order, and every search decision (start picks,
//! moves, restarts) depends only on already-collected deterministic
//! results. `BENCH_autotune.json` is therefore byte-identical across
//! runs and thread counts.

use std::collections::BTreeMap;

use crate::sweep::{RunStats, ScenarioSpec, SweepRunner};
use crate::util::rng::Pcg32;

use super::space::{AutotuneSpec, Candidate, Infeasible};
use super::{AutotuneError, Objective};

/// Pcg32 stream selector for the search RNG, so autotune draws never
/// collide with workload/fault streams even under a shared seed.
const SEARCH_STREAM: u64 = 0x4155_544f_5455_4e45; // "AUTOTUNE"

/// Random start-probe attempts per restart before falling back to a
/// deterministic linear scan for the first feasible unevaluated id.
const START_PROBES: usize = 128;

/// One simulated candidate with its score (or simulation error).
#[derive(Debug, Clone)]
pub struct EvaluatedCandidate {
    pub candidate: Candidate,
    /// `None` when the simulation failed (see `error`).
    pub score: Option<f64>,
    pub stats: Option<RunStats>,
    pub error: Option<String>,
}

/// The best evaluated candidate.
#[derive(Debug, Clone)]
pub struct Winner {
    pub id: usize,
    pub name: String,
    pub score: f64,
    pub luts: u32,
    pub spec: ScenarioSpec,
    pub stats: RunStats,
}

impl Winner {
    /// The ready-to-run floorplan string this plan lowers to (the
    /// explicit plan, or the legacy single-FPGA lowering).
    pub fn floorplan_text(&self) -> String {
        match &self.spec.floorplan {
            Some(text) => text.clone(),
            None => self
                .spec
                .plan()
                .map(|p| p.to_spec_string())
                .unwrap_or_default(),
        }
    }
}

/// The spec's fixed keys run as-is — with the shipped specs, the legacy
/// single-FPGA default plan the winner must beat.
#[derive(Debug, Clone)]
pub struct Baseline {
    pub name: String,
    pub score: Option<f64>,
    pub stats: Option<RunStats>,
    pub error: Option<String>,
    pub luts: u32,
}

/// Everything a search produced; `report` renders it as JSON/text.
#[derive(Debug, Clone)]
pub struct AutotuneOutcome {
    pub name: String,
    pub objective: Objective,
    /// `"exhaustive"` or `"hill_climb"`.
    pub strategy: &'static str,
    pub budget: usize,
    pub seed: u64,
    pub threads: usize,
    pub space_size: usize,
    /// Distinct candidates rejected by the feasibility filter, bucketed
    /// by [`Infeasible::kind`]. For exhaustive searches
    /// `evaluated.len() + pruned_total() == space_size`.
    pub pruned_resource: usize,
    pub pruned_fmax: usize,
    pub pruned_invalid: usize,
    /// Every simulated candidate, in candidate-id order.
    pub evaluated: Vec<EvaluatedCandidate>,
    pub baseline: Option<Baseline>,
    pub winner: Winner,
}

impl AutotuneOutcome {
    pub fn pruned_total(&self) -> usize {
        self.pruned_resource + self.pruned_fmax + self.pruned_invalid
    }
}

/// Search driver. Configure with the builder methods, then
/// [`Self::run`]. Objective/budget/seed default to the spec's own
/// values; the CLI overrides them from flags.
#[derive(Debug, Clone, Default)]
pub struct Autotuner {
    objective: Option<Objective>,
    budget: Option<usize>,
    seed: Option<u64>,
    /// 0 = every host core.
    threads: usize,
}

impl Autotuner {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = Some(objective);
        self
    }

    pub fn budget(mut self, budget: usize) -> Self {
        self.budget = Some(budget);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Run the search over `space`. Returns a typed error when the
    /// space is empty, the budget is zero, the objective does not fit
    /// the workload, nothing is feasible, or nothing simulates.
    pub fn run(
        &self,
        space: &AutotuneSpec,
    ) -> Result<AutotuneOutcome, AutotuneError> {
        let objective = self.objective.unwrap_or(space.objective);
        let budget = self.budget.unwrap_or(space.budget);
        let seed = self.seed.unwrap_or(space.seed);
        let size = space.space_size();
        if size == 0 {
            return Err(AutotuneError::EmptySpace);
        }
        if budget == 0 {
            return Err(AutotuneError::ZeroBudget);
        }
        if objective == Objective::MinSloViolations {
            let all_serving = space
                .get("workload.kind")
                .map(|vs| vs.iter().all(|v| v == "serving"))
                .unwrap_or(false);
            if !all_serving {
                return Err(AutotuneError::ObjectiveNeedsServing {
                    objective: objective.name(),
                });
            }
        }
        let runner = if self.threads == 0 {
            SweepRunner::new()
        } else {
            SweepRunner::with_threads(self.threads)
        };
        let threads = runner.threads();
        let mut st = SearchState {
            space,
            objective,
            runner,
            checked: BTreeMap::new(),
            evaluated: BTreeMap::new(),
        };

        let strategy = if size <= budget {
            // The budget covers the whole space: evaluate every
            // feasible candidate, so evaluated + pruned == size.
            for id in 0..size {
                let _ = st.check(id);
            }
            let feasible: Vec<usize> = (0..size)
                .filter(|id| matches!(st.checked.get(id), Some(Ok(_))))
                .collect();
            st.eval_batch(&feasible);
            "exhaustive"
        } else {
            self.hill_climb(&mut st, size, budget, seed);
            "hill_climb"
        };

        if st.evaluated.is_empty() {
            let (resource, fmax, invalid) = st.pruned_counts();
            return Err(AutotuneError::NoFeasibleCandidate {
                resource,
                fmax,
                invalid,
            });
        }

        let winner = match st.best() {
            Some(w) => w,
            None => {
                let first_error = st
                    .evaluated
                    .values()
                    .find_map(|e| e.error.clone())
                    .unwrap_or_else(|| "no candidate scored".to_string());
                return Err(AutotuneError::AllEvaluationsFailed {
                    first_error,
                });
            }
        };
        let baseline = st.baseline();
        let (pruned_resource, pruned_fmax, pruned_invalid) =
            st.pruned_counts();
        Ok(AutotuneOutcome {
            name: space.name.clone(),
            objective,
            strategy,
            budget,
            seed,
            threads,
            space_size: size,
            pruned_resource,
            pruned_fmax,
            pruned_invalid,
            evaluated: st.evaluated.into_values().collect(),
            baseline,
            winner,
        })
    }

    /// Seeded hill-climb with restarts. Each round: pick a feasible
    /// unevaluated start (random probes, then a deterministic scan),
    /// evaluate it, then repeatedly batch-evaluate all feasible
    /// unevaluated one-axis neighbors and move to the best if it
    /// strictly improves; otherwise restart. Stops when the budget is
    /// spent or the space is exhausted.
    fn hill_climb(
        &self,
        st: &mut SearchState<'_>,
        size: usize,
        budget: usize,
        seed: u64,
    ) {
        let mut rng = Pcg32::new(seed, SEARCH_STREAM);
        while st.evaluated.len() < budget {
            let start = match st.pick_start(&mut rng, size) {
                Some(id) => id,
                None => return, // space exhausted
            };
            st.eval_batch(&[start]);
            let mut cur = start;
            loop {
                if st.evaluated.len() >= budget {
                    return;
                }
                // A failed simulation has no score to climb from.
                let cur_score = match st.score_of(cur) {
                    Some(s) => s,
                    None => break,
                };
                let mut neigh: Vec<usize> = Vec::new();
                for id in st.space.neighbors(cur) {
                    if !st.evaluated.contains_key(&id)
                        && st.check(id).is_ok()
                    {
                        neigh.push(id);
                    }
                }
                neigh.truncate(budget - st.evaluated.len());
                if neigh.is_empty() {
                    break;
                }
                st.eval_batch(&neigh);
                let best = neigh
                    .iter()
                    .filter_map(|&id| st.score_of(id).map(|s| (id, s)))
                    .reduce(|(bi, bs), (id, s)| {
                        if st.objective.better(s, bs) {
                            (id, s)
                        } else {
                            (bi, bs) // ties keep the earlier id
                        }
                    });
                match best {
                    Some((id, s)) if st.objective.better(s, cur_score) => {
                        cur = id;
                    }
                    _ => break, // local optimum: restart
                }
            }
        }
    }
}

/// Mutable search bookkeeping: memoized feasibility checks and
/// evaluations, plus the shared scenario runner.
struct SearchState<'a> {
    space: &'a AutotuneSpec,
    objective: Objective,
    runner: SweepRunner,
    /// Every candidate id whose feasibility has been decided.
    checked: BTreeMap<usize, Result<Candidate, Infeasible>>,
    /// Every simulated candidate, keyed (and thus ordered) by id.
    evaluated: BTreeMap<usize, EvaluatedCandidate>,
}

impl SearchState<'_> {
    /// Memoized feasibility check.
    fn check(&mut self, id: usize) -> Result<Candidate, Infeasible> {
        if let Some(r) = self.checked.get(&id) {
            return r.clone();
        }
        let r = self.space.candidate(id);
        self.checked.insert(id, r.clone());
        r
    }

    /// Distinct pruned candidates encountered so far, bucketed as
    /// (resource, fmax, invalid).
    fn pruned_counts(&self) -> (usize, usize, usize) {
        let mut counts = (0, 0, 0);
        for r in self.checked.values() {
            match r {
                Err(Infeasible::Resource { .. }) => counts.0 += 1,
                Err(Infeasible::Fmax { .. }) => counts.1 += 1,
                Err(Infeasible::Invalid { .. }) => counts.2 += 1,
                Ok(_) => {}
            }
        }
        counts
    }

    /// Simulate `ids` (all pre-checked feasible) concurrently and
    /// record their scores. Input order in, input order out.
    fn eval_batch(&mut self, ids: &[usize]) {
        if ids.is_empty() {
            return;
        }
        let cands: Vec<Candidate> = ids
            .iter()
            .map(|&id| {
                self.check(id).expect("eval_batch takes feasible ids only")
            })
            .collect();
        let specs: Vec<ScenarioSpec> =
            cands.iter().map(|c| c.spec.clone()).collect();
        let results = self.runner.run_each(&specs);
        for (cand, result) in cands.into_iter().zip(results) {
            let id = cand.id;
            let rec = match result {
                Ok(stats) => {
                    let score = self.objective.score(&stats, cand.luts);
                    EvaluatedCandidate {
                        candidate: cand,
                        score: Some(score),
                        stats: Some(stats),
                        error: None,
                    }
                }
                Err(e) => EvaluatedCandidate {
                    candidate: cand,
                    score: None,
                    stats: None,
                    error: Some(e),
                },
            };
            self.evaluated.insert(id, rec);
        }
    }

    fn score_of(&self, id: usize) -> Option<f64> {
        self.evaluated.get(&id).and_then(|e| e.score)
    }

    /// A feasible, not-yet-evaluated start: bounded random probes for
    /// spread, then a deterministic linear scan so the search never
    /// stalls (and infeasible-everything spaces get fully classified).
    fn pick_start(&mut self, rng: &mut Pcg32, size: usize) -> Option<usize> {
        for _ in 0..START_PROBES {
            let id = draw(rng, size);
            if !self.evaluated.contains_key(&id) && self.check(id).is_ok() {
                return Some(id);
            }
        }
        (0..size)
            .find(|&id| {
                !self.evaluated.contains_key(&id) && self.check(id).is_ok()
            })
    }

    /// Best evaluated candidate: objective order, ties to the lowest id
    /// (BTreeMap iteration is id order, and `better` is strict).
    fn best(&self) -> Option<Winner> {
        let mut best: Option<(&EvaluatedCandidate, f64)> = None;
        for rec in self.evaluated.values() {
            let Some(score) = rec.score else { continue };
            match best {
                Some((_, bs)) if !self.objective.better(score, bs) => {}
                _ => best = Some((rec, score)),
            }
        }
        best.map(|(rec, score)| Winner {
            id: rec.candidate.id,
            name: rec.candidate.name.clone(),
            score,
            luts: rec.candidate.luts,
            spec: rec.candidate.spec.clone(),
            stats: rec.stats.clone().expect("scored candidates have stats"),
        })
    }

    /// Run the spec's fixed keys as the comparison baseline. `None`
    /// when the fixed keys alone don't describe a runnable scenario
    /// (then there is nothing meaningful to compare against).
    fn baseline(&self) -> Option<Baseline> {
        let map = self.space.base_map();
        let name = format!("{}[baseline]", self.space.name);
        let spec = match ScenarioSpec::from_map(&name, &map) {
            Ok(spec) => spec,
            Err(_) => return None,
        };
        let luts = AutotuneSpec::scenario_luts(&spec).unwrap_or(0);
        let mut results = self.runner.run_each(std::slice::from_ref(&spec));
        match results.pop().expect("one spec in, one result out") {
            Ok(stats) => Some(Baseline {
                name,
                score: Some(self.objective.score(&stats, luts)),
                stats: Some(stats),
                error: None,
                luts,
            }),
            Err(e) => Some(Baseline {
                name,
                score: None,
                stats: None,
                error: Some(e),
                luts,
            }),
        }
    }
}

/// Uniform draw in `0..size` (sizes past `u32` fall back to a modulo
/// draw; any bias at that scale is irrelevant to restart placement).
fn draw(rng: &mut Pcg32, size: usize) -> usize {
    if size <= u32::MAX as usize {
        rng.below(size as u32) as usize
    } else {
        (rng.next_u64() % size as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_space() -> AutotuneSpec {
        AutotuneSpec::new("qs")
            .axis("system.hwas", &["izigzag*2", "dfdiv*2"])
            .set("workload.kind", "openloop")
            .set("workload.rate_per_us", "1")
            .set("workload.warmup_us", "2")
            .set("workload.window_us", "10")
    }

    #[test]
    fn exhaustive_search_picks_the_known_best() {
        // izigzag runs 1 cycle @400 MHz; dfdiv 1200 cycles @250 MHz. The
        // p99 winner is never in doubt.
        let space = quick_space();
        let out = Autotuner::new()
            .threads(1)
            .run(&space)
            .expect("search succeeds");
        assert_eq!(out.strategy, "exhaustive");
        assert_eq!(out.space_size, 2);
        assert_eq!(out.evaluated.len() + out.pruned_total(), out.space_size);
        assert_eq!(out.winner.name, "qs[hwas=izigzag*2]");
        let base = out.baseline.expect("fixed keys are runnable");
        let bscore = base.score.expect("baseline simulates");
        assert!(
            out.winner.score <= bscore,
            "winner {} must not lose to the default plan {}",
            out.winner.score,
            bscore
        );
    }

    #[test]
    fn search_is_deterministic_across_thread_counts() {
        let space = quick_space();
        let a = Autotuner::new().threads(1).run(&space).unwrap();
        let b = Autotuner::new().threads(4).run(&space).unwrap();
        assert_eq!(a.winner.id, b.winner.id);
        assert_eq!(a.winner.score, b.winner.score);
        let ids = |o: &AutotuneOutcome| {
            o.evaluated
                .iter()
                .map(|e| e.candidate.id)
                .collect::<Vec<_>>()
        };
        assert_eq!(ids(&a), ids(&b));
    }

    #[test]
    fn hill_climb_respects_the_budget_and_seed() {
        // 3 x 3 x 2 = 18 candidates, budget 5 -> hill-climb.
        let space = AutotuneSpec::new("hc")
            .axis("system.hwas", &["izigzag*2", "izigzag*4", "dfdiv*2"])
            .axis("system.task_buffers", &["1", "2", "4"])
            .axis("system.ps_group", &["2", "4"])
            .set("workload.kind", "openloop")
            .set("workload.rate_per_us", "1")
            .set("workload.warmup_us", "2")
            .set("workload.window_us", "10")
            .budget(5)
            .seed(11);
        let a = Autotuner::new().threads(1).run(&space).unwrap();
        let b = Autotuner::new().threads(3).run(&space).unwrap();
        assert_eq!(a.strategy, "hill_climb");
        assert!(a.evaluated.len() <= 5);
        assert!(!a.evaluated.is_empty());
        let ids = |o: &AutotuneOutcome| {
            o.evaluated
                .iter()
                .map(|e| e.candidate.id)
                .collect::<Vec<_>>()
        };
        assert_eq!(ids(&a), ids(&b), "same seed, any thread count");
        assert_eq!(a.winner.id, b.winner.id);
    }

    #[test]
    fn infeasible_everything_is_a_typed_error() {
        let space = AutotuneSpec::new("bad")
            .axis("system.hwas", &["prime*3", "prime*4"])
            .set("workload.kind", "openloop")
            .set("workload.rate_per_us", "1");
        match Autotuner::new().threads(1).run(&space) {
            Err(AutotuneError::NoFeasibleCandidate {
                resource, fmax, invalid,
            }) => {
                assert_eq!(resource, 2);
                assert_eq!((fmax, invalid), (0, 0));
            }
            other => panic!("expected NoFeasibleCandidate, got {other:?}"),
        }
    }

    #[test]
    fn slo_objective_requires_serving_workloads() {
        let space = quick_space();
        match Autotuner::new()
            .objective(Objective::MinSloViolations)
            .run(&space)
        {
            Err(AutotuneError::ObjectiveNeedsServing { .. }) => {}
            other => panic!("expected ObjectiveNeedsServing, got {other:?}"),
        }
    }
}
