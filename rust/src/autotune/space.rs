//! The autotuner's search space: spec parsing, candidate enumeration,
//! and the model-based feasibility pre-filter.
//!
//! An [`AutotuneSpec`] is the flat `section.key = value` grid a
//! `SweepSpec` uses — every multi-valued key is a search axis — plus an
//! `[autotune]` section (`objective`, `budget`, `seed`). Candidates are
//! addressed by a dense id in `0..space_size()`: a mixed-radix number
//! over the axes in sorted-key order with the last axis fastest, the
//! exact order `SweepSpec::expand` enumerates, so candidate ids line up
//! with sweep-report rows for the same grid.

use std::collections::BTreeMap;

use crate::sweep::spec::{known_spec_key, split_list};
use crate::sweep::ScenarioSpec;
use crate::synth::fabric_fmax_mhz;
use crate::synth::resource::inventory_cost;
use crate::util::config_text::ConfigText;

use super::Objective;

/// Modeled iface fmax comparisons tolerate float dust so `iface_mhz =
/// <the modeled fmax itself>` counts as feasible.
const FMAX_EPS_MHZ: f64 = 1e-9;

/// Why a candidate was pruned before simulation. Ordered by the ladder
/// the filter walks: syntax/shape first, then per-fabric resources,
/// then timing closure.
#[derive(Debug, Clone, PartialEq)]
pub enum Infeasible {
    /// The candidate's key/value combination does not parse or lower to
    /// a buildable system (bad floorplan semantics, zero buffers, ...).
    Invalid { reason: String },
    /// Fabric `fabric`'s inventory (interface + cores) exceeds the
    /// device's LUT or BRAM budget.
    Resource { fabric: usize, luts: u32, brams: u32 },
    /// Fabric `fabric` asks for `iface_mhz` but the delay model caps
    /// its PR/PS strategy at `fmax_mhz`.
    Fmax {
        fabric: usize,
        iface_mhz: f64,
        fmax_mhz: f64,
    },
}

impl Infeasible {
    /// Stable bucket name used in reports and pruned-count accounting.
    pub fn kind(&self) -> &'static str {
        match self {
            Infeasible::Invalid { .. } => "invalid",
            Infeasible::Resource { .. } => "resource",
            Infeasible::Fmax { .. } => "fmax",
        }
    }
}

impl std::fmt::Display for Infeasible {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Infeasible::Invalid { reason } => write!(f, "invalid: {reason}"),
            Infeasible::Resource {
                fabric,
                luts,
                brams,
            } => write!(
                f,
                "fabric F{fabric} inventory ({luts} LUTs, {brams} BRAMs) \
                 exceeds the device budget"
            ),
            Infeasible::Fmax {
                fabric,
                iface_mhz,
                fmax_mhz,
            } => write!(
                f,
                "fabric F{fabric} wants {iface_mhz:.0} MHz but the delay \
                 model caps this strategy at {fmax_mhz:.1} MHz"
            ),
        }
    }
}

/// A candidate that survived the feasibility filter: a runnable
/// scenario plus the bookkeeping the scorer and report need.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Dense id in `0..space_size()` (mixed-radix axis indices).
    pub id: usize,
    /// `spec_name[axis=value,...]`, matching `SweepSpec::expand` naming.
    pub name: String,
    /// The axis choices that define this candidate, `(full_key, value)`
    /// in sorted-key order.
    pub axes: Vec<(String, String)>,
    /// The runnable scenario (validated end to end).
    pub spec: ScenarioSpec,
    /// Total inventory LUT cost across every fabric — the denominator
    /// for [`Objective::MaxThroughputPerLut`].
    pub luts: u32,
}

/// The declarative search problem: a value grid, an objective, and the
/// evaluation budget/seed. See the module docs for the id ordering.
#[derive(Debug, Clone)]
pub struct AutotuneSpec {
    pub name: String,
    /// Report path override; [`Self::output_path`] falls back to
    /// `BENCH_<name>.json`.
    pub output: Option<String>,
    pub objective: Objective,
    /// Maximum number of candidates to *simulate* (pruning is free).
    pub budget: usize,
    /// Seed for the hill-climb restarts; exhaustive searches ignore it.
    pub seed: u64,
    values: BTreeMap<String, Vec<String>>,
}

impl AutotuneSpec {
    pub fn new(name: &str) -> Self {
        AutotuneSpec {
            name: name.to_string(),
            output: None,
            objective: Objective::MinP99,
            budget: 64,
            seed: 7,
            values: BTreeMap::new(),
        }
    }

    /// Fix `key` to a single value (not a search axis).
    pub fn set(self, key: &str, value: &str) -> Self {
        self.axis(key, &[value])
    }

    /// Add `key` as a search axis over `vals`.
    pub fn axis(mut self, key: &str, vals: &[&str]) -> Self {
        self.values.insert(
            key.to_string(),
            vals.iter().map(|v| v.to_string()).collect(),
        );
        self
    }

    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    pub fn budget(mut self, budget: usize) -> Self {
        self.budget = budget;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Does this config text describe an autotune spec (any
    /// `[autotune]` key)? The `topology` verb and the shipped-config
    /// test use this to route files to the right parser.
    pub fn is_autotune_text(text: &str) -> bool {
        match ConfigText::parse(text) {
            Ok(cfg) => cfg.keys().any(|k| k.starts_with("autotune.")),
            Err(_) => false,
        }
    }

    /// Parse the TOML-subset format: top-level `name`/`output`, an
    /// `[autotune]` section, and sweep-style `section.key` grids for
    /// everything else. Unknown keys are errors, same as sweeps.
    pub fn parse_toml(text: &str) -> Result<Self, String> {
        let cfg = ConfigText::parse(text)?;
        let mut spec = AutotuneSpec::new("autotune");
        for key in cfg.keys() {
            let raw = cfg.get(key).unwrap_or("");
            match key {
                "name" => spec.name = raw.to_string(),
                "output" => spec.output = Some(raw.to_string()),
                "autotune.objective" => {
                    spec.objective = Objective::parse(raw)?;
                }
                "autotune.budget" => {
                    spec.budget = raw
                        .parse()
                        .map_err(|_| format!("autotune.budget: {raw:?}"))?;
                }
                "autotune.seed" => {
                    spec.seed = raw
                        .parse()
                        .map_err(|_| format!("autotune.seed: {raw:?}"))?;
                }
                k if k.starts_with("autotune.") => {
                    return Err(format!(
                        "unknown autotune key {k:?} \
                         (objective, budget, seed)"
                    ));
                }
                k => {
                    if !known_spec_key(k) {
                        return Err(format!("unknown spec key {k:?}"));
                    }
                    let vals = split_list(raw);
                    if vals.is_empty() {
                        return Err(format!("{k}: empty value list"));
                    }
                    spec.values.insert(k.to_string(), vals);
                }
            }
        }
        Ok(spec)
    }

    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse_toml(&text)
    }

    /// Report path: the spec's `output` or `BENCH_<name>.json`.
    pub fn output_path(&self) -> String {
        self.output
            .clone()
            .unwrap_or_else(|| format!("BENCH_{}.json", self.name))
    }

    /// The search axes (multi-valued keys) in sorted-key order.
    pub fn axes(&self) -> Vec<(&str, &[String])> {
        self.values
            .iter()
            .filter(|(_, v)| v.len() > 1)
            .map(|(k, v)| (k.as_str(), v.as_slice()))
            .collect()
    }

    /// The values for `key`, if set.
    pub fn get(&self, key: &str) -> Option<&[String]> {
        self.values.get(key).map(|v| v.as_slice())
    }

    /// Number of candidates (product of axis lengths; 1 for an all-fixed
    /// spec, 0 only if some value list is empty).
    pub fn space_size(&self) -> usize {
        self.values
            .values()
            .map(|v| v.len())
            .fold(1usize, |a, b| a.saturating_mul(b))
    }

    /// Decode `id` into per-axis indices (sorted-key order, last axis
    /// fastest — the `SweepSpec::expand` enumeration order).
    pub fn indices(&self, id: usize) -> Vec<usize> {
        let axes = self.axes();
        let mut idx = vec![0usize; axes.len()];
        let mut rem = id;
        for (d, (_, vals)) in axes.iter().enumerate().rev() {
            idx[d] = rem % vals.len();
            rem /= vals.len();
        }
        idx
    }

    /// Inverse of [`Self::indices`].
    pub fn id_of(&self, indices: &[usize]) -> usize {
        let axes = self.axes();
        let mut id = 0usize;
        for (d, (_, vals)) in axes.iter().enumerate() {
            id = id * vals.len() + indices[d];
        }
        id
    }

    /// All candidates one axis-step away from `id` (every axis, every
    /// alternative value), in deterministic (axis, value) order.
    pub fn neighbors(&self, id: usize) -> Vec<usize> {
        let axes = self.axes();
        let idx = self.indices(id);
        let mut out = Vec::new();
        for (d, (_, vals)) in axes.iter().enumerate() {
            for j in 0..vals.len() {
                if j == idx[d] {
                    continue;
                }
                let mut v = idx.clone();
                v[d] = j;
                out.push(self.id_of(&v));
            }
        }
        out
    }

    /// The flat spec map for candidate `id` (fixed keys + this
    /// candidate's axis choices).
    pub fn candidate_map(&self, id: usize) -> BTreeMap<String, String> {
        let idx = self.indices(id);
        let axis_pos: BTreeMap<&str, usize> = self
            .axes()
            .iter()
            .enumerate()
            .map(|(d, (k, _))| (*k, d))
            .collect();
        self.values
            .iter()
            .map(|(k, vals)| {
                let v = match axis_pos.get(k.as_str()) {
                    Some(&d) => vals[idx[d]].clone(),
                    None => vals[0].clone(),
                };
                (k.clone(), v)
            })
            .collect()
    }

    /// The axis choices for candidate `id`, `(full_key, value)`.
    pub fn axis_values(&self, id: usize) -> Vec<(String, String)> {
        let idx = self.indices(id);
        self.axes()
            .iter()
            .enumerate()
            .map(|(d, (k, vals))| (k.to_string(), vals[idx[d]].clone()))
            .collect()
    }

    /// `name[axis=value,...]` — the `SweepSpec::expand` naming scheme
    /// (axis keys shortened to their last `.` segment).
    pub fn candidate_name(&self, id: usize) -> String {
        let axes = self.axis_values(id);
        if axes.is_empty() {
            return self.name.clone();
        }
        let parts: Vec<String> = axes
            .iter()
            .map(|(k, v)| {
                let short = k.rsplit('.').next().unwrap_or(k.as_str());
                format!("{short}={v}")
            })
            .collect();
        format!("{}[{}]", self.name, parts.join(","))
    }

    /// The fixed (single-valued) keys only — the baseline scenario the
    /// report compares the winner against. With the shipped specs this
    /// is the legacy single-FPGA default plan.
    pub fn base_map(&self) -> BTreeMap<String, String> {
        self.values
            .iter()
            .filter(|(_, v)| v.len() == 1)
            .map(|(k, v)| (k.clone(), v[0].clone()))
            .collect()
    }

    /// Run candidate `id` through the feasibility ladder; `Ok` means it
    /// is worth simulator time. The ladder, in order:
    ///
    /// 1. parse + lower (`from_map_unvalidated`, `plan`, `fabric_specs`)
    ///    — failures are [`Infeasible::Invalid`];
    /// 2. per fabric, `inventory_cost` vs the candidate's
    ///    [`crate::synth::Device`] budget — [`Infeasible::Resource`];
    /// 3. per fabric, requested `iface_mhz` vs the modeled
    ///    [`fabric_fmax_mhz`] — [`Infeasible::Fmax`];
    /// 4. the full `system_config()` build — residual defects (MMU
    ///    reachability etc.) are [`Infeasible::Invalid`].
    pub fn candidate(&self, id: usize) -> Result<Candidate, Infeasible> {
        let map = self.candidate_map(id);
        let name = self.candidate_name(id);
        let invalid = |reason: String| Infeasible::Invalid { reason };
        let spec = ScenarioSpec::from_map_unvalidated(&name, &map)
            .map_err(invalid)?;
        let plan = spec.plan().map_err(invalid)?;
        let fabrics = spec.fabric_specs(&plan).map_err(invalid)?;
        let mut luts = 0u32;
        for (f, fs) in fabrics.iter().enumerate() {
            let cost = inventory_cost(
                fs.pr_group,
                fs.ps_group,
                &fs.specs,
                !fs.chain_groups.is_empty(),
            );
            luts = luts.saturating_add(cost.lut);
            if spec.device.exceeds(&cost) {
                return Err(Infeasible::Resource {
                    fabric: f,
                    luts: cost.lut,
                    brams: cost.bram,
                });
            }
            let fmax = fabric_fmax_mhz(fs.pr_group, fs.ps_group, fs.specs.len());
            if fs.iface_mhz > fmax + FMAX_EPS_MHZ {
                return Err(Infeasible::Fmax {
                    fabric: f,
                    iface_mhz: fs.iface_mhz,
                    fmax_mhz: fmax,
                });
            }
        }
        spec.system_config().map_err(invalid)?;
        Ok(Candidate {
            id,
            name,
            axes: self.axis_values(id),
            spec,
            luts,
        })
    }

    /// Total inventory LUT cost for an already-built scenario (used for
    /// the baseline row, which skips the candidate ladder).
    pub fn scenario_luts(spec: &ScenarioSpec) -> Result<u32, String> {
        let plan = spec.plan()?;
        let fabrics = spec.fabric_specs(&plan)?;
        let mut luts = 0u32;
        for fs in &fabrics {
            let cost = inventory_cost(
                fs.pr_group,
                fs.ps_group,
                &fs.specs,
                !fs.chain_groups.is_empty(),
            );
            luts = luts.saturating_add(cost.lut);
        }
        Ok(luts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_space() -> AutotuneSpec {
        AutotuneSpec::new("t")
            .axis("system.hwas", &["izigzag*2", "izigzag*4", "dfdiv*2"])
            .axis("system.task_buffers", &["1", "2"])
            .set("workload.kind", "openloop")
            .set("workload.rate_per_us", "2")
    }

    #[test]
    fn id_decode_matches_sweep_expand_order() {
        let s = small_space();
        assert_eq!(s.space_size(), 6);
        // Sorted axes: system.hwas (3 values), system.task_buffers (2).
        // Last axis fastest: id 0 -> (0,0), id 1 -> (0,1), id 2 -> (1,0).
        assert_eq!(s.indices(0), vec![0, 0]);
        assert_eq!(s.indices(1), vec![0, 1]);
        assert_eq!(s.indices(2), vec![1, 0]);
        assert_eq!(s.id_of(&[2, 1]), 5);
        assert_eq!(
            s.candidate_name(3),
            "t[hwas=izigzag*4,task_buffers=2]"
        );
        let map = s.candidate_map(5);
        assert_eq!(map["system.hwas"], "dfdiv*2");
        assert_eq!(map["system.task_buffers"], "2");
        assert_eq!(map["workload.kind"], "openloop");
    }

    #[test]
    fn neighbors_step_one_axis() {
        let s = small_space();
        let mut n = s.neighbors(0);
        n.sort_unstable();
        // From (0,0): hwas -> (1,0)=2, (2,0)=4; tbs -> (0,1)=1.
        assert_eq!(n, vec![1, 2, 4]);
    }

    #[test]
    fn feasibility_ladder_prunes_with_typed_reasons() {
        let s = AutotuneSpec::new("t")
            .axis("system.hwas", &["izigzag*4", "prime*3"])
            .axis("system.iface_mhz", &["300", "1000"])
            .set("workload.kind", "openloop")
            .set("workload.rate_per_us", "1");
        // id 0: izigzag*4 @ 300 MHz — feasible.
        let c = s.candidate(0).expect("feasible candidate");
        assert!(c.luts > 0);
        assert_eq!(c.name, "t[hwas=izigzag*4,iface_mhz=300]");
        // id 1: izigzag*4 @ 1000 MHz — fmax-pruned.
        match s.candidate(1) {
            Err(Infeasible::Fmax { fabric: 0, .. }) => {}
            other => panic!("expected fmax prune, got {other:?}"),
        }
        // id 2: prime*3 blows the 690T LUT budget — resource-pruned
        // (before the fmax check even runs).
        match s.candidate(2) {
            Err(Infeasible::Resource { fabric: 0, luts, .. }) => {
                assert!(luts > 433_200);
            }
            other => panic!("expected resource prune, got {other:?}"),
        }
        // A nonsense mix is Invalid, not a panic.
        let bad = AutotuneSpec::new("t").set("system.hwas", "nosuchhwa*2");
        match bad.candidate(0) {
            Err(Infeasible::Invalid { .. }) => {}
            other => panic!("expected invalid, got {other:?}"),
        }
    }

    #[test]
    fn toml_round_trip_and_detection() {
        let text = "\
name = smoke
output = BENCH_x.json

[autotune]
objective = p99
budget = 12
seed = 9

[system]
hwas = izigzag*2, izigzag*4

[workload]
kind = openloop
rate_per_us = 2
";
        assert!(AutotuneSpec::is_autotune_text(text));
        let s = AutotuneSpec::parse_toml(text).expect("parse");
        assert_eq!(s.name, "smoke");
        assert_eq!(s.output_path(), "BENCH_x.json");
        assert_eq!(s.objective, Objective::MinP99);
        assert_eq!((s.budget, s.seed), (12, 9));
        assert_eq!(s.space_size(), 2);
        // Sweep specs are not autotune specs.
        assert!(!AutotuneSpec::is_autotune_text(
            "name = x\n[system]\nhwas = izigzag*2\n"
        ));
        // Unknown keys in either namespace are errors.
        assert!(AutotuneSpec::parse_toml("[autotune]\nbudjet = 3\n").is_err());
        assert!(AutotuneSpec::parse_toml("[system]\nhwaz = a\n").is_err());
    }
}
