//! Rendering an [`AutotuneOutcome`]: the deterministic
//! `BENCH_autotune.json` artifact, the human-readable report, and the
//! ready-to-run `configs/`-style TOML fragment for the winning plan.
//!
//! Nothing here reads the clock or any other ambient state, so for a
//! fixed spec/seed the JSON is byte-identical across runs and thread
//! counts — the property the determinism tests pin.

use std::fmt::Write as _;

use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::search::AutotuneOutcome;

impl AutotuneOutcome {
    /// Percentage improvement of the winner over the baseline, in the
    /// objective's own direction (positive = winner better). `None`
    /// when there is no comparable baseline score.
    pub fn improvement_vs_baseline_pct(&self) -> Option<f64> {
        let base = self.baseline.as_ref()?.score?;
        if base == 0.0 || !base.is_finite() || !self.winner.score.is_finite()
        {
            return None;
        }
        let win = self.winner.score;
        Some(if self.objective.maximize() {
            (win - base) / base * 100.0
        } else {
            (base - win) / base * 100.0
        })
    }

    /// The winning plan as a `configs/`-style TOML spec, ready to drop
    /// into a file and run with `accnoc sweep`.
    pub fn winner_toml(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "# autotuned winner for {} (objective: {})",
            self.name,
            self.objective.name()
        );
        let _ = writeln!(out, "name = {}_tuned", self.name);
        let _ = writeln!(out, "output = BENCH_{}_tuned.json", self.name);
        let mut section = String::new();
        for (k, v) in self.winner.spec.to_map() {
            let (sec, key) = match k.split_once('.') {
                Some((s, rest)) => (s.to_string(), rest.to_string()),
                None => (String::new(), k.clone()),
            };
            if sec != section {
                let _ = writeln!(out, "\n[{sec}]");
                section = sec;
            }
            let _ = writeln!(out, "{key} = {v}");
        }
        out
    }

    /// The full machine-readable result (`BENCH_autotune.json` schema).
    pub fn to_json(&self) -> Json {
        let mut cands = Vec::with_capacity(self.evaluated.len());
        for rec in &self.evaluated {
            let c = &rec.candidate;
            let mut pairs: Vec<(String, Json)> = vec![
                ("id".to_string(), Json::Num(c.id as f64)),
                ("name".to_string(), Json::Str(c.name.clone())),
                (
                    "axes".to_string(),
                    Json::Obj(
                        c.axes
                            .iter()
                            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                            .collect(),
                    ),
                ),
                ("luts".to_string(), Json::Num(c.luts as f64)),
                (
                    "score".to_string(),
                    // Non-finite scores (no completions) also serialize
                    // as null via fmt_num; map them explicitly for
                    // clarity.
                    match rec.score {
                        Some(s) if s.is_finite() => Json::Num(s),
                        _ => Json::Null,
                    },
                ),
            ];
            if let Some(stats) = &rec.stats {
                pairs.push((
                    "p99_us".to_string(),
                    Json::Num(stats.latency.p99_us),
                ));
                pairs.push((
                    "completions_per_us".to_string(),
                    Json::Num(stats.completions_per_us),
                ));
                pairs.push((
                    "tasks_executed".to_string(),
                    Json::Num(stats.tasks_executed as f64),
                ));
            }
            if let Some(e) = &rec.error {
                pairs.push(("error".to_string(), Json::Str(e.clone())));
            }
            cands.push(Json::Obj(pairs));
        }

        let baseline = match &self.baseline {
            None => Json::Null,
            Some(b) => Json::obj(vec![
                ("name", Json::Str(b.name.clone())),
                (
                    "score",
                    match b.score {
                        Some(s) if s.is_finite() => Json::Num(s),
                        _ => Json::Null,
                    },
                ),
                ("luts", Json::Num(b.luts as f64)),
                (
                    "p99_us",
                    b.stats
                        .as_ref()
                        .map(|s| Json::Num(s.latency.p99_us))
                        .unwrap_or(Json::Null),
                ),
                (
                    "completions_per_us",
                    b.stats
                        .as_ref()
                        .map(|s| Json::Num(s.completions_per_us))
                        .unwrap_or(Json::Null),
                ),
                (
                    "error",
                    b.error
                        .as_ref()
                        .map(|e| Json::Str(e.clone()))
                        .unwrap_or(Json::Null),
                ),
            ]),
        };

        let winner = Json::obj(vec![
            ("id", Json::Num(self.winner.id as f64)),
            ("name", Json::Str(self.winner.name.clone())),
            ("score", Json::Num(self.winner.score)),
            ("luts", Json::Num(self.winner.luts as f64)),
            ("p99_us", Json::Num(self.winner.stats.latency.p99_us)),
            (
                "completions_per_us",
                Json::Num(self.winner.stats.completions_per_us),
            ),
            (
                "floorplan",
                Json::Str(self.winner.floorplan_text()),
            ),
            (
                "spec",
                Json::Obj(
                    self.winner
                        .spec
                        .to_map()
                        .into_iter()
                        .map(|(k, v)| (k, Json::Str(v)))
                        .collect(),
                ),
            ),
        ]);

        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("kind", Json::Str("autotune".to_string())),
            ("name", Json::Str(self.name.clone())),
            ("objective", Json::Str(self.objective.name().to_string())),
            ("strategy", Json::Str(self.strategy.to_string())),
            ("budget", Json::Num(self.budget as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("space_size", Json::Num(self.space_size as f64)),
            (
                "pruned",
                Json::obj(vec![
                    ("resource", Json::Num(self.pruned_resource as f64)),
                    ("fmax", Json::Num(self.pruned_fmax as f64)),
                    ("invalid", Json::Num(self.pruned_invalid as f64)),
                    ("total", Json::Num(self.pruned_total() as f64)),
                ]),
            ),
            ("evaluated", Json::Num(self.evaluated.len() as f64)),
            ("baseline", baseline),
            ("candidates", Json::Arr(cands)),
            ("winner", winner),
            (
                "winner_toml",
                Json::Str(self.winner_toml()),
            ),
            (
                "improvement_vs_baseline_pct",
                self.improvement_vs_baseline_pct()
                    .map(Json::Num)
                    .unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn render_json(&self) -> String {
        self.to_json().render()
    }

    pub fn write_json(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.render_json())
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    /// The human-readable search report the CLI prints.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "autotune {}: objective {} ({}), strategy {}",
            self.name,
            self.objective.name(),
            self.objective.describe(),
            self.strategy
        );
        let _ = writeln!(
            out,
            "space: {} candidate(s) -> {} pruned ({} resource, {} fmax, \
             {} invalid), {} evaluated (budget {}, seed {})",
            self.space_size,
            self.pruned_total(),
            self.pruned_resource,
            self.pruned_fmax,
            self.pruned_invalid,
            self.evaluated.len(),
            self.budget,
            self.seed
        );
        let mut t = Table::new(
            "evaluated candidates",
            &["id", "candidate", "score", "p99 us", "compl/us", "kLUT"],
        );
        for rec in &self.evaluated {
            let c = &rec.candidate;
            let (score, p99, thr) = match (&rec.score, &rec.stats) {
                (Some(s), Some(stats)) => (
                    num(*s, 3),
                    num(stats.latency.p99_us, 2),
                    num(stats.completions_per_us, 4),
                ),
                _ => (
                    format!(
                        "failed: {}",
                        rec.error.as_deref().unwrap_or("no score")
                    ),
                    "-".to_string(),
                    "-".to_string(),
                ),
            };
            t.row(&[
                c.id.to_string(),
                c.name.clone(),
                score,
                p99,
                thr,
                num(c.luts as f64 / 1000.0, 1),
            ]);
        }
        out.push_str(&t.render());
        let _ = writeln!(
            out,
            "winner: {} (score {})",
            self.winner.name,
            num(self.winner.score, 3)
        );
        let _ = writeln!(out, "  floorplan: {}", self.winner.floorplan_text());
        match &self.baseline {
            Some(b) => match b.score {
                Some(bs) => {
                    let _ = write!(
                        out,
                        "baseline (default plan): score {}",
                        num(bs, 3)
                    );
                    match self.improvement_vs_baseline_pct() {
                        Some(pct) => {
                            let _ = writeln!(
                                out,
                                " -> winner improves {}%",
                                num(pct, 1)
                            );
                        }
                        None => {
                            let _ = writeln!(out);
                        }
                    }
                }
                None => {
                    let _ = writeln!(
                        out,
                        "baseline (default plan): failed: {}",
                        b.error.as_deref().unwrap_or("no score")
                    );
                }
            },
            None => {
                let _ = writeln!(
                    out,
                    "baseline: none (fixed keys alone are not runnable)"
                );
            }
        }
        out.push_str("\n--- winning plan as a config fragment ---\n");
        out.push_str(&self.winner_toml());
        out
    }
}

#[cfg(test)]
mod tests {
    use crate::autotune::{Autotuner, AutotuneSpec};

    #[test]
    fn json_and_report_are_deterministic_and_complete() {
        let space = AutotuneSpec::new("rp")
            .axis("system.hwas", &["izigzag*2", "izigzag*4"])
            .set("workload.kind", "openloop")
            .set("workload.rate_per_us", "1")
            .set("workload.warmup_us", "2")
            .set("workload.window_us", "10");
        let run = || {
            Autotuner::new()
                .threads(1)
                .run(&space)
                .expect("search succeeds")
        };
        let a = run().render_json();
        let b = run().render_json();
        assert_eq!(a, b, "same spec/seed must render byte-identically");
        let parsed = crate::util::json::Json::parse(&a).expect("valid JSON");
        assert_eq!(
            parsed.get("kind").and_then(|v| v.as_str()),
            Some("autotune")
        );
        assert!(parsed.get("winner").is_some());
        assert!(parsed.get("pruned").is_some());

        let out = run();
        let report = out.report();
        assert!(report.contains("winner:"), "{report}");
        assert!(report.contains("floorplan:"), "{report}");
        let toml = out.winner_toml();
        assert!(toml.contains("[system]"), "{toml}");
        assert!(toml.contains("[workload]"), "{toml}");
        // The fragment must itself parse as a sweep spec.
        let reparsed = crate::sweep::SweepSpec::parse_toml(&toml)
            .expect("winner fragment is a valid spec");
        assert_eq!(reparsed.name, "rp_tuned");
    }
}
