//! Floorplan autotuner: closed-loop design-space search over the
//! topology grammar, gated by the calibrated hardware models.
//!
//! The paper picks its PR/PS strategies and channel counts by hand from
//! fmax/resource sweeps (Fig. 7, Table 4). This subsystem closes that
//! loop: given a workload and an [`Objective`], it searches the
//! floorplan space — fabric placement on the mesh, number of fabrics,
//! per-fabric accelerator inventory, PR/PS strategy, interface clock,
//! MMU assignment, device — and reports the best plan as a
//! ready-to-run floorplan string plus a `configs/`-style TOML fragment.
//!
//! Three pieces:
//!
//! * [`AutotuneSpec`] / [`Candidate`] (`space`) — the typed search
//!   space. A spec is the same flat `section.key` grid a
//!   [`crate::sweep::SweepSpec`] describes (every multi-valued key is a
//!   search dimension) plus an `[autotune]` section for the objective,
//!   evaluation budget and search seed. Each candidate passes a
//!   **feasibility pre-filter** before any simulation time is spent:
//!   its per-fabric inventory must fit the scenario's
//!   [`crate::synth::Device`] LUT/BRAM budget
//!   ([`crate::synth::resource::inventory_cost`]) and its `iface_mhz`
//!   must not exceed the modeled interface fmax for its PR/PS strategy
//!   ([`crate::synth::delay::fabric_fmax_mhz`]). Infeasible candidates
//!   are pruned with a typed [`Infeasible`] reason.
//! * [`Autotuner`] (`search`) — the evaluation engine. Surviving
//!   candidates lower to [`crate::sweep::ScenarioSpec`]s and run through
//!   the multi-threaded [`crate::sweep::SweepRunner`]; spaces that fit
//!   the budget are searched exhaustively, larger ones by seeded
//!   hill-climbing with restarts. Both are **bit-identical for a fixed
//!   seed across `--threads`**, the same discipline as every sweep.
//! * [`AutotuneOutcome`] (`report`) — the result: per-candidate scores,
//!   pruned-candidate accounting (exhaustive searches satisfy
//!   `evaluated + pruned == space size`), the winning plan, a baseline
//!   comparison against the spec's fixed keys at their defaults (the
//!   legacy single-FPGA plan, for the shipped specs), and the
//!   `BENCH_autotune.json` artifact.
//!
//! The `accnoc autotune <spec.toml>` CLI verb drives all three; see
//! `configs/autotune_smoke.toml` and docs/ARCHITECTURE.md §Autotuner.

pub mod report;
pub mod search;
pub mod space;

pub use search::{Autotuner, AutotuneOutcome, Baseline, EvaluatedCandidate, Winner};
pub use space::{AutotuneSpec, Candidate, Infeasible};

use crate::sweep::RunStats;

/// What the search optimizes. Scores are raw metrics (not normalized),
/// so the report stays interpretable; the direction lives in
/// [`Objective::maximize`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minimize p99 request latency (µs). Candidates that complete
    /// nothing score infinitely bad.
    MinP99,
    /// Maximize completed invocations per µs.
    MaxThroughput,
    /// Maximize completions/µs per 100 kLUTs of fabric inventory
    /// (interface + cores across every fabric) — throughput per unit of
    /// silicon spent.
    MaxThroughputPerLut,
    /// Minimize the total SLO violations across tenants (serving
    /// workloads only; [`Autotuner::run`] rejects other workloads with
    /// a typed error).
    MinSloViolations,
}

impl Objective {
    pub fn parse(text: &str) -> Result<Self, String> {
        match text.trim() {
            "p99" | "min_p99" => Ok(Objective::MinP99),
            "throughput" | "max_throughput" => Ok(Objective::MaxThroughput),
            "throughput_per_lut" => Ok(Objective::MaxThroughputPerLut),
            "slo" | "slo_violations" => Ok(Objective::MinSloViolations),
            other => Err(format!(
                "objective: {other:?} \
                 (p99|throughput|throughput_per_lut|slo_violations)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::MinP99 => "p99",
            Objective::MaxThroughput => "throughput",
            Objective::MaxThroughputPerLut => "throughput_per_lut",
            Objective::MinSloViolations => "slo_violations",
        }
    }

    /// Human description of the score column.
    pub fn describe(&self) -> &'static str {
        match self {
            Objective::MinP99 => "p99 latency in µs, lower is better",
            Objective::MaxThroughput => {
                "completions per µs, higher is better"
            }
            Objective::MaxThroughputPerLut => {
                "completions/µs per 100 kLUTs, higher is better"
            }
            Objective::MinSloViolations => {
                "SLO violations across tenants, lower is better"
            }
        }
    }

    pub fn maximize(&self) -> bool {
        matches!(
            self,
            Objective::MaxThroughput | Objective::MaxThroughputPerLut
        )
    }

    /// The candidate's score under this objective. `luts` is the total
    /// fabric-inventory cost the feasibility pass already computed.
    pub fn score(&self, stats: &RunStats, luts: u32) -> f64 {
        match self {
            Objective::MinP99 => {
                if stats.latency.count == 0 {
                    f64::INFINITY
                } else {
                    stats.latency.p99_us
                }
            }
            Objective::MaxThroughput => stats.completions_per_us,
            Objective::MaxThroughputPerLut => {
                stats.completions_per_us * 100_000.0 / luts.max(1) as f64
            }
            Objective::MinSloViolations => stats
                .tenants
                .iter()
                .map(|t| t.slo_violations)
                .sum::<u64>() as f64,
        }
    }

    /// Is score `a` strictly better than score `b`?
    pub fn better(&self, a: f64, b: f64) -> bool {
        if self.maximize() {
            a > b
        } else {
            a < b
        }
    }
}

/// Why a search could not produce a winner. Every variant is a typed,
/// printable rejection — an infeasible-everything space is an error,
/// never a panic.
#[derive(Debug, Clone, PartialEq)]
pub enum AutotuneError {
    /// The spec describes zero candidates (an empty value list).
    EmptySpace,
    /// A zero evaluation budget can never score anything.
    ZeroBudget,
    /// `slo_violations` needs per-tenant counters, which only serving
    /// workloads produce.
    ObjectiveNeedsServing { objective: &'static str },
    /// Every candidate the search examined failed the feasibility
    /// filter (counts by reason; for hill-climb searches these cover
    /// the candidates *encountered*, which is the whole space by the
    /// time this error is reached).
    NoFeasibleCandidate {
        resource: usize,
        fmax: usize,
        invalid: usize,
    },
    /// Every feasible candidate's simulation failed (e.g. missed its
    /// closed-loop deadline).
    AllEvaluationsFailed { first_error: String },
}

impl std::fmt::Display for AutotuneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AutotuneError::EmptySpace => {
                write!(f, "the search space has no candidates")
            }
            AutotuneError::ZeroBudget => {
                write!(f, "budget must be >= 1 evaluation")
            }
            AutotuneError::ObjectiveNeedsServing { objective } => write!(
                f,
                "objective {objective} requires workload.kind = serving \
                 for every candidate"
            ),
            AutotuneError::NoFeasibleCandidate {
                resource,
                fmax,
                invalid,
            } => write!(
                f,
                "no feasible candidate: {resource} pruned by the device \
                 resource budget, {fmax} by modeled interface fmax, \
                 {invalid} invalid"
            ),
            AutotuneError::AllEvaluationsFailed { first_error } => write!(
                f,
                "every feasible candidate failed to simulate \
                 (first error: {first_error})"
            ),
        }
    }
}

impl std::error::Error for AutotuneError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_parse_round_trips() {
        for obj in [
            Objective::MinP99,
            Objective::MaxThroughput,
            Objective::MaxThroughputPerLut,
            Objective::MinSloViolations,
        ] {
            assert_eq!(Objective::parse(obj.name()), Ok(obj));
        }
        assert!(Objective::parse("p42").is_err());
        // CLI shorthand aliases.
        assert_eq!(Objective::parse("slo"), Ok(Objective::MinSloViolations));
        assert_eq!(Objective::parse("min_p99"), Ok(Objective::MinP99));
    }

    #[test]
    fn objective_direction() {
        assert!(Objective::MinP99.better(1.0, 2.0));
        assert!(!Objective::MinP99.better(2.0, 1.0));
        assert!(Objective::MaxThroughput.better(2.0, 1.0));
        // Ties are never "better": the engine breaks them on candidate id.
        assert!(!Objective::MinP99.better(1.0, 1.0));
        assert!(!Objective::MaxThroughput.better(1.0, 1.0));
    }

    #[test]
    fn errors_render() {
        let e = AutotuneError::NoFeasibleCandidate {
            resource: 3,
            fmax: 2,
            invalid: 0,
        };
        let text = e.to_string();
        assert!(text.contains("3 pruned") && text.contains("fmax"), "{text}");
    }
}
