//! # accnoc
//!
//! Full-system reproduction of *"Scalable Light-Weight Integration of FPGA
//! Based Accelerators with Chip Multi-Processors"* (Lin, Sinha, Liang,
//! Feng, Zhang — IEEE TMSCS, DOI 10.1109/TMSCS.2017.2754378).
//!
//! Three-layer architecture (DESIGN.md):
//! * **L3 (this crate)** — cycle-level simulator of the paper's entire
//!   prototype: mesh NoC, FPGA multi-accelerator interface (packet
//!   receivers/senders, HWA channels, request/grant, chaining), MicroBlaze
//!   CMP model, MMU/DMA, AXI and shared-cache baselines, plus an
//!   analytical synthesis model for fmax/resource results.
//! * **L2/L1 (python/)** — JAX graphs + Pallas kernels for the HWA
//!   compute, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **runtime** — loads the artifacts through PJRT (`xla` crate) so HWA
//!   invocations in the simulator produce real numerics.
//!
//! Work is submitted through the typed driver layer in [`accel`]
//! (`AccelRuntime` sessions, `Job`/`Chain` builders, completion
//! `Receipt`s); the raw `cmp::core` segment stream is its compilation
//! target.

pub mod accel;
pub mod autotune;
pub mod baseline;
pub mod clock;
pub mod cmp;
pub mod coordinator;
pub mod fault;
pub mod mem;
pub mod synth;
pub mod fpga;
pub mod flit;
pub mod noc;
pub mod reconfig;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod workload;
pub mod util;
