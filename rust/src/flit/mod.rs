//! 137-bit flit format (paper Table 1), packets and task framing.

pub mod fields;
pub mod packet;

pub use fields::{
    Direction, FlitKind, HeadFields, PacketType, RawFlit, BODY_PAYLOAD_BITS,
    FLIT_BITS, HEAD_PAYLOAD_BITS,
};
pub use packet::{
    payload_packet_flits, Flit, FlitMeta, Packet, PacketBuilder,
    WORDS_PER_BODY_FLIT,
};
