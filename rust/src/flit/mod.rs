//! 137-bit flit format (paper Table 1), packets, task framing and the
//! pooled packet/word-buffer arena backing the zero-copy hot path.

pub mod arena;
pub mod fields;
pub mod packet;

pub use arena::{ArenaStats, PacketArena, PacketHandle, WordsHandle};
pub use fields::{
    command_payload_origin, command_payload_with_origin, crc16, payload_crc,
    payload_with_crc, Direction, FlitKind, HeadFields, PacketType, RawFlit,
    BODY_PAYLOAD_BITS, CMD_ORIGIN_LO, FLIT_BITS, HEAD_PAYLOAD_BITS,
    PAYLOAD_CRC_LO,
};
pub use packet::{
    payload_packet_flits, Flit, FlitMeta, Packet, PacketBuilder,
    WORDS_PER_BODY_FLIT,
};
