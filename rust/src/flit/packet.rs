//! Packets: sequences of flits plus simulation metadata, and the builders
//! for every packet class the protocol uses (§4.1, §4.2 B.2, §5):
//!
//! * **request**  — single command flit, processor -> FPGA
//! * **grant**    — single command flit, FPGA -> processor or MMU
//! * **notify**   — single command flit, FPGA -> processor (completion)
//! * **payload**  — head + body* + tail carrying task input data
//! * **result**   — head + body* + tail carrying HWA output data

use super::fields::{
    crc16, decode_body_payload, encode_body, payload_with_crc, FlitKind,
    HeadFields, PacketType, RawFlit, BODY_PAYLOAD_BITS,
};

/// Simulation-side metadata carried next to the 137 wire bits. Never
/// consulted by any protocol/timing decision — used for metrics and
/// invariant checking only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FlitMeta {
    /// Flow id: unique per (source, invocation).
    pub flow: u32,
    /// Sequence number of this flit within its flow.
    pub seq: u32,
    /// Injection timestamp (ps) stamped by the first NI that saw it.
    pub injected_ps: u64,
}

/// A flit in flight: raw wire image + metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Flit {
    pub raw: RawFlit,
    pub meta: FlitMeta,
}

impl Flit {
    pub fn kind(&self) -> FlitKind {
        FlitKind::decode(self.raw.get(128, 2))
    }

    pub fn dest(&self) -> u8 {
        self.raw.get(130, 7) as u8
    }

    pub fn is_head(&self) -> bool {
        self.kind().is_head()
    }

    pub fn is_tail(&self) -> bool {
        self.kind().is_tail()
    }

    pub fn head_fields(&self) -> HeadFields {
        debug_assert!(self.is_head(), "head_fields on non-head flit");
        HeadFields::decode(&self.raw)
    }

    /// Stamp the interface tile of origin into this head flit's spare
    /// payload bits (see [`super::fields::CMD_ORIGIN_LO`]). The system
    /// does this to every head leaving a fabric for the interconnect —
    /// command heads (grants/notifies) and result-payload heads alike;
    /// both keep those payload bits unused (payload packets carry their
    /// data in body flits). Body/tail flits carry data in those bits and
    /// must never be stamped.
    pub fn stamp_origin(&mut self, node: u8) {
        debug_assert!(self.is_head(), "origin stamp on a data flit");
        debug_assert!(node < 128, "node ids are 7 bits");
        self.raw
            .set(super::fields::CMD_ORIGIN_LO, 8, 0x80 | node as u64);
    }

    /// The origin tile stamped into this head flit, if any.
    pub fn command_origin(&self) -> Option<u8> {
        super::fields::command_payload_origin(self.raw.get(0, 61))
    }

    pub fn body_payload(&self) -> [u64; 2] {
        decode_body_payload(&self.raw)
    }
}

/// An ordered run of flits forming one packet.
#[derive(Debug, Clone, Default)]
pub struct Packet {
    pub flits: Vec<Flit>,
}

impl Packet {
    pub fn len(&self) -> usize {
        self.flits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.flits.is_empty()
    }

    pub fn head(&self) -> HeadFields {
        self.flits[0].head_fields()
    }

    /// Extract the data words carried by body/tail flits (u32 lanes; four
    /// per 128-bit body payload), truncated to `n_words`.
    pub fn data_words(&self, n_words: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(n_words);
        for f in &self.flits {
            if matches!(f.kind(), FlitKind::Body | FlitKind::Tail) {
                let [a, b] = f.body_payload();
                for w in [a as u32, (a >> 32) as u32, b as u32, (b >> 32) as u32] {
                    if out.len() < n_words {
                        out.push(w);
                    }
                }
            }
        }
        out
    }

    /// Well-formedness: head first, tail last, bodies between, one packet.
    pub fn is_well_formed(&self) -> bool {
        if self.flits.is_empty() {
            return false;
        }
        let n = self.flits.len();
        if n == 1 {
            return self.flits[0].kind() == FlitKind::Single;
        }
        self.flits[0].kind() == FlitKind::Head
            && self.flits[n - 1].kind() == FlitKind::Tail
            && self.flits[1..n - 1]
                .iter()
                .all(|f| f.kind() == FlitKind::Body)
    }
}

/// Words (u32) carried per body/tail flit.
pub const WORDS_PER_BODY_FLIT: usize = (BODY_PAYLOAD_BITS / 32) as usize;

/// Builder context: stamps flow/seq metadata.
#[derive(Debug, Clone)]
pub struct PacketBuilder {
    pub flow: u32,
    next_seq: u32,
}

impl PacketBuilder {
    pub fn new(flow: u32) -> Self {
        Self { flow, next_seq: 0 }
    }

    fn stamp(&mut self, raw: RawFlit) -> Flit {
        let meta = FlitMeta {
            flow: self.flow,
            seq: self.next_seq,
            injected_ps: 0,
        };
        self.next_seq += 1;
        Flit { raw, meta }
    }

    /// Single command flit from decoded fields (kind forced Single, type
    /// forced Command). The allocation-free core of [`Self::command`]:
    /// callers that queue flits (not packets) use this directly.
    pub fn command_flit(&mut self, mut fields: HeadFields) -> Flit {
        fields.kind = FlitKind::Single;
        fields.pkt_type = PacketType::Command;
        self.stamp(fields.encode())
    }

    /// Single-flit command packet from decoded fields (kind forced Single,
    /// type forced Command).
    pub fn command(&mut self, fields: HeadFields) -> Packet {
        Packet {
            flits: vec![self.command_flit(fields)],
        }
    }

    /// Allocation-free core of [`Self::payload`]: stamp and encode the
    /// head + body + tail flits of a payload packet, handing each to
    /// `emit` in order. Every flit is stamped (seq consumed) regardless
    /// of what `emit` does with it, so drop-on-full callers stay
    /// sequence-identical with callers that keep the whole packet.
    pub fn payload_with(
        &mut self,
        mut fields: HeadFields,
        words: &[u32],
        mut emit: impl FnMut(Flit),
    ) {
        fields.pkt_type = PacketType::Payload;
        fields.data_size = ((words.len() * 4).min(1023)) as u16;
        // End-to-end checksum: every payload head carries a CRC16 over
        // its data words (fields::PAYLOAD_CRC_LO) so receivers can
        // reject in-flight corruption. Skipped when data_size saturates
        // (the receiver could no longer recover the exact word count).
        if words.len() * 4 <= 1023 {
            fields.payload = payload_with_crc(fields.payload, crc16(words));
        }
        let n_body = words.len().div_ceil(WORDS_PER_BODY_FLIT).max(1);
        fields.kind = FlitKind::Head;
        let routing = fields.routing;
        emit(self.stamp(fields.encode()));
        // A payload packet always has at least one data flit; chunk the
        // words without intermediate allocation (hot path, §Perf).
        for i in 0..n_body {
            let chunk = if words.is_empty() {
                &[] as &[u32]
            } else {
                let lo = i * WORDS_PER_BODY_FLIT;
                &words[lo..(lo + WORDS_PER_BODY_FLIT).min(words.len())]
            };
            let mut lanes = [0u32; 4];
            lanes[..chunk.len()].copy_from_slice(chunk);
            let payload = [
                lanes[0] as u64 | ((lanes[1] as u64) << 32),
                lanes[2] as u64 | ((lanes[3] as u64) << 32),
            ];
            let kind = if i + 1 == n_body {
                FlitKind::Tail
            } else {
                FlitKind::Body
            };
            emit(self.stamp(encode_body(routing, kind, payload)));
        }
    }

    /// Multi-flit payload packet: head (task/routing info) followed by the
    /// data words packed four u32 lanes per body flit; last flit is Tail.
    /// `fields.data_size` is set to the byte count (10-bit field, saturated).
    pub fn payload(&mut self, fields: HeadFields, words: &[u32]) -> Packet {
        let n_body = words.len().div_ceil(WORDS_PER_BODY_FLIT).max(1);
        let mut flits = Vec::with_capacity(1 + n_body);
        self.payload_with(fields, words, |f| flits.push(f));
        Packet { flits }
    }
}

/// Flit count of a payload packet carrying `n_words` u32 words
/// (head + ceil(words/4) body/tail flits; minimum one data flit).
pub fn payload_packet_flits(n_words: usize) -> usize {
    1 + n_words.div_ceil(WORDS_PER_BODY_FLIT).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::fields::Direction;

    fn fields(dest: u8, hwa: u8) -> HeadFields {
        HeadFields {
            routing: dest,
            hwa_id: hwa,
            direction: Direction::ProcToHwa,
            ..HeadFields::default()
        }
    }

    #[test]
    fn command_is_single_flit() {
        let mut b = PacketBuilder::new(1);
        let p = b.command(fields(3, 7));
        assert_eq!(p.len(), 1);
        assert!(p.is_well_formed());
        assert_eq!(p.head().pkt_type, PacketType::Command);
        assert_eq!(p.flits[0].kind(), FlitKind::Single);
    }

    #[test]
    fn payload_packs_words_roundtrip() {
        let mut b = PacketBuilder::new(2);
        let words: Vec<u32> = (0..13).map(|i| 0xA000_0000 | i).collect();
        let p = b.payload(fields(5, 2), &words);
        // 13 words -> 4 data flits (4+4+4+1) + head.
        assert_eq!(p.len(), 5);
        assert!(p.is_well_formed());
        assert_eq!(p.data_words(13), words);
        assert_eq!(p.head().data_size, 52);
    }

    #[test]
    fn payload_word_multiple_of_four() {
        let mut b = PacketBuilder::new(3);
        let words: Vec<u32> = (0..8).collect();
        let p = b.payload(fields(1, 1), &words);
        assert_eq!(p.len(), 3); // head + 2
        assert_eq!(p.data_words(8), words);
    }

    #[test]
    fn empty_payload_still_has_data_flit() {
        let mut b = PacketBuilder::new(4);
        let p = b.payload(fields(1, 1), &[]);
        assert_eq!(p.len(), 2);
        assert!(p.is_well_formed());
    }

    #[test]
    fn seq_numbers_increase_across_packets() {
        let mut b = PacketBuilder::new(5);
        let p1 = b.command(fields(1, 1));
        let p2 = b.command(fields(1, 1));
        assert_eq!(p1.flits[0].meta.seq, 0);
        assert_eq!(p2.flits[0].meta.seq, 1);
        assert_eq!(p1.flits[0].meta.flow, 5);
    }

    #[test]
    fn well_formedness_rejects_misordered() {
        let mut b = PacketBuilder::new(6);
        let p = b.payload(fields(1, 1), &(0..8).collect::<Vec<_>>());
        let mut bad = p.clone();
        bad.flits.swap(0, 1);
        assert!(!bad.is_well_formed());
        let empty = Packet::default();
        assert!(!empty.is_well_formed());
    }

    #[test]
    fn streaming_builders_match_packet_builders_bit_for_bit() {
        // The wrapper/core split (command vs command_flit, payload vs
        // payload_with) must be flit-identical including metadata, so
        // pooled call sites provably emit the pre-refactor wire stream.
        let mut a = PacketBuilder::new(9);
        let mut b = PacketBuilder::new(9);
        for n in [0usize, 1, 4, 13, 64] {
            let words: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
            let p = a.payload(fields(3, 2), &words);
            let mut streamed = Vec::new();
            b.payload_with(fields(3, 2), &words, |f| streamed.push(f));
            assert_eq!(p.flits, streamed, "payload n={n}");
            let c = a.command(fields(6, 1));
            let cf = b.command_flit(fields(6, 1));
            assert_eq!(c.flits, vec![cf], "command");
        }
    }

    #[test]
    fn payload_with_consumes_seq_even_when_emit_drops() {
        // Drop-on-full call sites must stay sequence-identical with the
        // packet-keeping path: stamping happens before emit.
        let mut a = PacketBuilder::new(10);
        let mut b = PacketBuilder::new(10);
        a.payload_with(fields(1, 1), &[1, 2, 3, 4, 5], |_| {});
        b.payload(fields(1, 1), &[1, 2, 3, 4, 5]);
        let fa = a.command_flit(fields(1, 1));
        let fb = b.command_flit(fields(1, 1));
        assert_eq!(fa.meta.seq, fb.meta.seq);
    }

    #[test]
    fn payload_heads_carry_matching_crc() {
        use crate::flit::fields::payload_crc;
        let mut b = PacketBuilder::new(11);
        for n in [0usize, 1, 13, 255] {
            let words: Vec<u32> = (0..n as u32).map(|i| i ^ 0x5A5A).collect();
            let p = b.payload(fields(2, 1), &words);
            assert_eq!(
                payload_crc(p.head().payload),
                Some(crc16(&words)),
                "n={n}"
            );
            // Receiver-side recomputation over the reassembled words.
            let n_back = p.head().data_size as usize / 4;
            assert_eq!(crc16(&p.data_words(n_back)), crc16(&words));
        }
        // Saturated data_size -> no stamp (word count unrecoverable).
        let big: Vec<u32> = (0..256).collect();
        let p = b.payload(fields(2, 1), &big);
        assert_eq!(payload_crc(p.head().payload), None);
    }

    #[test]
    fn payload_flit_count_helper_matches_builder() {
        let mut b = PacketBuilder::new(7);
        for n in [0usize, 1, 3, 4, 5, 16, 64, 255] {
            let words: Vec<u32> = (0..n as u32).collect();
            let p = b.payload(fields(1, 1), &words);
            assert_eq!(p.len(), payload_packet_flits(n), "n={n}");
        }
    }
}
