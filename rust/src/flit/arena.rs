//! Pooled packet and word-buffer storage for the simulation hot path.
//!
//! Every `Packet` used to be a heap-allocated `Vec<Flit>` cloned across
//! the flit → NoC → channel → MMU module boundaries; task inputs and
//! results were fresh `Vec<u32>`s per invocation. The paper's whole
//! argument (§4-§5) is that light-weight interfacing wins by avoiding
//! data-movement overhead — so the simulator's own data movement should
//! be free too. A [`PacketArena`] owns flit storage and task word
//! buffers in recyclable slabs: allocation hands out a copyable,
//! generation-checked handle ([`PacketHandle`] / [`WordsHandle`]); free
//! pushes the slot onto a free-list with its backing `Vec` *cleared but
//! not dropped*, so capacity is retained and steady-state simulation
//! performs zero heap allocation (proven by the counting-allocator test
//! in `util::alloc_count`).
//!
//! Contract:
//! * Handles are plain indices — `Copy`, no lifetimes — validated
//!   against a per-slot generation counter. Using a handle after its
//!   slot was freed (and any use of a stale handle after the slot was
//!   reissued) panics instead of silently aliasing.
//! * The arena never shrinks: high-water mark = live slots at the worst
//!   moment of the run. [`ArenaStats`] exposes allocs/reuses/frees and
//!   high-water per pool for the bench harness to pin.
//! * `packets` and `words` are separate pools (separate struct fields),
//!   so a packet can be encoded *from* an arena word buffer *into* an
//!   arena flit buffer with disjoint borrows ([`PacketArena::build_payload`]).

use super::packet::{Flit, Packet, PacketBuilder, WORDS_PER_BODY_FLIT};
use super::HeadFields;

/// Handle to a pooled flit buffer (one packet).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PacketHandle {
    idx: u32,
    gen: u32,
}

/// Handle to a pooled `u32` word buffer (task input/output data).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WordsHandle {
    idx: u32,
    gen: u32,
}

#[derive(Debug, Default)]
struct PacketSlot {
    flits: Vec<Flit>,
    gen: u32,
    live: bool,
}

#[derive(Debug, Default)]
struct WordsSlot {
    words: Vec<u32>,
    gen: u32,
    live: bool,
}

/// Per-pool allocation counters (cheap enough to keep always-on; the
/// bench harness emits them into `BENCH_hotpath.json`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Slab-growing allocations (fresh slots) in the packet pool.
    pub packet_allocs: u64,
    /// Free-list reuses in the packet pool.
    pub packet_reuses: u64,
    pub packet_frees: u64,
    /// Maximum simultaneously-live packet slots.
    pub packet_high_water: u64,
    pub words_allocs: u64,
    pub words_reuses: u64,
    pub words_frees: u64,
    pub words_high_water: u64,
}

/// Recyclable slab pool for packets (flit runs) and task word buffers.
#[derive(Debug, Default)]
pub struct PacketArena {
    packets: Vec<PacketSlot>,
    free_packets: Vec<u32>,
    packets_live: u64,
    words: Vec<WordsSlot>,
    free_words: Vec<u32>,
    words_live: u64,
    stats: ArenaStats,
}

impl PacketArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size both pools (warm construction; optional — pools also
    /// grow on demand).
    pub fn with_capacity(packets: usize, words: usize) -> Self {
        let mut a = Self::default();
        a.packets.reserve(packets);
        a.free_packets.reserve(packets);
        a.words.reserve(words);
        a.free_words.reserve(words);
        a
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Live (allocated, not yet freed) buffers: (packets, word buffers).
    pub fn live(&self) -> (u64, u64) {
        (self.packets_live, self.words_live)
    }

    // ------------------------------------------------------------------
    // Packet pool
    // ------------------------------------------------------------------

    /// Hand out an empty pooled flit buffer (cleared, capacity retained).
    pub fn alloc_packet(&mut self) -> PacketHandle {
        self.packets_live += 1;
        self.stats.packet_high_water =
            self.stats.packet_high_water.max(self.packets_live);
        if let Some(idx) = self.free_packets.pop() {
            let slot = &mut self.packets[idx as usize];
            debug_assert!(!slot.live && slot.flits.is_empty());
            slot.live = true;
            self.stats.packet_reuses += 1;
            PacketHandle { idx, gen: slot.gen }
        } else {
            let idx = self.packets.len() as u32;
            self.packets.push(PacketSlot {
                flits: Vec::new(),
                gen: 0,
                live: true,
            });
            self.stats.packet_allocs += 1;
            PacketHandle { idx, gen: 0 }
        }
    }

    /// Return a packet buffer to the pool. Its handle (and any copy of
    /// it) becomes stale; the backing storage keeps its capacity.
    pub fn free_packet(&mut self, h: PacketHandle) {
        let slot = &mut self.packets[h.idx as usize];
        assert!(
            slot.live && slot.gen == h.gen,
            "free of a stale/dead packet handle {h:?}"
        );
        slot.flits.clear();
        slot.gen = slot.gen.wrapping_add(1);
        slot.live = false;
        self.free_packets.push(h.idx);
        self.packets_live -= 1;
        self.stats.packet_frees += 1;
    }

    fn packet_slot(&self, h: PacketHandle) -> &PacketSlot {
        let slot = &self.packets[h.idx as usize];
        assert!(
            slot.live && slot.gen == h.gen,
            "use of a stale/dead packet handle {h:?}"
        );
        slot
    }

    pub fn flits(&self, h: PacketHandle) -> &[Flit] {
        &self.packet_slot(h).flits
    }

    pub fn flits_mut(&mut self, h: PacketHandle) -> &mut Vec<Flit> {
        let slot = &mut self.packets[h.idx as usize];
        assert!(
            slot.live && slot.gen == h.gen,
            "use of a stale/dead packet handle {h:?}"
        );
        &mut slot.flits
    }

    /// Owned copy of a pooled packet (test/debug convenience — the hot
    /// path never needs it).
    pub fn to_packet(&self, h: PacketHandle) -> Packet {
        Packet {
            flits: self.packet_slot(h).flits.clone(),
        }
    }

    // ------------------------------------------------------------------
    // Word pool
    // ------------------------------------------------------------------

    /// Hand out an empty pooled word buffer (cleared, capacity retained).
    pub fn alloc_words(&mut self) -> WordsHandle {
        self.words_live += 1;
        self.stats.words_high_water =
            self.stats.words_high_water.max(self.words_live);
        if let Some(idx) = self.free_words.pop() {
            let slot = &mut self.words[idx as usize];
            debug_assert!(!slot.live && slot.words.is_empty());
            slot.live = true;
            self.stats.words_reuses += 1;
            WordsHandle { idx, gen: slot.gen }
        } else {
            let idx = self.words.len() as u32;
            self.words.push(WordsSlot {
                words: Vec::new(),
                gen: 0,
                live: true,
            });
            self.stats.words_allocs += 1;
            WordsHandle { idx, gen: 0 }
        }
    }

    /// Allocate a word buffer pre-filled with a copy of `src`.
    pub fn alloc_words_from(&mut self, src: &[u32]) -> WordsHandle {
        let h = self.alloc_words();
        self.words[h.idx as usize].words.extend_from_slice(src);
        h
    }

    /// Return a word buffer to the pool (handle becomes stale, capacity
    /// retained).
    pub fn free_words(&mut self, h: WordsHandle) {
        let slot = &mut self.words[h.idx as usize];
        assert!(
            slot.live && slot.gen == h.gen,
            "free of a stale/dead words handle {h:?}"
        );
        slot.words.clear();
        slot.gen = slot.gen.wrapping_add(1);
        slot.live = false;
        self.free_words.push(h.idx);
        self.words_live -= 1;
        self.stats.words_frees += 1;
    }

    pub fn words(&self, h: WordsHandle) -> &[u32] {
        let slot = &self.words[h.idx as usize];
        assert!(
            slot.live && slot.gen == h.gen,
            "use of a stale/dead words handle {h:?}"
        );
        &slot.words
    }

    pub fn words_mut(&mut self, h: WordsHandle) -> &mut Vec<u32> {
        let slot = &mut self.words[h.idx as usize];
        assert!(
            slot.live && slot.gen == h.gen,
            "use of a stale/dead words handle {h:?}"
        );
        &mut slot.words
    }

    // ------------------------------------------------------------------
    // Cross-pool builders
    // ------------------------------------------------------------------

    /// Encode a payload packet whose data words already live in this
    /// arena, into a pooled flit buffer — no intermediate `Vec`s. The
    /// flits (including flow/seq metadata) are bit-identical to
    /// `builder.payload(fields, arena.words(src))`.
    pub fn build_payload(
        &mut self,
        builder: &mut PacketBuilder,
        fields: HeadFields,
        src: WordsHandle,
    ) -> PacketHandle {
        let h = self.alloc_packet();
        {
            // Disjoint pools: encode *from* the word slab *into* the
            // flit slab without cloning either.
            let src_slot = &self.words[src.idx as usize];
            assert!(
                src_slot.live && src_slot.gen == src.gen,
                "use of a stale/dead words handle {src:?}"
            );
            let dst = &mut self.packets[h.idx as usize].flits;
            dst.reserve(
                1 + src_slot.words.len().div_ceil(WORDS_PER_BODY_FLIT).max(1),
            );
            builder.payload_with(fields, &src_slot.words, |f| dst.push(f));
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen, IntGen, VecGen};

    #[test]
    fn packet_roundtrip_and_reuse() {
        let mut a = PacketArena::new();
        let mut b = PacketBuilder::new(1);
        let w = a.alloc_words_from(&[1, 2, 3, 4, 5]);
        let p = a.build_payload(&mut b, HeadFields::default(), w);
        assert_eq!(a.flits(p).len(), 1 + 2);
        let reference = PacketBuilder::new(1).payload(HeadFields::default(), &[1, 2, 3, 4, 5]);
        assert_eq!(a.flits(p), &reference.flits[..], "bit-identical to Vec path");
        a.free_packet(p);
        a.free_words(w);
        // Second round reuses both slots: no fresh slab growth.
        let w2 = a.alloc_words_from(&[9]);
        let p2 = a.build_payload(&mut b, HeadFields::default(), w2);
        let s = a.stats();
        assert_eq!(s.packet_allocs, 1);
        assert_eq!(s.packet_reuses, 1);
        assert_eq!(s.words_allocs, 1);
        assert_eq!(s.words_reuses, 1);
        assert_eq!(a.flits(p2).len(), 2);
    }

    #[test]
    #[should_panic(expected = "stale/dead")]
    fn stale_packet_handle_panics() {
        let mut a = PacketArena::new();
        let p = a.alloc_packet();
        a.free_packet(p);
        let _ = a.flits(p);
    }

    #[test]
    #[should_panic(expected = "stale/dead")]
    fn stale_words_handle_panics_after_reissue() {
        let mut a = PacketArena::new();
        let w = a.alloc_words_from(&[7]);
        a.free_words(w);
        let w2 = a.alloc_words();
        assert_ne!(w, w2, "reissued handle carries a new generation");
        let _ = a.words(w);
    }

    #[test]
    #[should_panic(expected = "stale/dead")]
    fn double_free_panics() {
        let mut a = PacketArena::new();
        let w = a.alloc_words();
        a.free_words(w);
        a.free_words(w);
    }

    /// Drive random alloc/free sequences; at every point the set of live
    /// handles must be readable, disjoint, and the pool's live count
    /// consistent — i.e. a freed slot is never aliased by a live handle.
    #[test]
    fn prop_no_aliasing_after_free() {
        // op % 3: 0/1 = alloc (with distinct fill), 2 = free oldest.
        check(
            "arena: no handle aliasing after free",
            VecGen::new(IntGen::below(3), 0, 64),
            |ops| {
                let mut a = PacketArena::new();
                let mut live: Vec<(WordsHandle, u32)> = Vec::new();
                let mut tag = 0u32;
                for op in ops {
                    if *op == 2 && !live.is_empty() {
                        let (h, _) = live.remove(0);
                        a.free_words(h);
                    } else {
                        tag += 1;
                        let h = a.alloc_words_from(&[tag]);
                        live.push((h, tag));
                    }
                    // Every live buffer still holds its own fill word.
                    if !live.iter().all(|(h, t)| a.words(*h) == [*t]) {
                        return false;
                    }
                }
                a.live().1 == live.len() as u64
            },
        );
    }

    /// Exhausting the free list grows the slab (never corrupts): allocs
    /// beyond the freed count mint fresh slots and all fills stay intact.
    #[test]
    fn prop_freelist_exhaustion_grows_never_corrupts() {
        check(
            "arena: free-list exhaustion grows",
            IntGen::range(1, 48),
            |n| {
                let n = *n as usize;
                let mut a = PacketArena::new();
                let first: Vec<WordsHandle> =
                    (0..n).map(|i| a.alloc_words_from(&[i as u32])).collect();
                for h in first {
                    a.free_words(h);
                }
                // 2n allocs: n reuses then n fresh slots.
                let second: Vec<WordsHandle> = (0..2 * n)
                    .map(|i| a.alloc_words_from(&[1000 + i as u32]))
                    .collect();
                let s = a.stats();
                s.words_reuses == n as u64
                    && s.words_allocs == 2 * n as u64
                    && second
                        .iter()
                        .enumerate()
                        .all(|(i, h)| a.words(*h) == [1000 + i as u32])
            },
        );
    }

    /// Over a long random run with bounded concurrency the high-water
    /// mark stabilizes: it never exceeds the live-set bound, and after
    /// warmup further traffic stops moving it.
    #[test]
    fn prop_high_water_stabilizes() {
        check(
            "arena: high-water stabilizes",
            IntGen::range(1, 8),
            |bound| {
                let bound = *bound as usize;
                let mut a = PacketArena::new();
                let mut live: Vec<WordsHandle> = Vec::new();
                let mut warm_high = 0;
                for round in 0..400 {
                    // Deterministic churn: fill to `bound`, drain one.
                    while live.len() < bound {
                        live.push(a.alloc_words_from(&[round]));
                    }
                    a.free_words(live.remove(0));
                    if round == 100 {
                        warm_high = a.stats().words_high_water;
                    }
                }
                let s = a.stats();
                s.words_high_water <= bound as u64
                    && s.words_high_water == warm_high
                    && s.words_allocs == s.words_high_water
            },
        );
    }

    #[test]
    fn build_payload_matches_builder_over_random_corpus() {
        check(
            "arena: build_payload flit-identical to Vec path",
            VecGen::new(IntGen::below(u32::MAX as u64), 0, 70),
            |words| {
                let words: Vec<u32> = words.iter().map(|w| *w as u32).collect();
                let mut a = PacketArena::new();
                let mut b1 = PacketBuilder::new(42);
                let mut b2 = PacketBuilder::new(42);
                let fields = HeadFields {
                    routing: 9,
                    hwa_id: 3,
                    ..HeadFields::default()
                };
                let w = a.alloc_words_from(&words);
                let p = a.build_payload(&mut b1, fields, w);
                let reference = b2.payload(fields, &words);
                a.flits(p) == &reference.flits[..]
            },
        );
    }
}
