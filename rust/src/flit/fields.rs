//! Bit-exact 137-bit flit layout (paper Table 1).
//!
//! | bits    | field                                   |
//! |---------|-----------------------------------------|
//! | 130-136 | routing information (destination node)  |
//! | 128-129 | packet head & tail bits                 |
//! | 125-127 | source ID (requesting processor)        |
//! | 120-124 | HWA ID                                  |
//! | 119     | packet type (1 = command, 0 = payload)  |
//! | 117-118 | task head & tail bits                   |
//! | 115-116 | task buffer ID                          |
//! | 113-114 | chaining depth                          |
//! | 107-112 | chaining index (3 × 2-bit group indexes)|
//! | 105-106 | packet priority                         |
//! | 103-104 | packet direction                        |
//! | 71-102  | start address                           |
//! | 61-70   | data size (bytes to fetch)              |
//! | 0-60    | payload data (head flit)                |
//!
//! Body/tail flits: bits 128-136 carry routing + head/tail bits; bits
//! 0-127 are payload data.
//!
//! The raw image is three little-endian u64 words (bit i lives at word
//! i/64, bit i%64); bits 137-191 are always zero. Simulation-only metadata
//! (flow id, timestamps) lives in [`super::packet::FlitMeta`], outside the
//! 137 wire bits, and is asserted not to influence any timing decision by
//! the codec round-trip tests.

/// A raw 137-bit flit image.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct RawFlit(pub [u64; 3]);

pub const FLIT_BITS: u32 = 137;

/// Number of payload bits in a head flit (bits 0-60).
pub const HEAD_PAYLOAD_BITS: u32 = 61;
/// Number of payload bits in a body/tail flit (bits 0-127).
pub const BODY_PAYLOAD_BITS: u32 = 128;

impl RawFlit {
    /// Extract `len` bits starting at bit `lo` (len <= 64).
    #[inline]
    pub fn get(&self, lo: u32, len: u32) -> u64 {
        debug_assert!(len >= 1 && len <= 64 && lo + len <= 192);
        let word = (lo / 64) as usize;
        let off = lo % 64;
        let mut v = self.0[word] >> off;
        if off + len > 64 && word + 1 < 3 {
            v |= self.0[word + 1] << (64 - off);
        }
        if len == 64 {
            v
        } else {
            v & ((1u64 << len) - 1)
        }
    }

    /// Set `len` bits starting at `lo` to `value` (masked).
    #[inline]
    pub fn set(&mut self, lo: u32, len: u32, value: u64) {
        debug_assert!(len >= 1 && len <= 64 && lo + len <= 192);
        let masked = if len == 64 {
            value
        } else {
            value & ((1u64 << len) - 1)
        };
        let word = (lo / 64) as usize;
        let off = lo % 64;
        let lo_mask = if len == 64 && off == 0 {
            u64::MAX
        } else {
            (((1u128 << len) - 1) << off) as u64
        };
        self.0[word] = (self.0[word] & !lo_mask) | (masked << off);
        if off + len > 64 && word + 1 < 3 {
            let hi_len = off + len - 64;
            let hi_mask = (1u64 << hi_len) - 1;
            self.0[word + 1] =
                (self.0[word + 1] & !hi_mask) | (masked >> (64 - off));
        }
    }

    /// True when every bit at index >= 137 is zero (well-formed image).
    pub fn padding_clear(&self) -> bool {
        let hi = self.0[2];
        (hi >> (FLIT_BITS - 128)) == 0
    }
}

/// Head/body/tail discriminant from bits 128-129 (bit129 = head,
/// bit128 = tail; a single-flit packet sets both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FlitKind {
    Head,
    Body,
    Tail,
    /// Single-flit packet (head+tail set) — command packets are these.
    Single,
}

impl FlitKind {
    pub fn encode(self) -> u64 {
        match self {
            FlitKind::Body => 0b00,
            FlitKind::Tail => 0b01,
            FlitKind::Head => 0b10,
            FlitKind::Single => 0b11,
        }
    }

    pub fn decode(bits: u64) -> Self {
        match bits & 0b11 {
            0b00 => FlitKind::Body,
            0b01 => FlitKind::Tail,
            0b10 => FlitKind::Head,
            _ => FlitKind::Single,
        }
    }

    pub fn is_head(self) -> bool {
        matches!(self, FlitKind::Head | FlitKind::Single)
    }

    pub fn is_tail(self) -> bool {
        matches!(self, FlitKind::Tail | FlitKind::Single)
    }
}

/// Packet type bit 119.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PacketType {
    Payload,
    Command,
}

impl PacketType {
    pub fn encode(self) -> u64 {
        match self {
            PacketType::Payload => 0,
            PacketType::Command => 1,
        }
    }

    pub fn decode(bit: u64) -> Self {
        if bit & 1 == 1 {
            PacketType::Command
        } else {
            PacketType::Payload
        }
    }
}

/// Packet direction bits 103-104 (source/destination of the data).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Direction {
    /// Processor sends input data directly (Fig. 5a).
    ProcToHwa,
    /// Data fetched from memory via the MMU (Fig. 5b).
    MemToHwa,
    /// Results returned to the requesting processor.
    HwaToProc,
    /// Results written back to memory.
    HwaToMem,
}

impl Direction {
    pub fn encode(self) -> u64 {
        match self {
            Direction::ProcToHwa => 0,
            Direction::MemToHwa => 1,
            Direction::HwaToProc => 2,
            Direction::HwaToMem => 3,
        }
    }

    pub fn decode(bits: u64) -> Self {
        match bits & 0b11 {
            0 => Direction::ProcToHwa,
            1 => Direction::MemToHwa,
            2 => Direction::HwaToProc,
            _ => Direction::HwaToMem,
        }
    }
}

/// Decoded head-flit fields (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeadFields {
    pub routing: u8,        // 7 bits: destination node id
    pub kind: FlitKind,     // 2 bits
    pub src_id: u8,         // 3 bits
    pub hwa_id: u8,         // 5 bits
    pub pkt_type: PacketType, // 1 bit
    pub task_head: bool,    // bit 118
    pub task_tail: bool,    // bit 117
    pub tb_id: u8,          // 2 bits
    pub chain_depth: u8,    // 2 bits
    pub chain_index: [u8; 3], // 3 x 2 bits (bits 107-112, index 0 lowest)
    pub priority: u8,       // 2 bits
    pub direction: Direction, // 2 bits
    pub start_addr: u32,    // 32 bits
    pub data_size: u16,     // 10 bits
    pub payload: u64,       // 61 bits
}

impl Default for HeadFields {
    fn default() -> Self {
        Self {
            routing: 0,
            kind: FlitKind::Head,
            src_id: 0,
            hwa_id: 0,
            pkt_type: PacketType::Payload,
            task_head: false,
            task_tail: false,
            tb_id: 0,
            chain_depth: 0,
            chain_index: [0; 3],
            priority: 0,
            direction: Direction::ProcToHwa,
            start_addr: 0,
            data_size: 0,
            payload: 0,
        }
    }
}

impl HeadFields {
    pub fn encode(&self) -> RawFlit {
        debug_assert!(self.routing < 128, "routing is 7 bits");
        debug_assert!(self.src_id < 8, "src_id is 3 bits");
        debug_assert!(self.hwa_id < 32, "hwa_id is 5 bits");
        debug_assert!(self.tb_id < 4, "tb_id is 2 bits");
        debug_assert!(self.chain_depth < 4, "chain_depth is 2 bits");
        debug_assert!(self.priority < 4, "priority is 2 bits");
        debug_assert!(self.data_size < 1024, "data_size is 10 bits");
        debug_assert!(self.payload < (1 << 61), "head payload is 61 bits");
        let mut raw = RawFlit::default();
        raw.set(130, 7, self.routing as u64);
        raw.set(128, 2, self.kind.encode());
        raw.set(125, 3, self.src_id as u64);
        raw.set(120, 5, self.hwa_id as u64);
        raw.set(119, 1, self.pkt_type.encode());
        raw.set(118, 1, self.task_head as u64);
        raw.set(117, 1, self.task_tail as u64);
        raw.set(115, 2, self.tb_id as u64);
        raw.set(113, 2, self.chain_depth as u64);
        let ci = (self.chain_index[0] as u64 & 0b11)
            | ((self.chain_index[1] as u64 & 0b11) << 2)
            | ((self.chain_index[2] as u64 & 0b11) << 4);
        raw.set(107, 6, ci);
        raw.set(105, 2, self.priority as u64);
        raw.set(103, 2, self.direction.encode());
        raw.set(71, 32, self.start_addr as u64);
        raw.set(61, 10, self.data_size as u64);
        raw.set(0, 61, self.payload);
        raw
    }

    pub fn decode(raw: &RawFlit) -> Self {
        let ci = raw.get(107, 6);
        Self {
            routing: raw.get(130, 7) as u8,
            kind: FlitKind::decode(raw.get(128, 2)),
            src_id: raw.get(125, 3) as u8,
            hwa_id: raw.get(120, 5) as u8,
            pkt_type: PacketType::decode(raw.get(119, 1)),
            task_head: raw.get(118, 1) == 1,
            task_tail: raw.get(117, 1) == 1,
            tb_id: raw.get(115, 2) as u8,
            chain_depth: raw.get(113, 2) as u8,
            chain_index: [
                (ci & 0b11) as u8,
                ((ci >> 2) & 0b11) as u8,
                ((ci >> 4) & 0b11) as u8,
            ],
            priority: raw.get(105, 2) as u8,
            direction: Direction::decode(raw.get(103, 2)),
            start_addr: raw.get(71, 32) as u32,
            data_size: raw.get(61, 10) as u16,
            payload: raw.get(0, 61),
        }
    }
}

/// Origin-tile addressing inside command payloads.
///
/// Command packets (request/grant/notify) use only the low bits of the
/// 61-bit head payload for their [`crate::fpga::channel::task::CommandKind`]
/// subtype. With floorplanned systems carrying several FPGA interface
/// tiles, grants and notifies additionally carry the **tile of origin**
/// in payload bits [`CMD_ORIGIN_LO`]..`CMD_ORIGIN_LO + 8` (a presence
/// bit plus the 7-bit node id), so MMUs and traffic sources can route
/// their answers back to the granting fabric without any global
/// "the FPGA node" assumption. A payload without the presence bit (all
/// pre-floorplan traffic, and processor-built requests) simply has no
/// origin — consumers fall back to their configured default fabric.
pub const CMD_ORIGIN_LO: u32 = 8;

/// Set the origin tile in a command payload (7-bit node + presence bit).
pub fn command_payload_with_origin(payload: u64, node: u8) -> u64 {
    debug_assert!(node < 128, "node ids are 7 bits");
    let mask = 0xFFu64 << CMD_ORIGIN_LO;
    (payload & !mask) | ((0x80 | node as u64) << CMD_ORIGIN_LO)
}

/// The origin tile of a command payload, if one was stamped.
pub fn command_payload_origin(payload: u64) -> Option<u8> {
    let bits = (payload >> CMD_ORIGIN_LO) & 0xFF;
    if bits & 0x80 != 0 {
        Some((bits & 0x7F) as u8)
    } else {
        None
    }
}

/// End-to-end payload checksum inside head payloads.
///
/// Payload packets stamp a CRC16 over their data words into head
/// payload bits `PAYLOAD_CRC_LO..PAYLOAD_CRC_LO + 16`, with a presence
/// bit at `PAYLOAD_CRC_LO + 16` (same presence-bit discipline as
/// [`CMD_ORIGIN_LO`], which occupies the disjoint range 8..16). The
/// receiver recomputes the CRC over the reassembled words and rejects
/// the packet on mismatch — the detection edge of the fault-recovery
/// path. Pre-CRC traffic simply lacks the presence bit and is accepted
/// unverified.
pub const PAYLOAD_CRC_LO: u32 = 16;

/// CRC-16/CCITT-FALSE over the little-endian bytes of `words`
/// (init 0xFFFF, poly 0x1021, no reflection).
pub fn crc16(words: &[u32]) -> u16 {
    let mut crc: u16 = 0xFFFF;
    for w in words {
        for byte in w.to_le_bytes() {
            crc ^= (byte as u16) << 8;
            for _ in 0..8 {
                crc = if crc & 0x8000 != 0 {
                    (crc << 1) ^ 0x1021
                } else {
                    crc << 1
                };
            }
        }
    }
    crc
}

/// Stamp a payload-packet CRC16 (plus its presence bit) into a head
/// payload.
pub fn payload_with_crc(payload: u64, crc: u16) -> u64 {
    let mask = 0x1_FFFFu64 << PAYLOAD_CRC_LO;
    (payload & !mask) | ((0x1_0000 | crc as u64) << PAYLOAD_CRC_LO)
}

/// The stamped CRC16 of a payload-packet head payload, if present.
pub fn payload_crc(payload: u64) -> Option<u16> {
    let bits = (payload >> PAYLOAD_CRC_LO) & 0x1_FFFF;
    if bits & 0x1_0000 != 0 {
        Some((bits & 0xFFFF) as u16)
    } else {
        None
    }
}

/// Encode a body or tail flit: routing + kind + 128-bit payload.
pub fn encode_body(routing: u8, kind: FlitKind, payload: [u64; 2]) -> RawFlit {
    debug_assert!(matches!(kind, FlitKind::Body | FlitKind::Tail));
    let mut raw = RawFlit::default();
    raw.set(130, 7, routing as u64);
    raw.set(128, 2, kind.encode());
    raw.set(0, 64, payload[0]);
    raw.set(64, 64, payload[1]);
    raw
}

/// Decode the 128-bit payload of a body/tail flit.
pub fn decode_body_payload(raw: &RawFlit) -> [u64; 2] {
    [raw.get(0, 64), raw.get(64, 64)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> HeadFields {
        HeadFields {
            routing: 0b101_1010,
            kind: FlitKind::Head,
            src_id: 5,
            hwa_id: 19,
            pkt_type: PacketType::Command,
            task_head: true,
            task_tail: false,
            tb_id: 2,
            chain_depth: 3,
            chain_index: [1, 2, 3],
            priority: 2,
            direction: Direction::MemToHwa,
            start_addr: 0xDEAD_BEEF,
            data_size: 777,
            payload: 0x0ABC_DEF0_1234_5678 & ((1 << 61) - 1),
        }
    }

    #[test]
    fn head_roundtrip_exact() {
        let h = sample();
        assert_eq!(HeadFields::decode(&h.encode()), h);
    }

    #[test]
    fn padding_bits_stay_zero() {
        assert!(sample().encode().padding_clear());
    }

    #[test]
    fn table1_bit_positions() {
        // Spot-check absolute bit positions against Table 1.
        let h = sample();
        let raw = h.encode();
        assert_eq!(raw.get(130, 7), 0b101_1010); // routing at 130
        assert_eq!(raw.get(120, 5), 19); // hwa id at 120
        assert_eq!(raw.get(119, 1), 1); // command bit
        assert_eq!(raw.get(71, 32), 0xDEAD_BEEF); // start addr at 71
        assert_eq!(raw.get(61, 10), 777); // data size at 61
    }

    #[test]
    fn kind_encoding_matches_head_tail_bits() {
        assert_eq!(FlitKind::Head.encode(), 0b10);
        assert_eq!(FlitKind::Tail.encode(), 0b01);
        assert_eq!(FlitKind::Single.encode(), 0b11);
        assert!(FlitKind::Single.is_head() && FlitKind::Single.is_tail());
        assert!(FlitKind::Head.is_head() && !FlitKind::Head.is_tail());
    }

    #[test]
    fn command_origin_roundtrips_and_is_absent_by_default() {
        // CommandKind subtypes live in the low payload bits; the origin
        // field must coexist with them without corruption.
        for kind in [0u64, 1, 2] {
            assert_eq!(command_payload_origin(kind), None);
            for node in [0u8, 1, 8, 127] {
                let stamped = command_payload_with_origin(kind, node);
                assert_eq!(command_payload_origin(stamped), Some(node));
                assert_eq!(stamped & 0b11, kind, "subtype bits preserved");
                // Stamping is idempotent / overwritable.
                let restamped = command_payload_with_origin(stamped, 5);
                assert_eq!(command_payload_origin(restamped), Some(5));
            }
        }
    }

    #[test]
    fn stamped_origin_survives_head_encode_decode() {
        let mut h = sample();
        h.payload = command_payload_with_origin(1, 8);
        let back = HeadFields::decode(&h.encode());
        assert_eq!(command_payload_origin(back.payload), Some(8));
    }

    #[test]
    fn payload_crc_roundtrips_and_is_absent_by_default() {
        assert_eq!(payload_crc(0), None);
        assert_eq!(payload_crc(CMD_LIKE_PAYLOAD), None);
        let words = [0xDEAD_BEEFu32, 1, 2, 3];
        let c = crc16(&words);
        let stamped = payload_with_crc(CMD_LIKE_PAYLOAD, c);
        assert_eq!(payload_crc(stamped), Some(c));
        // Coexists with the command subtype and origin fields.
        assert_eq!(stamped & 0b11, CMD_LIKE_PAYLOAD & 0b11);
        let with_origin = command_payload_with_origin(stamped, 9);
        assert_eq!(payload_crc(with_origin), Some(c));
        assert_eq!(command_payload_origin(with_origin), Some(9));
        // Still fits the 61-bit head payload.
        assert!(with_origin < (1 << 61));
        // Restamping overwrites cleanly.
        assert_eq!(payload_crc(payload_with_crc(stamped, 0)), Some(0));
    }

    const CMD_LIKE_PAYLOAD: u64 = 0b10;

    #[test]
    fn crc16_detects_single_bit_flips() {
        let words = [7u32, 0x1234_5678, 0xFFFF_FFFF, 0];
        let good = crc16(&words);
        for w in 0..words.len() {
            for bit in [0u32, 13, 31] {
                let mut bad = words;
                bad[w] ^= 1 << bit;
                assert_ne!(crc16(&bad), good, "flip at word {w} bit {bit}");
            }
        }
        // Known stability pin so the polynomial never silently changes.
        assert_eq!(crc16(&[]), 0xFFFF);
    }

    #[test]
    fn body_roundtrip() {
        let payload = [0x1122_3344_5566_7788, 0x99AA_BBCC_DDEE_FF00];
        let raw = encode_body(77, FlitKind::Body, payload);
        assert_eq!(decode_body_payload(&raw), payload);
        assert_eq!(raw.get(130, 7), 77);
        assert_eq!(FlitKind::decode(raw.get(128, 2)), FlitKind::Body);
        assert!(raw.padding_clear());
    }

    #[test]
    fn get_set_cross_word_boundary() {
        let mut raw = RawFlit::default();
        raw.set(60, 10, 0x3FF);
        assert_eq!(raw.get(60, 10), 0x3FF);
        assert_eq!(raw.get(0, 60), 0);
        raw.set(100, 64, u64::MAX);
        assert_eq!(raw.get(100, 64), u64::MAX);
        raw.set(100, 64, 0xDEAD);
        assert_eq!(raw.get(100, 64), 0xDEAD);
    }

    #[test]
    fn set_is_idempotent_and_isolated() {
        let mut raw = sample().encode();
        let before = raw;
        raw.set(61, 10, 777); // same value
        assert_eq!(raw, before);
        raw.set(61, 10, 1); // different value changes only that field
        let h = HeadFields::decode(&raw);
        assert_eq!(h.data_size, 1);
        assert_eq!(h.start_addr, 0xDEAD_BEEF);
        assert_eq!(h.hwa_id, 19);
    }
}
