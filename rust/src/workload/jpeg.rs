//! Realistic JPEG-block workload: synthetic images pushed through the
//! forward DCT + quantization so the simulator decodes *real* coefficient
//! blocks (used by the end-to-end example and the Fig. 10 experiment).

use crate::runtime::native::{jpeg_encode, DEFAULT_QTABLE};
use crate::util::rng::Pcg32;

/// A synthetic 8x8-block image with smooth gradients + noise (so the
/// DCT coefficients have realistic energy compaction).
pub struct BlockImage {
    pub blocks: Vec<[f32; 64]>,
}

impl BlockImage {
    pub fn synthetic(n_blocks: usize, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let blocks = (0..n_blocks)
            .map(|b| {
                let base = (b % 17) as f32 * 13.0;
                let mut px = [0f32; 64];
                for (i, p) in px.iter_mut().enumerate() {
                    let (x, y) = ((i % 8) as f32, (i / 8) as f32);
                    let v = base + 8.0 * x + 5.0 * y
                        + rng.f64() as f32 * 24.0;
                    *p = v.clamp(0.0, 255.0);
                }
                px
            })
            .collect();
        Self { blocks }
    }

    /// Encode every block to scan-order quantized coefficients.
    pub fn encode(&self) -> Vec<[i32; 64]> {
        self.blocks
            .iter()
            .map(|b| jpeg_encode(b, &DEFAULT_QTABLE))
            .collect()
    }

    /// Coefficient blocks as u32 word vectors (task payloads).
    pub fn coefficient_words(&self) -> Vec<Vec<u32>> {
        self.encode()
            .iter()
            .map(|scan| scan.iter().map(|c| *c as u32).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::jpeg_chain;

    #[test]
    fn encode_decode_roundtrip_quality() {
        let img = BlockImage::synthetic(16, 7);
        let coeffs = img.encode();
        let mut total_err = 0.0f64;
        for (px, scan) in img.blocks.iter().zip(&coeffs) {
            let decoded = jpeg_chain(scan, &DEFAULT_QTABLE);
            for i in 0..64 {
                total_err += (px[i] as f64 - decoded[i] as f64).abs();
            }
        }
        let mean = total_err / (16.0 * 64.0);
        assert!(mean < 20.0, "mean abs error {mean}");
    }

    #[test]
    fn coefficients_are_sparse() {
        // Energy compaction: most high-frequency coefficients quantize
        // to zero for smooth blocks.
        let img = BlockImage::synthetic(8, 9);
        let coeffs = img.encode();
        let zeros: usize = coeffs
            .iter()
            .map(|c| c.iter().filter(|x| **x == 0).count())
            .sum();
        assert!(zeros > 8 * 32, "zeros={zeros}");
    }
}
