//! Open-loop traffic sources for the §6.4 throughput experiments: each
//! processor node issues requests at a configured rate *without blocking
//! on results* (injection rate is the experiment's independent variable;
//! the paper sweeps "request frequencies" with multiple outstanding
//! invocations in flight).
//!
//! The source speaks the full protocol (request -> grant -> payload) but
//! keeps issuing while earlier invocations are still executing. On
//! floorplanned systems a source spreads its requests uniformly over
//! every accelerator of every fabric: its target list is fabric-major
//! `(interface node, hwa_id, spec)` entries, and grant/notify answers
//! are matched back by the origin tile stamped into the command payload
//! (see `flit::fields::CMD_ORIGIN_LO`).

use std::collections::VecDeque;

use crate::clock::{Activity, Ps, PS_PER_US};
use crate::fault::{FaultStats, RecoveryPolicy};
use crate::flit::{
    payload_packet_flits, Direction, Flit, FlitKind, HeadFields,
    PacketBuilder, PacketType,
};
use crate::fpga::channel::task::CommandKind;
use crate::fpga::hwa::HwaSpec;
use crate::util::rng::Pcg32;

/// Bound on queued outbound flits (prevents unbounded memory at deep
/// over-saturation; drops are counted, mirroring a finite source FIFO).
const OUTBOX_CAP: usize = 4096;

/// Outstanding invocations a source keeps in flight per target. Matches
/// the 2-deep task-buffer pipelining of the fabric: issuing more would
/// only pile requests into RBs without adding throughput. Arrivals
/// beyond the cap are deferred, making the source semi-open (open up to
/// the cap).
const MAX_OUTSTANDING_PER_HWA: u64 = 2;

/// One invokable accelerator as the source sees it: which interface
/// tile to address and which channel on it.
#[derive(Debug, Clone)]
pub struct OpenLoopTarget {
    /// NoC node of the owning fabric's interface tile.
    pub node: u8,
    /// Channel index on that fabric.
    pub hwa_id: u8,
    pub spec: HwaSpec,
}

pub struct OpenLoopSource {
    pub id: u8,
    pub node: u8,
    targets: Vec<OpenLoopTarget>,
    rate_per_us: f64,
    rng: Pcg32,
    next_arrival: Ps,
    outbox: VecDeque<Flit>,
    builder: PacketBuilder,
    pub requests_issued: u64,
    pub grants_seen: u64,
    pub results_done: u64,
    pub drops: u64,
    /// Request issue times awaiting completion, queued **per target**:
    /// completions are in order within one target (grants are FCFS and
    /// a channel executes serially) but not across targets, so a single
    /// FIFO would cross-attribute latencies between a fast and a slow
    /// accelerator whenever they complete out of issue order.
    issue_times: Vec<VecDeque<Ps>>,
    pub latencies_ps: Vec<u64>,
    /// Outstanding invocations per target (issued - completed).
    outstanding: Vec<u64>,
    /// (hwa_id, stamped origin tile) of the result packet currently
    /// being received.
    rx_head: Option<(u8, Option<u8>)>,
    /// Arrivals deferred because the target HWA was at its cap.
    pub deferred: u64,
    /// Reusable payload-word buffer: refilled per grant so steady-state
    /// payload assembly performs no heap allocation.
    words_scratch: Vec<u32>,
    /// Lost-completion age bound, armed by fault injection. `None` (the
    /// default) leaves the source byte-identical to the fault-free
    /// build: entries wait forever, exactly as before.
    fault_timeout: Option<Ps>,
    /// Earliest instant an outstanding entry can expire (`Ps::MAX` when
    /// unarmed or nothing is in flight) — folded into [`activity`] so
    /// the idle-skipping scheduler cannot leap past a sweep.
    next_sweep: Ps,
    fault_stats: FaultStats,
}

impl OpenLoopSource {
    pub fn new(
        id: u8,
        node: u8,
        targets: Vec<OpenLoopTarget>,
        rate_per_us: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg32::new(seed, id as u64 + 1);
        let mean_gap = PS_PER_US as f64 / rate_per_us.max(1e-9);
        let first = rng.exp(mean_gap) as Ps;
        let n_targets = targets.len();
        let max_words = targets
            .iter()
            .map(|t| t.spec.in_words)
            .max()
            .unwrap_or(0);
        Self {
            id,
            node,
            targets,
            rate_per_us,
            rng,
            next_arrival: first,
            outbox: VecDeque::with_capacity(OUTBOX_CAP),
            builder: PacketBuilder::new(((id as u32) << 20) | 0x10_0000),
            requests_issued: 0,
            grants_seen: 0,
            results_done: 0,
            drops: 0,
            issue_times: vec![
                VecDeque::with_capacity(
                    MAX_OUTSTANDING_PER_HWA as usize + 1
                );
                n_targets
            ],
            // Grows past this in very long runs; sized so steady-state
            // measurement windows stay allocation-free.
            latencies_ps: Vec::with_capacity(4096),
            outstanding: vec![0; n_targets],
            rx_head: None,
            deferred: 0,
            words_scratch: Vec::with_capacity(max_words),
            fault_timeout: None,
            next_sweep: Ps::MAX,
            fault_stats: FaultStats::default(),
        }
    }

    /// Arm the lost-completion sweep. An open-loop source measures an
    /// arrival process, so no policy re-issues work here (that would
    /// distort the injected rate the experiment is sweeping): under any
    /// policy, entries older than `timeout_ps` are counted as `drops`
    /// and their per-target outstanding slot is released. Without the
    /// sweep, a completion lost to a fault wedges its target at the
    /// outstanding cap forever and the issue-time sample leaks.
    pub fn arm_fault_recovery(
        &mut self,
        _policy: RecoveryPolicy,
        timeout_ps: Ps,
    ) {
        self.fault_timeout = Some(timeout_ps.max(1));
    }

    /// Fault counters accumulated by the sweep and NACK handling (all
    /// zero when recovery was never armed).
    pub fn fault_stats(&self) -> FaultStats {
        self.fault_stats
    }

    /// Single-fabric convenience: every spec lives on `fpga_node` with
    /// `hwa_id` = its index (the pre-floorplan constructor shape).
    pub fn single_fabric(
        id: u8,
        node: u8,
        fpga_node: u8,
        specs: Vec<HwaSpec>,
        rate_per_us: f64,
        seed: u64,
    ) -> Self {
        let targets = specs
            .into_iter()
            .enumerate()
            .map(|(i, spec)| OpenLoopTarget {
                node: fpga_node,
                hwa_id: i as u8,
                spec,
            })
            .collect();
        Self::new(id, node, targets, rate_per_us, seed)
    }

    /// True when no flits are queued for injection (scheduler probe).
    pub fn outbox_is_empty(&self) -> bool {
        self.outbox.is_empty()
    }

    /// Time of the next scheduled request arrival — the idle-skipping
    /// scheduler's wakeup when the whole system has drained.
    pub fn next_arrival_at(&self) -> Ps {
        self.next_arrival
    }

    /// Scheduler probe (the [`Activity`] contract): queued flits need
    /// every NoC edge; otherwise nothing happens before the next Poisson
    /// arrival (grants/results re-activate the source via `deliver`,
    /// which only fires while the interconnect is busy anyway).
    pub fn activity(&self) -> Activity {
        if !self.outbox.is_empty() {
            return Activity::Busy;
        }
        let mut act = Activity::NextEventAt(self.next_arrival);
        if self.next_sweep != Ps::MAX {
            act = act.join(Activity::NextEventAt(self.next_sweep));
        }
        act
    }

    /// Target index for an incoming command: by (origin tile, hwa_id)
    /// when the origin was stamped, by hwa_id alone otherwise (single-
    /// fabric traffic and pre-floorplan rigs).
    fn target_index(&self, origin: Option<u8>, hwa_id: u8) -> Option<usize> {
        match origin {
            Some(node) => self
                .targets
                .iter()
                .position(|t| t.node == node && t.hwa_id == hwa_id),
            None => self.targets.iter().position(|t| t.hwa_id == hwa_id),
        }
    }

    /// One NoC/CMP cycle: emit at most one flit.
    pub fn step(&mut self, now: Ps, can_inject: bool) -> Option<Flit> {
        debug_assert_eq!(self.outstanding.len(), self.targets.len());
        self.sweep_lost(now);
        while now >= self.next_arrival {
            let mean_gap = PS_PER_US as f64 / self.rate_per_us.max(1e-9);
            self.next_arrival += self.rng.exp(mean_gap).max(1.0) as Ps;
            let idx = self.rng.range(0, self.targets.len());
            if self.outstanding[idx] >= MAX_OUTSTANDING_PER_HWA {
                self.deferred += 1;
                continue;
            }
            self.outstanding[idx] += 1;
            let target = &self.targets[idx];
            let req = self.builder.command_flit(HeadFields {
                routing: target.node,
                hwa_id: target.hwa_id,
                src_id: self.id,
                direction: Direction::ProcToHwa,
                data_size: ((target.spec.in_words * 4).min(1023)) as u16,
                payload: CommandKind::Request.encode(),
                ..HeadFields::default()
            });
            if self.outbox.len() + 1 <= OUTBOX_CAP {
                self.outbox.push_back(req);
                self.requests_issued += 1;
                self.issue_times[idx].push_back(now);
                if let Some(t) = self.fault_timeout {
                    self.next_sweep = self.next_sweep.min(now + t);
                }
            } else {
                self.drops += 1;
            }
        }
        if can_inject {
            self.outbox.pop_front()
        } else {
            None
        }
    }

    /// Expire issue-time entries older than the armed timeout: each is
    /// a completion the fault layer ate (dropped notify, dead slot, or
    /// hung task). Counting them as `drops` and releasing the slot
    /// un-wedges the per-target cap; fault-free builds never reach here
    /// (`fault_timeout` is `None` and `next_sweep` stays `Ps::MAX`).
    fn sweep_lost(&mut self, now: Ps) {
        let Some(timeout) = self.fault_timeout else { return };
        if now < self.next_sweep {
            return;
        }
        let mut next = Ps::MAX;
        for (idx, q) in self.issue_times.iter_mut().enumerate() {
            while let Some(&t0) = q.front() {
                if now.saturating_sub(t0) < timeout {
                    // Entries behind the front are younger still (FIFO).
                    next = next.min(t0 + timeout);
                    break;
                }
                q.pop_front();
                self.outstanding[idx] =
                    self.outstanding[idx].saturating_sub(1);
                self.drops += 1;
                self.fault_stats.detected += 1;
                self.fault_stats.permanently_failed += 1;
            }
        }
        self.next_sweep = next;
    }

    /// A flit ejected at this node.
    pub fn deliver(&mut self, flit: Flit, now: Ps) {
        if flit.is_head() {
            let h = flit.head_fields();
            if h.pkt_type == PacketType::Payload {
                // Result heads carry the emitting fabric's tile (stamped
                // by the system), disambiguating completions when several
                // fabrics expose the same hwa_ids.
                self.rx_head = Some((h.hwa_id, flit.command_origin()));
            }
            if h.pkt_type == PacketType::Command {
                let origin = flit.command_origin();
                match CommandKind::decode(h.payload) {
                    CommandKind::Grant => {
                        self.grants_seen += 1;
                        self.answer_grant(&h, origin);
                    }
                    CommandKind::Nack => {
                        // The interface rejected our payload (CRC check
                        // failed after a link fault) but kept the
                        // reservation: retransmit into it.
                        self.fault_stats.detected += 1;
                        self.fault_stats.retried += 1;
                        self.answer_grant(&h, origin);
                    }
                    CommandKind::Notify => {
                        self.complete(now, origin, h.hwa_id);
                    }
                    CommandKind::Request => {}
                }
            }
            return;
        }
        if flit.kind() == FlitKind::Tail {
            let (hwa, origin) = self.rx_head.take().unwrap_or((0, None));
            self.complete(now, origin, hwa);
        }
    }

    /// Answer a grant — or a NACK, which re-opens the same reservation —
    /// by building and queueing the input payload for the granted task
    /// buffer.
    fn answer_grant(&mut self, h: &HeadFields, origin: Option<u8>) {
        let Some(idx) = self.target_index(origin, h.hwa_id) else {
            // A grant naming no known target (forged or misrouted):
            // nothing sane to answer.
            return;
        };
        let target = &self.targets[idx];
        let in_words = target.spec.in_words;
        let dest = target.node;
        self.words_scratch.clear();
        for _ in 0..in_words {
            let w = self.rng.next_u32();
            self.words_scratch.push(w);
        }
        // Seq numbers are consumed whether or not the packet fits
        // (matching the build-then-drop behaviour this path used to
        // have).
        let fits =
            self.outbox.len() + payload_packet_flits(in_words) <= OUTBOX_CAP;
        let outbox = &mut self.outbox;
        self.builder.payload_with(
            HeadFields {
                routing: dest,
                hwa_id: h.hwa_id,
                src_id: self.id,
                tb_id: h.tb_id,
                task_head: true,
                task_tail: true,
                direction: Direction::ProcToHwa,
                ..HeadFields::default()
            },
            &self.words_scratch,
            |f| {
                if fits {
                    outbox.push_back(f);
                }
            },
        );
        if !fits {
            self.drops += 1;
        }
    }

    fn complete(&mut self, now: Ps, origin: Option<u8>, hwa_id: u8) {
        self.results_done += 1;
        // Prefer a matching target that actually has work outstanding
        // (several fabrics may share an hwa_id); fall back to the first
        // match so single-fabric accounting is saturating, as before.
        let origin_ok = |t: &OpenLoopTarget| match origin {
            Some(o) => t.node == o,
            None => true,
        };
        let idx = self
            .targets
            .iter()
            .enumerate()
            .position(|(i, t)| {
                t.hwa_id == hwa_id
                    && origin_ok(t)
                    && self.outstanding.get(i).copied().unwrap_or(0) > 0
            })
            .or_else(|| self.target_index(origin, hwa_id));
        if let Some(o) = idx.and_then(|i| self.outstanding.get_mut(i)) {
            *o = o.saturating_sub(1);
        }
        // Pop the matched target's queue. A completion that resolves to
        // no target (or to one with no sample left — forged traffic)
        // falls back to the oldest sample anywhere, keeping aggregate
        // accounting saturating as before.
        let t0 = match idx {
            Some(i) if !self.issue_times[i].is_empty() => {
                self.issue_times[i].pop_front()
            }
            _ => self
                .issue_times
                .iter_mut()
                .filter(|q| !q.is_empty())
                .min_by_key(|q| *q.front().unwrap())
                .and_then(|q| q.pop_front()),
        };
        if let Some(t0) = t0 {
            self.latencies_ps.push(now.saturating_sub(t0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::hwa::spec_by_name;

    #[test]
    fn issues_requests_up_to_outstanding_cap() {
        let specs = vec![spec_by_name("izigzag").unwrap()];
        let mut src = OpenLoopSource::single_fabric(0, 0, 8, specs, 4.0, 7);
        let mut flits = 0;
        for c in 0..10_000u64 {
            if src.step(c * 1000, true).is_some() {
                flits += 1;
            }
        }
        // One HWA, never completing: capped at MAX_OUTSTANDING_PER_HWA,
        // further arrivals deferred.
        assert_eq!(src.requests_issued, MAX_OUTSTANDING_PER_HWA);
        assert_eq!(flits as u64, src.requests_issued);
        assert!(src.deferred > 10, "deferred {}", src.deferred);
    }

    #[test]
    fn completion_reopens_the_cap() {
        let specs = vec![spec_by_name("izigzag").unwrap()];
        let mut src = OpenLoopSource::single_fabric(0, 0, 8, specs, 4.0, 7);
        let mut issued = 0;
        for c in 0..10_000u64 {
            let now = c * 1000;
            if src.step(now, true).is_some() {
                issued += 1;
            }
            // Simulate completions: notify packets.
            if c % 1000 == 999 {
                let mut b = PacketBuilder::new(77);
                let n = b.command(HeadFields {
                    hwa_id: 0,
                    payload: CommandKind::Notify.encode(),
                    ..HeadFields::default()
                });
                src.deliver(n.flits[0], now);
            }
        }
        assert!(issued > MAX_OUTSTANDING_PER_HWA, "issued {issued}");
        assert_eq!(src.results_done, 10);
    }

    #[test]
    fn grant_triggers_payload_without_waiting_result() {
        let specs = vec![spec_by_name("dfadd").unwrap()];
        let mut src = OpenLoopSource::single_fabric(1, 0, 8, specs, 1.0, 9);
        let mut b = PacketBuilder::new(50);
        let grant = b.command(HeadFields {
            hwa_id: 0,
            src_id: 1,
            tb_id: 1,
            payload: CommandKind::Grant.encode(),
            ..HeadFields::default()
        });
        src.deliver(grant.flits[0], 100);
        assert_eq!(src.grants_seen, 1);
        let mut got = Vec::new();
        for c in 1..100u64 {
            if let Some(f) = src.step(c, true) {
                got.push(f);
            }
        }
        assert!(got.iter().any(|f| f.is_head()
            && f.head_fields().pkt_type == PacketType::Payload));
    }

    #[test]
    fn multi_target_completions_attribute_latency_per_target() {
        // A fast and a slow accelerator complete out of issue order;
        // each latency sample must pair with its own target's issue
        // time, not with the globally oldest one (the regression the
        // old single-FIFO bookkeeping had).
        let specs = vec![
            spec_by_name("dfadd").unwrap(),
            spec_by_name("izigzag").unwrap(),
        ];
        let mut src = OpenLoopSource::single_fabric(0, 0, 8, specs, 8.0, 7);
        let mut now = 0;
        while src.outstanding.iter().any(|&o| o == 0) {
            now += 1000;
            src.step(now, true);
            assert!(now < 1_000_000_000, "targets never both occupied");
        }
        let t0_fast = *src.issue_times[0].front().unwrap();
        let t0_slow = *src.issue_times[1].front().unwrap();
        let mut b = PacketBuilder::new(77);
        // Target 1 completes first, then target 0.
        for (hwa, at) in [(1u8, now + 10_000), (0u8, now + 20_000)] {
            let n = b.command(HeadFields {
                hwa_id: hwa,
                payload: CommandKind::Notify.encode(),
                ..HeadFields::default()
            });
            src.deliver(n.flits[0], at);
        }
        assert_eq!(
            src.latencies_ps,
            vec![now + 10_000 - t0_slow, now + 20_000 - t0_fast]
        );
    }

    #[test]
    fn stamped_grant_routes_payload_to_the_granting_fabric() {
        // Two fabrics both expose hwa_id 0 (nodes 2 and 8): the payload
        // must answer the tile the grant came from, disambiguated by the
        // origin stamp.
        let spec = spec_by_name("dfadd").unwrap();
        let targets = vec![
            OpenLoopTarget {
                node: 2,
                hwa_id: 0,
                spec: spec.clone(),
            },
            OpenLoopTarget {
                node: 8,
                hwa_id: 0,
                spec,
            },
        ];
        let mut src = OpenLoopSource::new(1, 0, targets, 1.0, 9);
        let mut b = PacketBuilder::new(50);
        let grant = b.command(HeadFields {
            hwa_id: 0,
            src_id: 1,
            payload: CommandKind::Grant.encode(),
            ..HeadFields::default()
        });
        let mut flit = grant.flits[0];
        flit.stamp_origin(8);
        src.deliver(flit, 100);
        let mut heads = Vec::new();
        for c in 1..100u64 {
            if let Some(f) = src.step(c, true) {
                if f.is_head() {
                    heads.push(f.head_fields());
                }
            }
        }
        let payload = heads
            .iter()
            .find(|h| h.pkt_type == PacketType::Payload)
            .expect("payload sent");
        assert_eq!(payload.routing, 8, "answers the granting fabric");
    }

    #[test]
    fn armed_sweep_unwedges_a_target_with_lost_completions() {
        // Regression for the silent wedge: with completions lost (no
        // deliver() ever called), an unarmed source stops issuing
        // forever once every target hits the outstanding cap, leaking
        // the issue-time entries. The armed sweep must expire them,
        // count each as dropped, and let new requests flow.
        let specs = vec![spec_by_name("izigzag").unwrap()];
        let mut src = OpenLoopSource::single_fabric(0, 0, 8, specs, 4.0, 7);
        src.arm_fault_recovery(RecoveryPolicy::RetryFailover, 1_000_000);
        for c in 0..10_000u64 {
            src.step(c * 1000, true);
        }
        assert!(
            src.requests_issued > MAX_OUTSTANDING_PER_HWA,
            "sweep never released the cap: issued {}",
            src.requests_issued
        );
        let st = src.fault_stats();
        assert!(st.detected > 0 && st.permanently_failed == st.detected);
        // Lost entries became drops (outbox never fills here), and the
        // in-flight bookkeeping stays bounded instead of leaking.
        assert_eq!(src.drops, st.permanently_failed);
        let queued: usize =
            src.issue_times.iter().map(|q| q.len()).sum();
        assert!(
            queued as u64 <= MAX_OUTSTANDING_PER_HWA,
            "issue-time entries leaked: {queued}"
        );
        assert!(src.outstanding.iter().all(|&o| o <= MAX_OUTSTANDING_PER_HWA));
    }

    #[test]
    fn unarmed_source_never_sweeps() {
        // Fault-free builds must behave byte-identically to the old
        // code: no timeout, no sweep, wedge preserved (the fix is gated
        // on arming so `fault.spec = "none"` artifacts stay bit-exact).
        let specs = vec![spec_by_name("izigzag").unwrap()];
        let mut src = OpenLoopSource::single_fabric(0, 0, 8, specs, 4.0, 7);
        for c in 0..10_000u64 {
            src.step(c * 1000, true);
        }
        assert_eq!(src.requests_issued, MAX_OUTSTANDING_PER_HWA);
        assert_eq!(src.drops, 0);
        assert!(!src.fault_stats().any());
    }
}
