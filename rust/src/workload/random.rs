//! Rate-controlled random request workload (paper §6.4): each processor
//! randomly sends requests to specific HWAs under a configurable request
//! frequency (Poisson arrivals per processor).
//!
//! This closed-loop driver blocks each processor on its in-flight
//! invocation. The open-loop variant the Fig. 8 sweeps use lives in
//! `workload::openloop` and is measured by `sweep::run_scenario`.

use crate::clock::{Ps, PS_PER_US};
use crate::cmp::core::{InvokeSpec, Segment};
use crate::sim::system::System;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct RandomWorkloadConfig {
    /// Aggregate request frequency across all processors (requests/µs).
    pub total_rate_per_us: f64,
    pub seed: u64,
}

pub struct RandomWorkload {
    cfg: RandomWorkloadConfig,
    next_arrival: Vec<Ps>,
    rng: Pcg32,
    pub issued: u64,
}

impl RandomWorkload {
    pub fn new(cfg: RandomWorkloadConfig, n_procs: usize) -> Self {
        let mut rng = Pcg32::seeded(cfg.seed);
        let per_proc = cfg.total_rate_per_us / n_procs as f64;
        let mean_gap_ps = PS_PER_US as f64 / per_proc.max(1e-9);
        let next_arrival = (0..n_procs)
            .map(|_| rng.exp(mean_gap_ps) as Ps)
            .collect();
        Self {
            cfg,
            next_arrival,
            rng,
            issued: 0,
        }
    }

    /// Called periodically: enqueue new invocations on idle processors
    /// whose next arrival time has come.
    pub fn drive(&mut self, sys: &mut System, now: Ps) {
        let per_proc =
            self.cfg.total_rate_per_us / sys.n_procs() as f64;
        let mean_gap_ps = PS_PER_US as f64 / per_proc.max(1e-9);
        for i in 0..sys.n_procs() {
            if now >= self.next_arrival[i] && sys.procs[i].done() {
                let n_hwas = sys.config.specs.len();
                let hwa = self.rng.range(0, n_hwas);
                let spec = &sys.config.specs[hwa];
                let words: Vec<u32> = (0..spec.in_words)
                    .map(|_| self.rng.next_u32())
                    .collect();
                let expect = spec.out_words;
                sys.load_program(
                    i,
                    vec![Segment::Invoke(InvokeSpec::direct(
                        hwa as u8, words, expect,
                    ))],
                );
                self.issued += 1;
                self.next_arrival[i] = now + self.rng.exp(mean_gap_ps) as Ps;
            }
        }
    }
}

/// Run a rate point: warmup, then measure injection/throughput over the
/// window. Returns (injection flits/µs, throughput flits/µs, busy frac,
/// completed invocations/µs).
pub fn measure_rate_point(
    sys: &mut System,
    workload: &mut RandomWorkload,
    warmup_us: u64,
    window_us: u64,
) -> RatePoint {
    let drive_every = 200_000; // 0.2 µs granularity for arrivals
    let mut next_drive = 0;
    let warmup_end = sys.now() + warmup_us * PS_PER_US;
    while sys.now() < warmup_end {
        let t = sys.step();
        if t >= next_drive {
            workload.drive(sys, t);
            next_drive = t + drive_every;
        }
    }
    let (in0, out0) = sys.fabric.flits_in_out();
    let done0: usize = sys.procs.iter().map(|p| p.invocations_done()).sum();
    let (busy0, cyc0) = match &sys.fabric {
        crate::sim::system::Fabric::Buffered(f) => {
            (f.stats.busy_iface_cycles, f.stats.iface_cycles)
        }
        _ => (0, 1),
    };
    let end = sys.now() + window_us * PS_PER_US;
    while sys.now() < end {
        let t = sys.step();
        if t >= next_drive {
            workload.drive(sys, t);
            next_drive = t + drive_every;
        }
    }
    let (in1, out1) = sys.fabric.flits_in_out();
    let done1: usize = sys.procs.iter().map(|p| p.invocations_done()).sum();
    let (busy1, cyc1) = match &sys.fabric {
        crate::sim::system::Fabric::Buffered(f) => {
            (f.stats.busy_iface_cycles, f.stats.iface_cycles)
        }
        _ => (0, 1),
    };
    RatePoint {
        injection_flits_per_us: (in1 - in0) as f64 / window_us as f64,
        throughput_flits_per_us: (out1 - out0) as f64 / window_us as f64,
        busy_fraction: if cyc1 > cyc0 {
            (busy1 - busy0) as f64 / (cyc1 - cyc0) as f64
        } else {
            0.0
        },
        completions_per_us: (done1 - done0) as f64 / window_us as f64,
    }
}

#[derive(Debug, Clone, Copy)]
pub struct RatePoint {
    pub injection_flits_per_us: f64,
    pub throughput_flits_per_us: f64,
    pub busy_fraction: f64,
    pub completions_per_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::hwa::spec_by_name;
    use crate::sim::system::SystemConfig;

    #[test]
    fn workload_issues_requests_at_rate() {
        let cfg = SystemConfig::paper(vec![spec_by_name("izigzag").unwrap(); 8]);
        let mut sys = System::new(cfg);
        let mut wl = RandomWorkload::new(
            RandomWorkloadConfig {
                total_rate_per_us: 2.0,
                seed: 1,
            },
            sys.n_procs(),
        );
        let p = measure_rate_point(&mut sys, &mut wl, 5, 20);
        // 2 requests/µs * 17-flit payloads + commands: injection well
        // above zero and throughput within a factor of the injection.
        assert!(p.injection_flits_per_us > 5.0, "{p:?}");
        assert!(p.throughput_flits_per_us > 5.0, "{p:?}");
        assert!(p.completions_per_us > 0.5, "{p:?}");
    }

    #[test]
    fn higher_rate_higher_injection_until_saturation() {
        let mk = || {
            let cfg =
                SystemConfig::paper(vec![spec_by_name("izigzag").unwrap(); 8]);
            System::new(cfg)
        };
        let mut lo_sys = mk();
        let mut lo_wl = RandomWorkload::new(
            RandomWorkloadConfig {
                total_rate_per_us: 0.5,
                seed: 2,
            },
            lo_sys.n_procs(),
        );
        let lo = measure_rate_point(&mut lo_sys, &mut lo_wl, 5, 20);
        let mut hi_sys = mk();
        let mut hi_wl = RandomWorkload::new(
            RandomWorkloadConfig {
                total_rate_per_us: 4.0,
                seed: 2,
            },
            hi_sys.n_procs(),
        );
        let hi = measure_rate_point(&mut hi_sys, &mut hi_wl, 5, 20);
        assert!(hi.injection_flits_per_us > lo.injection_flits_per_us);
    }
}
