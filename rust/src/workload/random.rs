//! Rate-controlled random request workload (paper §6.4): each processor
//! randomly sends requests to specific HWAs under a configurable request
//! frequency (Poisson arrivals per processor).
//!
//! This closed-loop driver blocks each processor on its in-flight
//! invocation and submits every request through the typed driver layer
//! ([`AccelRuntime`]). The open-loop variant the Fig. 8 sweeps use lives
//! in `workload::openloop` and is measured by `sweep::run_scenario`.

use crate::accel::{AccelRuntime, Job};
use crate::clock::{Ps, PS_PER_US};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct RandomWorkloadConfig {
    /// Aggregate request frequency across all processors (requests/µs).
    pub total_rate_per_us: f64,
    pub seed: u64,
}

pub struct RandomWorkload {
    cfg: RandomWorkloadConfig,
    next_arrival: Vec<Ps>,
    rng: Pcg32,
    /// Fabric-major handle list, cached on first `drive` (the system's
    /// inventory cannot change mid-run).
    accels: Vec<crate::accel::AccelHandle>,
    pub issued: u64,
}

impl RandomWorkload {
    pub fn new(cfg: RandomWorkloadConfig, n_procs: usize) -> Self {
        let mut rng = Pcg32::seeded(cfg.seed);
        let per_proc = cfg.total_rate_per_us / n_procs as f64;
        let mean_gap_ps = PS_PER_US as f64 / per_proc.max(1e-9);
        let next_arrival = (0..n_procs)
            .map(|_| rng.exp(mean_gap_ps) as Ps)
            .collect();
        Self {
            cfg,
            next_arrival,
            rng,
            accels: Vec::new(),
            issued: 0,
        }
    }

    /// Called periodically: submit new jobs on idle cores whose next
    /// arrival time has come.
    pub fn drive(&mut self, rt: &mut AccelRuntime, now: Ps) {
        let per_proc = self.cfg.total_rate_per_us / rt.n_cores() as f64;
        let mean_gap_ps = PS_PER_US as f64 / per_proc.max(1e-9);
        if self.accels.is_empty() {
            self.accels = rt.accels();
        }
        for core in 0..rt.n_cores() {
            if now >= self.next_arrival[core] && rt.core_done(core) {
                // Uniform over every accelerator of every fabric
                // (fabric-major); single-fabric systems draw the exact
                // legacy channel sequence.
                let handle =
                    self.accels[self.rng.range(0, self.accels.len())];
                let words: Vec<u32> = (0..handle.in_words())
                    .map(|_| self.rng.next_u32())
                    .collect();
                rt.submit(core, Job::on(handle).direct(words))
                    .expect("random workload jobs are always valid");
                self.issued += 1;
                self.next_arrival[core] =
                    now + self.rng.exp(mean_gap_ps) as Ps;
            }
        }
    }
}

/// Run a rate point: warmup, then measure injection/throughput over the
/// window. Returns (injection flits/µs, throughput flits/µs, busy frac,
/// completed invocations/µs).
pub fn measure_rate_point(
    rt: &mut AccelRuntime,
    workload: &mut RandomWorkload,
    warmup_us: u64,
    window_us: u64,
) -> RatePoint {
    let drive_every = 200_000; // 0.2 µs granularity for arrivals
    let mut next_drive = 0;
    let warmup_end = rt.now() + warmup_us * PS_PER_US;
    while rt.now() < warmup_end {
        let t = rt.step();
        if t >= next_drive {
            workload.drive(rt, t);
            next_drive = t + drive_every;
        }
    }
    let (in0, out0) = rt.system().flits_in_out();
    let done0 = rt.invocations_done();
    let (busy0, cyc0) = rt.system().iface_busy();
    let end = rt.now() + window_us * PS_PER_US;
    while rt.now() < end {
        let t = rt.step();
        if t >= next_drive {
            workload.drive(rt, t);
            next_drive = t + drive_every;
        }
    }
    let (in1, out1) = rt.system().flits_in_out();
    let done1 = rt.invocations_done();
    let (busy1, cyc1) = rt.system().iface_busy();
    RatePoint {
        injection_flits_per_us: (in1 - in0) as f64 / window_us as f64,
        throughput_flits_per_us: (out1 - out0) as f64 / window_us as f64,
        busy_fraction: if cyc1 > cyc0 {
            (busy1 - busy0) as f64 / (cyc1 - cyc0) as f64
        } else {
            0.0
        },
        completions_per_us: (done1 - done0) as f64 / window_us as f64,
    }
}

#[derive(Debug, Clone, Copy)]
pub struct RatePoint {
    pub injection_flits_per_us: f64,
    pub throughput_flits_per_us: f64,
    pub busy_fraction: f64,
    pub completions_per_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::hwa::spec_by_name;
    use crate::sim::system::SystemConfig;

    #[test]
    fn workload_issues_requests_at_rate() {
        let cfg = SystemConfig::paper(vec![spec_by_name("izigzag").unwrap(); 8]);
        let mut rt = AccelRuntime::new(cfg);
        let mut wl = RandomWorkload::new(
            RandomWorkloadConfig {
                total_rate_per_us: 2.0,
                seed: 1,
            },
            rt.n_cores(),
        );
        let p = measure_rate_point(&mut rt, &mut wl, 5, 20);
        // 2 requests/µs * 17-flit payloads + commands: injection well
        // above zero and throughput within a factor of the injection.
        assert!(p.injection_flits_per_us > 5.0, "{p:?}");
        assert!(p.throughput_flits_per_us > 5.0, "{p:?}");
        assert!(p.completions_per_us > 0.5, "{p:?}");
    }

    #[test]
    fn higher_rate_higher_injection_until_saturation() {
        let mk = || {
            let cfg =
                SystemConfig::paper(vec![spec_by_name("izigzag").unwrap(); 8]);
            AccelRuntime::new(cfg)
        };
        let mut lo_rt = mk();
        let mut lo_wl = RandomWorkload::new(
            RandomWorkloadConfig {
                total_rate_per_us: 0.5,
                seed: 2,
            },
            lo_rt.n_cores(),
        );
        let lo = measure_rate_point(&mut lo_rt, &mut lo_wl, 5, 20);
        let mut hi_rt = mk();
        let mut hi_wl = RandomWorkload::new(
            RandomWorkloadConfig {
                total_rate_per_us: 4.0,
                seed: 2,
            },
            hi_rt.n_cores(),
        );
        let hi = measure_rate_point(&mut hi_rt, &mut hi_wl, 5, 20);
        assert!(hi.injection_flits_per_us > lo.injection_flits_per_us);
    }
}
