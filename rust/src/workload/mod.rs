//! Workload generators: random rate-driven requests (§6.4, closed- and
//! open-loop) and real JPEG coefficient blocks (§6.6 / end-to-end
//! example). The `sweep` module composes these into declarative
//! scenarios; see `WorkloadSpec` there for the catalogue.

pub mod jpeg;
pub mod openloop;
pub mod random;
pub mod serving;

pub use jpeg::BlockImage;
pub use openloop::{OpenLoopSource, OpenLoopTarget};
pub use serving::{
    ArrivalProcess, JobKind, JobMix, ServingSource, ServingTarget,
    TenantSpec, TenantState,
};
pub use random::{measure_rate_point, RandomWorkload, RandomWorkloadConfig, RatePoint};
