//! Workload generators: random rate-driven requests (§6.4) and real
//! JPEG coefficient blocks (§6.6 / end-to-end example).

pub mod jpeg;
pub mod openloop;
pub mod random;

pub use jpeg::BlockImage;
pub use random::{measure_rate_point, RandomWorkload, RandomWorkloadConfig, RatePoint};
