//! Traffic tracing and flow-invariant checking for the NoC.
//!
//! [`FlowTracker`] asserts the properties every higher layer relies on:
//! per-flow in-order delivery, no duplication, no loss; plus latency
//! accounting used by the experiment drivers.

use std::collections::BTreeMap;

use crate::clock::Ps;
use crate::flit::Flit;
use crate::util::stats::Accum;

#[derive(Debug, Default)]
struct FlowState {
    sent: u32,
    received: u32,
    next_seq_base: Option<u32>,
}

#[derive(Debug, Default)]
pub struct FlowTracker {
    flows: BTreeMap<u32, FlowState>,
    pub latency: Accum,
    violations: Vec<String>,
}

impl FlowTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_inject(&mut self, flit: &mut Flit, now: Ps) {
        flit.meta.injected_ps = now;
        let st = self.flows.entry(flit.meta.flow).or_default();
        st.sent += 1;
    }

    pub fn on_eject(&mut self, flit: &Flit, now: Ps) {
        let st = self.flows.entry(flit.meta.flow).or_default();
        st.received += 1;
        if st.received > st.sent {
            self.violations.push(format!(
                "flow {}: duplication ({} received > {} sent)",
                flit.meta.flow, st.received, st.sent
            ));
        }
        // Sequence monotonicity within the flow.
        match st.next_seq_base {
            None => st.next_seq_base = Some(flit.meta.seq + 1),
            Some(expected) => {
                if flit.meta.seq < expected {
                    self.violations.push(format!(
                        "flow {}: reorder/dup (seq {} after {})",
                        flit.meta.flow,
                        flit.meta.seq,
                        expected - 1
                    ));
                }
                st.next_seq_base = Some(flit.meta.seq + 1);
            }
        }
        if now >= flit.meta.injected_ps {
            self.latency.push((now - flit.meta.injected_ps) as f64);
        }
    }

    /// Flits still unaccounted for (sent - received) across all flows.
    pub fn outstanding(&self) -> u64 {
        self.flows
            .values()
            .map(|s| (s.sent - s.received) as u64)
            .sum()
    }

    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    pub fn assert_clean(&self) {
        assert!(
            self.violations.is_empty(),
            "flow invariant violations: {:?}",
            self.violations
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{FlitMeta, Flit};

    fn flit(flow: u32, seq: u32) -> Flit {
        Flit {
            meta: FlitMeta {
                flow,
                seq,
                injected_ps: 0,
            },
            ..Flit::default()
        }
    }

    #[test]
    fn in_order_flow_is_clean() {
        let mut t = FlowTracker::new();
        for seq in 0..5 {
            let mut f = flit(1, seq);
            t.on_inject(&mut f, 100);
            t.on_eject(&f, 200);
        }
        t.assert_clean();
        assert_eq!(t.outstanding(), 0);
        assert_eq!(t.latency.count(), 5);
    }

    #[test]
    fn reorder_is_flagged() {
        let mut t = FlowTracker::new();
        let mut a = flit(1, 0);
        let mut b = flit(1, 1);
        t.on_inject(&mut a, 0);
        t.on_inject(&mut b, 0);
        t.on_eject(&b, 10);
        t.on_eject(&a, 20);
        assert!(!t.violations().is_empty());
    }

    #[test]
    fn duplication_is_flagged() {
        let mut t = FlowTracker::new();
        let mut a = flit(2, 0);
        t.on_inject(&mut a, 0);
        t.on_eject(&a, 10);
        t.on_eject(&a, 20);
        assert!(!t.violations().is_empty());
    }

    #[test]
    fn outstanding_counts_in_flight() {
        let mut t = FlowTracker::new();
        let mut a = flit(3, 0);
        t.on_inject(&mut a, 0);
        assert_eq!(t.outstanding(), 1);
        t.on_eject(&a, 5);
        assert_eq!(t.outstanding(), 0);
    }
}
