//! Cycle-level 5-port mesh router: XY dimension-ordered routing, virtual
//! output queues (VOQs), wormhole packet locking, credit-based flow
//! control — the externally visible properties of the CONNECT NoC the
//! paper prototypes (§6.1), per DESIGN.md substitution 5.
//!
//! Pipeline model: one cycle per hop (route-compute + switch allocation +
//! traversal collapsed into the allocation step, as in CONNECT's
//! low-latency single-stage configuration); credits return to the upstream
//! router one cycle after a flit departs an input buffer.
//!
//! Hot-path layout (§Perf): the 25 VOQs are fixed-capacity **inline ring
//! buffers** (`VoqRing`) embedded directly in the router struct — no
//! per-queue heap allocation, no pointer chasing — and the router
//! maintains occupancy/lock counters so [`Router::is_active`] answers in
//! O(1) whether stepping it this cycle can do anything at all. The mesh
//! uses that to visit only active routers (`noc/mesh.rs`).

use crate::flit::Flit;

pub const PORTS: usize = 5;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Port {
    Local = 0,
    North = 1,
    East = 2,
    South = 3,
    West = 4,
}

impl Port {
    pub fn from_index(i: usize) -> Port {
        match i {
            0 => Port::Local,
            1 => Port::North,
            2 => Port::East,
            3 => Port::South,
            _ => Port::West,
        }
    }

    /// The port on the neighbouring router that receives what we send.
    pub fn opposite(self) -> Port {
        match self {
            Port::Local => Port::Local,
            Port::North => Port::South,
            Port::East => Port::West,
            Port::South => Port::North,
            Port::West => Port::East,
        }
    }
}

/// Per-input-port buffer capacity in flits (shared across that input's
/// VOQs). CONNECT's default virtual-output-queued router uses shallow
/// per-port buffers; 8 flits is representative and is swept in tests.
pub const DEFAULT_IN_BUF: u32 = 8;

/// Inline VOQ ring capacity in flits. Per-input occupancy is credit-bound
/// by `in_buf_cap <= VOQ_RING_CAP` (asserted in [`Router::new`]), so no
/// individual (input, output) ring can ever overflow it.
pub const VOQ_RING_CAP: usize = DEFAULT_IN_BUF as usize;

/// One virtual output queue: a fixed-capacity ring of flits stored inline
/// (no heap). `Flit` is `Copy`, so push/pop are plain array writes.
#[derive(Debug, Clone, Copy, Default)]
struct VoqRing {
    slots: [Flit; VOQ_RING_CAP],
    head: u8,
    len: u8,
}

impl VoqRing {
    #[inline]
    fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn front(&self) -> Option<&Flit> {
        if self.len == 0 {
            None
        } else {
            Some(&self.slots[self.head as usize])
        }
    }

    #[inline]
    fn push_back(&mut self, flit: Flit) {
        // Hard cap even in release builds (same reasoning as the mesh's
        // eject assert): credits make this unreachable, and a silent
        // wrap-around would overwrite a buffered flit undetectably.
        assert!((self.len as usize) < VOQ_RING_CAP, "VOQ ring overflow");
        let tail = (self.head as usize + self.len as usize) % VOQ_RING_CAP;
        self.slots[tail] = flit;
        self.len += 1;
    }

    #[inline]
    fn pop_front(&mut self) -> Option<Flit> {
        if self.len == 0 {
            return None;
        }
        let flit = self.slots[self.head as usize];
        self.head = ((self.head as usize + 1) % VOQ_RING_CAP) as u8;
        self.len -= 1;
        Some(flit)
    }
}

/// A single selected flit movement for this cycle.
#[derive(Debug, Clone)]
pub struct Move {
    pub in_port: usize,
    pub out_port: usize,
    pub flit: Flit,
}

#[derive(Debug)]
pub struct Router {
    pub id: u8,
    pub x: u8,
    pub y: u8,
    /// voq[in][out]: inline rings, no heap (§Perf).
    voq: [[VoqRing; PORTS]; PORTS],
    /// Occupancy per input port (sum over VOQs), for credit accounting.
    in_occupancy: [u32; PORTS],
    /// Occupancy per output port (sum over that output's VOQs): lets
    /// allocation skip idle outputs without scanning five queues (§Perf).
    out_occupancy: [u32; PORTS],
    /// Total buffered flits (sum of `in_occupancy`), maintained so
    /// [`Router::is_active`]/[`Router::buffered`] are O(1).
    buffered: u32,
    in_buf_cap: u32,
    /// Wormhole lock per output: input port owning the output mid-packet.
    out_lock: [Option<usize>; PORTS],
    /// Number of held wormhole locks (maintained for `is_active`).
    locks_held: u8,
    /// Round-robin pointer per output.
    rr: [usize; PORTS],
    /// Credits per output link = free slots downstream.
    pub credits: [u32; PORTS],
    /// Stats.
    pub flits_routed: u64,
}

impl Router {
    pub fn new(id: u8, x: u8, y: u8, in_buf_cap: u32, out_credits: [u32; PORTS]) -> Self {
        assert!(
            in_buf_cap as usize <= VOQ_RING_CAP,
            "in_buf_cap {in_buf_cap} exceeds the inline VOQ ring capacity \
             {VOQ_RING_CAP} (raise VOQ_RING_CAP to sweep deeper buffers)"
        );
        Self {
            id,
            x,
            y,
            voq: [[VoqRing::default(); PORTS]; PORTS],
            in_occupancy: [0; PORTS],
            out_occupancy: [0; PORTS],
            buffered: 0,
            in_buf_cap,
            out_lock: [None; PORTS],
            locks_held: 0,
            rr: [0; PORTS],
            credits: out_credits,
            flits_routed: 0,
        }
    }

    /// XY dimension-ordered route: X first, then Y, then Local.
    pub fn route(&self, dest_x: u8, dest_y: u8) -> usize {
        if dest_x > self.x {
            Port::East as usize
        } else if dest_x < self.x {
            Port::West as usize
        } else if dest_y > self.y {
            Port::South as usize
        } else if dest_y < self.y {
            Port::North as usize
        } else {
            Port::Local as usize
        }
    }

    pub fn can_accept(&self, in_port: usize) -> bool {
        self.in_occupancy[in_port] < self.in_buf_cap
    }

    pub fn input_occupancy(&self, in_port: usize) -> u32 {
        self.in_occupancy[in_port]
    }

    /// Buffer an arriving flit at `in_port` (route-compute into the VOQ).
    /// Caller must have checked `can_accept` (credits guarantee it).
    pub fn accept(&mut self, in_port: usize, flit: Flit, mesh_w: u8) {
        let dest = flit.dest();
        let (dx, dy) = (dest % mesh_w, dest / mesh_w);
        let out = self.route(dx, dy);
        self.in_occupancy[in_port] += 1;
        self.out_occupancy[out] += 1;
        self.buffered += 1;
        self.voq[in_port][out].push_back(flit);
        debug_assert!(
            self.in_occupancy[in_port] <= self.in_buf_cap,
            "router {} input {in_port} overflow",
            self.id
        );
    }

    /// Switch allocation for one cycle: pick at most one flit per output
    /// (and at most one per input), respecting wormhole locks and credits.
    /// Returns the moves.
    #[cfg(test)]
    pub fn allocate(&mut self) -> Vec<Move> {
        let mut moves = Vec::new();
        self.allocate_into(0, &mut |_, m| moves.push(m));
        moves
    }

    /// Allocation without per-cycle allocation: emits each move through
    /// `sink(tag, move)`. Early-exits when the router holds no flits —
    /// the common case on a lightly loaded mesh (hot path, §Perf).
    #[inline]
    pub fn allocate_into(
        &mut self,
        tag: usize,
        sink: &mut impl FnMut(usize, Move),
    ) {
        if self.buffered == 0 {
            return;
        }
        let mut input_used = [false; PORTS];
        for out in 0..PORTS {
            if self.credits[out] == 0 || self.out_occupancy[out] == 0 {
                continue;
            }
            let chosen_in = match self.out_lock[out] {
                Some(locked) => {
                    if input_used[locked] || self.voq[locked][out].is_empty() {
                        None
                    } else {
                        Some(locked)
                    }
                }
                None => {
                    // Round-robin over inputs with a packet *head* waiting.
                    let mut found = None;
                    for k in 0..PORTS {
                        let inp = (self.rr[out] + k) % PORTS;
                        if input_used[inp] {
                            continue;
                        }
                        if let Some(f) = self.voq[inp][out].front() {
                            if f.is_head() {
                                found = Some(inp);
                                break;
                            }
                            // A non-head at queue front without a lock can
                            // only be the continuation of a packet whose
                            // lock was released by a tail we already sent —
                            // impossible; packets are contiguous per VOQ.
                            debug_assert!(
                                false,
                                "orphan body flit at router {} in {inp} out {out}",
                                self.id
                            );
                        }
                    }
                    if let Some(inp) = found {
                        self.rr[out] = (inp + 1) % PORTS;
                    }
                    found
                }
            };
            if let Some(inp) = chosen_in {
                let flit = self.voq[inp][out].pop_front().expect("nonempty");
                input_used[inp] = true;
                self.credits[out] -= 1;
                self.in_occupancy[inp] -= 1;
                self.out_occupancy[out] -= 1;
                self.buffered -= 1;
                self.flits_routed += 1;
                if flit.is_head() && !flit.is_tail() {
                    debug_assert!(self.out_lock[out].is_none());
                    self.out_lock[out] = Some(inp);
                    self.locks_held += 1;
                } else if flit.is_tail() && self.out_lock[out].take().is_some() {
                    self.locks_held -= 1;
                }
                sink(
                    tag,
                    Move {
                        in_port: inp,
                        out_port: out,
                        flit,
                    },
                );
            }
        }
    }

    /// Return one credit for output `out` (a downstream slot freed).
    pub fn return_credit(&mut self, out: usize) {
        self.credits[out] += 1;
    }

    /// Total buffered flits (for drain checks). O(1): maintained counter.
    pub fn buffered(&self) -> u32 {
        self.buffered
    }

    /// Can stepping this router this cycle do anything at all? True when
    /// flits are buffered or a wormhole lock is held mid-packet (the lock
    /// keeps the router on the mesh's active worklist until its packet's
    /// tail has passed — see the activation/retirement contract in
    /// docs/ARCHITECTURE.md). O(1): derived from maintained state.
    pub fn is_active(&self) -> bool {
        self.buffered > 0 || self.locks_held > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{FlitKind, HeadFields, PacketBuilder};

    fn head_flit(dest: u8) -> Flit {
        let mut b = PacketBuilder::new(1);
        b.command(HeadFields {
            routing: dest,
            ..HeadFields::default()
        })
        .flits[0]
    }

    #[test]
    fn xy_routing_order() {
        let r = Router::new(4, 1, 1, 8, [8; PORTS]); // center of 3x3
        assert_eq!(r.route(2, 1), Port::East as usize);
        assert_eq!(r.route(0, 1), Port::West as usize);
        assert_eq!(r.route(1, 2), Port::South as usize);
        assert_eq!(r.route(1, 0), Port::North as usize);
        assert_eq!(r.route(1, 1), Port::Local as usize);
        // X resolves before Y.
        assert_eq!(r.route(2, 0), Port::East as usize);
        assert_eq!(r.route(0, 2), Port::West as usize);
    }

    #[test]
    fn allocate_moves_single_flit() {
        let mut r = Router::new(4, 1, 1, 8, [8; PORTS]);
        r.accept(Port::Local as usize, head_flit(5), 3); // dest (2,1) -> East
        let moves = r.allocate();
        assert_eq!(moves.len(), 1);
        assert_eq!(moves[0].out_port, Port::East as usize);
        assert_eq!(r.credits[Port::East as usize], 7);
        assert_eq!(r.buffered(), 0);
    }

    #[test]
    fn no_credits_no_move() {
        let mut credits = [8; PORTS];
        credits[Port::East as usize] = 0;
        let mut r = Router::new(4, 1, 1, 8, credits);
        r.accept(Port::Local as usize, head_flit(5), 3);
        assert!(r.allocate().is_empty());
        assert_eq!(r.buffered(), 1);
    }

    #[test]
    fn wormhole_locks_output_until_tail() {
        let mut r = Router::new(4, 1, 1, 8, [8; PORTS]);
        // Two 3-flit packets from different inputs to the same output.
        let mut b1 = PacketBuilder::new(10);
        let p1 = b1.payload(
            HeadFields {
                routing: 5,
                ..HeadFields::default()
            },
            &[1, 2, 3, 4, 5],
        );
        let mut b2 = PacketBuilder::new(11);
        let p2 = b2.payload(
            HeadFields {
                routing: 5,
                ..HeadFields::default()
            },
            &[9, 9, 9, 9, 9],
        );
        for f in &p1.flits {
            r.accept(Port::Local as usize, *f, 3);
        }
        for f in &p2.flits {
            r.accept(Port::West as usize, *f, 3);
        }
        // Drain: all p1 flits must come out contiguously before any p2 flit
        // (or vice versa) on the East port.
        let mut order = Vec::new();
        for _ in 0..12 {
            for m in r.allocate() {
                order.push(m.flit.meta.flow);
            }
        }
        assert_eq!(order.len(), 6);
        let first = order[0];
        assert!(order[..3].iter().all(|f| *f == first));
        let second = order[3];
        assert_ne!(first, second);
        assert!(order[3..].iter().all(|f| *f == second));
    }

    #[test]
    fn input_serves_one_voq_per_cycle() {
        let mut r = Router::new(4, 1, 1, 8, [8; PORTS]);
        // Two single-flit packets from the same input to different outputs.
        r.accept(Port::Local as usize, head_flit(5), 3); // East
        r.accept(Port::Local as usize, head_flit(3), 3); // West
        let moves = r.allocate();
        assert_eq!(moves.len(), 1, "one flit per input per cycle");
        let moves2 = r.allocate();
        assert_eq!(moves2.len(), 1);
    }

    #[test]
    fn round_robin_is_fair_across_inputs() {
        let mut r = Router::new(4, 1, 1, 8, [64; PORTS]);
        // Keep both inputs loaded with single-flit packets to East.
        for _ in 0..6 {
            r.accept(Port::Local as usize, head_flit(5), 3);
            r.accept(Port::West as usize, head_flit(5), 3);
        }
        let mut from = [0u32; PORTS];
        for _ in 0..12 {
            for m in r.allocate() {
                from[m.in_port] += 1;
            }
        }
        assert_eq!(from[Port::Local as usize], 6);
        assert_eq!(from[Port::West as usize], 6);
    }

    #[test]
    fn single_flit_packet_does_not_lock() {
        let mut r = Router::new(4, 1, 1, 8, [8; PORTS]);
        let f = head_flit(5);
        assert_eq!(f.kind(), FlitKind::Single);
        r.accept(Port::Local as usize, f, 3);
        r.allocate();
        assert!(r.out_lock.iter().all(|l| l.is_none()));
        assert!(!r.is_active());
    }

    #[test]
    fn voq_ring_wraps_and_keeps_fifo_order() {
        let mut ring = VoqRing::default();
        // Interleave pushes and pops so head walks around the ring twice.
        let mut next_in = 0u32;
        let mut next_out = 0u32;
        for _ in 0..3 {
            while (ring.len as usize) < VOQ_RING_CAP {
                ring.push_back(head_flit((next_in % 9) as u8));
                next_in += 1;
            }
            for _ in 0..VOQ_RING_CAP {
                let f = ring.pop_front().expect("nonempty");
                assert_eq!(f.dest(), (next_out % 9) as u8, "FIFO order");
                next_out += 1;
            }
            assert!(ring.is_empty());
        }
        assert_eq!(next_out, 3 * VOQ_RING_CAP as u32);
    }

    #[test]
    fn is_active_tracks_occupancy_and_locks() {
        let mut r = Router::new(4, 1, 1, 8, [8; PORTS]);
        assert!(!r.is_active(), "fresh router is inactive");
        // A 2-flit packet: after the head moves, the router holds a lock
        // (mid-packet) and the body flit — active throughout.
        let mut b = PacketBuilder::new(3);
        let p = b.payload(
            HeadFields {
                routing: 5,
                ..HeadFields::default()
            },
            &[1],
        );
        assert_eq!(p.flits.len(), 2);
        r.accept(Port::Local as usize, p.flits[0], 3);
        assert!(r.is_active());
        let moves = r.allocate();
        assert_eq!(moves.len(), 1);
        assert!(r.is_active(), "lock held mid-packet keeps router active");
        assert_eq!(r.buffered(), 0);
        r.accept(Port::Local as usize, p.flits[1], 3);
        let moves = r.allocate();
        assert_eq!(moves.len(), 1);
        assert!(!r.is_active(), "tail released the lock; nothing buffered");
    }
}
