//! Cycle-level mesh NoC (XY routing, VOQs, wormhole, credits) — the
//! CONNECT-equivalent substrate of the paper's prototype (§3.1, §6.1).

pub mod mesh;
pub mod router;
pub mod traffic;

pub use mesh::{Mesh, MeshConfig, DEFAULT_EJECT_CAP};
pub use router::{Port, Router, DEFAULT_IN_BUF, PORTS};
pub use traffic::FlowTracker;
