//! W×H mesh: router wiring, injection/ejection interfaces (FSL-like NIs,
//! §6.1) and the per-cycle stepping engine with one-cycle credit return.

use std::collections::VecDeque;

use crate::flit::Flit;

use super::router::{Move, Port, Router, DEFAULT_IN_BUF, PORTS};

/// Default ejection (local output) buffer capacity in flits.
pub const DEFAULT_EJECT_CAP: u32 = 16;

#[derive(Debug, Clone)]
pub struct MeshConfig {
    pub width: u8,
    pub height: u8,
    pub in_buf_cap: u32,
    pub eject_cap: u32,
}

impl Default for MeshConfig {
    fn default() -> Self {
        // The paper's 3x3 CONNECT mesh (Fig. 1).
        Self {
            width: 3,
            height: 3,
            in_buf_cap: DEFAULT_IN_BUF,
            eject_cap: DEFAULT_EJECT_CAP,
        }
    }
}

#[derive(Debug)]
pub struct Mesh {
    pub config: MeshConfig,
    routers: Vec<Router>,
    eject: Vec<VecDeque<Flit>>,
    /// Credits the local injector holds toward each router's local input.
    inject_credits: Vec<u32>,
    /// (router index, output port) credits to apply at the next step.
    pending_credits: Vec<(usize, usize)>,
    /// Scratch to avoid per-cycle allocation.
    moves_scratch: Vec<(usize, Move)>,
    pub cycles: u64,
    pub flits_injected: u64,
    pub flits_ejected: u64,
}

impl Mesh {
    pub fn new(config: MeshConfig) -> Self {
        let n = config.width as usize * config.height as usize;
        let mut routers = Vec::with_capacity(n);
        for id in 0..n {
            let x = (id % config.width as usize) as u8;
            let y = (id / config.width as usize) as u8;
            let mut credits = [0u32; PORTS];
            credits[Port::Local as usize] = config.eject_cap;
            if y > 0 {
                credits[Port::North as usize] = config.in_buf_cap;
            }
            if x + 1 < config.width {
                credits[Port::East as usize] = config.in_buf_cap;
            }
            if y + 1 < config.height {
                credits[Port::South as usize] = config.in_buf_cap;
            }
            if x > 0 {
                credits[Port::West as usize] = config.in_buf_cap;
            }
            routers.push(Router::new(id as u8, x, y, config.in_buf_cap, credits));
        }
        Self {
            routers,
            eject: (0..n).map(|_| VecDeque::new()).collect(),
            inject_credits: vec![config.in_buf_cap; n],
            pending_credits: Vec::new(),
            moves_scratch: Vec::new(),
            cycles: 0,
            flits_injected: 0,
            flits_ejected: 0,
            config,
        }
    }

    pub fn node_count(&self) -> usize {
        self.routers.len()
    }

    fn neighbor(&self, id: usize, out: usize) -> usize {
        let w = self.config.width as usize;
        match Port::from_index(out) {
            Port::North => id - w,
            Port::South => id + w,
            Port::East => id + 1,
            Port::West => id - 1,
            Port::Local => id,
        }
    }

    /// Inject a flit at `node`'s NI. Returns false on backpressure.
    pub fn try_inject(&mut self, node: usize, flit: Flit) -> bool {
        if self.inject_credits[node] == 0 {
            return false;
        }
        self.inject_credits[node] -= 1;
        let w = self.config.width;
        self.routers[node].accept(Port::Local as usize, flit, w);
        self.flits_injected += 1;
        true
    }

    pub fn can_inject(&self, node: usize) -> bool {
        self.inject_credits[node] > 0
    }

    /// Pop an ejected flit at `node` (frees a local-output credit).
    pub fn eject_pop(&mut self, node: usize) -> Option<Flit> {
        let f = self.eject[node].pop_front();
        if f.is_some() {
            self.pending_credits.push((node, Port::Local as usize));
            self.flits_ejected += 1;
        }
        f
    }

    pub fn eject_peek(&self, node: usize) -> Option<&Flit> {
        self.eject[node].front()
    }

    pub fn eject_len(&self, node: usize) -> usize {
        self.eject[node].len()
    }

    /// Advance the NoC by one clock cycle.
    pub fn step(&mut self) {
        self.cycles += 1;
        // Apply credits freed last cycle.
        for (router, out) in self.pending_credits.drain(..) {
            self.routers[router].return_credit(out);
        }
        // Phase A: allocation on the pre-cycle state of every router
        // (allocation-free: moves land in the reused scratch buffer).
        let mut moves = std::mem::take(&mut self.moves_scratch);
        moves.clear();
        for i in 0..self.routers.len() {
            self.routers[i].allocate_into(i, &mut |tag, m| moves.push((tag, m)));
        }
        // Phase B: traversal + credit scheduling.
        for (i, m) in moves.drain(..) {
            // Credit back to whoever feeds (i, m.in_port).
            if m.in_port == Port::Local as usize {
                self.inject_credits[i] += 1;
            } else {
                let upstream = self.neighbor(i, m.in_port);
                let up_out = Port::from_index(m.in_port).opposite() as usize;
                self.pending_credits.push((upstream, up_out));
            }
            // Deliver.
            if m.out_port == Port::Local as usize {
                debug_assert!(
                    self.eject[i].len() < self.config.eject_cap as usize,
                    "eject overflow at node {i}"
                );
                self.eject[i].push_back(m.flit);
            } else {
                let j = self.neighbor(i, m.out_port);
                let in_port = Port::from_index(m.out_port).opposite() as usize;
                let w = self.config.width;
                self.routers[j].accept(in_port, m.flit, w);
            }
        }
        self.moves_scratch = moves;
    }

    /// Fold `n` NoC cycles the idle-skipping scheduler fast-forwarded past
    /// (the mesh was provably empty, so stepping them would be a no-op).
    pub fn account_idle_cycles(&mut self, n: u64) {
        self.cycles += n;
    }

    /// Flits currently buffered anywhere in the network (excluding eject).
    pub fn in_flight(&self) -> u32 {
        self.routers.iter().map(|r| r.buffered()).sum()
    }

    /// True when nothing is buffered and all eject queues are drained.
    pub fn idle(&self) -> bool {
        self.in_flight() == 0 && self.eject.iter().all(|q| q.is_empty())
    }

    pub fn router(&self, id: usize) -> &Router {
        &self.routers[id]
    }

    /// Node id of coordinates.
    pub fn node_at(&self, x: u8, y: u8) -> usize {
        y as usize * self.config.width as usize + x as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{HeadFields, PacketBuilder};

    fn single(dest: u8, flow: u32) -> Flit {
        let mut b = PacketBuilder::new(flow);
        b.command(HeadFields {
            routing: dest,
            ..HeadFields::default()
        })
        .flits[0]
    }

    #[test]
    fn delivers_across_mesh() {
        let mut mesh = Mesh::new(MeshConfig::default());
        // Corner to corner: (0,0) -> (2,2), 4 hops + eject.
        assert!(mesh.try_inject(0, single(8, 1)));
        let mut delivered = None;
        for cycle in 0..20 {
            mesh.step();
            if let Some(f) = mesh.eject_pop(8) {
                delivered = Some((cycle, f));
                break;
            }
        }
        let (cycle, f) = delivered.expect("flit delivered");
        assert_eq!(f.meta.flow, 1);
        // 4 router hops + local ejection = 5 cycles.
        assert_eq!(cycle + 1, 5);
    }

    #[test]
    fn multi_flit_packet_arrives_in_order_contiguously() {
        let mut mesh = Mesh::new(MeshConfig::default());
        let mut b = PacketBuilder::new(7);
        let p = b.payload(
            HeadFields {
                routing: 4,
                ..HeadFields::default()
            },
            &(0..20).collect::<Vec<u32>>(),
        );
        let mut pending: VecDeque<Flit> = p.flits.iter().copied().collect();
        let mut got = Vec::new();
        for _ in 0..100 {
            if let Some(f) = pending.front() {
                if mesh.try_inject(0, *f) {
                    pending.pop_front();
                }
            }
            mesh.step();
            while let Some(f) = mesh.eject_pop(4) {
                got.push(f);
            }
            if got.len() == p.flits.len() {
                break;
            }
        }
        assert_eq!(got.len(), p.flits.len());
        for (i, f) in got.iter().enumerate() {
            assert_eq!(f.meta.seq, i as u32, "in-order delivery");
        }
    }

    #[test]
    fn backpressure_blocks_injection_not_loses() {
        let cfg = MeshConfig {
            eject_cap: 2,
            in_buf_cap: 2,
            ..MeshConfig::default()
        };
        let mut mesh = Mesh::new(cfg);
        // Saturate node 1's ejection without draining it.
        let mut sent = 0u32;
        let mut rejected = 0u32;
        for _ in 0..50 {
            if mesh.try_inject(0, single(1, 9)) {
                sent += 1;
            } else {
                rejected += 1;
            }
            mesh.step();
        }
        assert!(rejected > 0, "backpressure engaged");
        // Drain and count: every accepted flit must surface.
        let mut got = 0;
        for _ in 0..500 {
            mesh.step();
            while mesh.eject_pop(1).is_some() {
                got += 1;
            }
            if mesh.idle() {
                break;
            }
        }
        assert_eq!(got, sent);
        assert!(mesh.idle());
    }

    #[test]
    fn no_flit_loss_under_random_traffic() {
        use crate::util::rng::Pcg32;
        let mut mesh = Mesh::new(MeshConfig::default());
        let mut rng = Pcg32::seeded(42);
        let n = mesh.node_count();
        let mut sent = 0u64;
        let mut got = 0u64;
        for _ in 0..2000 {
            let src = rng.range(0, n);
            let dst = rng.range(0, n);
            if src != dst && mesh.try_inject(src, single(dst as u8, src as u32)) {
                sent += 1;
            }
            mesh.step();
            for node in 0..n {
                while mesh.eject_pop(node).is_some() {
                    got += 1;
                }
            }
        }
        for _ in 0..1000 {
            mesh.step();
            for node in 0..n {
                while mesh.eject_pop(node).is_some() {
                    got += 1;
                }
            }
            if mesh.idle() {
                break;
            }
        }
        assert_eq!(got, sent, "conservation of flits");
        assert!(mesh.idle());
    }

    #[test]
    fn dateline_free_xy_has_no_deadlock_under_saturation() {
        // All-to-one hotspot at max injection for many cycles, then drain.
        let mut mesh = Mesh::new(MeshConfig::default());
        let mut sent = 0u64;
        for _ in 0..3000 {
            for src in 0..9 {
                if src != 4 && mesh.try_inject(src, single(4, src as u32)) {
                    sent += 1;
                }
            }
            mesh.step();
            while mesh.eject_pop(4).is_some() {
                sent -= 1;
            }
        }
        for _ in 0..5000 {
            mesh.step();
            while mesh.eject_pop(4).is_some() {
                sent -= 1;
            }
            if mesh.idle() {
                break;
            }
        }
        assert_eq!(sent, 0, "all flits eventually delivered");
        assert!(mesh.idle(), "network drains (no deadlock)");
    }
}
