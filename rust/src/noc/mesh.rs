//! W×H mesh: router wiring, injection/ejection interfaces (FSL-like NIs,
//! §6.1) and the per-cycle stepping engine with one-cycle credit return.
//!
//! Stepping cost scales with **activity, not structure size** (§Perf):
//! the mesh keeps a worklist of active routers (buffered flits or a held
//! wormhole lock) and visits only those each cycle. Routers are activated
//! by [`Mesh::try_inject`] and by flit delivery in phase B, and retire
//! from the worklist when [`Router::is_active`] goes false after
//! allocation (credit returns never need to re-activate: a usable credit
//! implies the router still holds flits and is therefore still queued).
//! `in_flight`/`idle` are O(1) via incrementally maintained totals. The
//! pre-worklist full-scan stepper survives behind `#[cfg(test)]` as the
//! reference for the equivalence property test.

use crate::fault::LinkFaults;
use crate::flit::Flit;

use super::router::{Move, Port, Router, DEFAULT_IN_BUF, PORTS};

/// Default ejection (local output) buffer capacity in flits.
pub const DEFAULT_EJECT_CAP: u32 = 16;

/// Fixed-capacity ejection ring (the NI-side Local output buffer). Like
/// the router's `VoqRing`s, capacity is a hard invariant — the Local
/// output's credits stall allocation on a full ring, so `push` asserts
/// instead of growing. Sized from `MeshConfig::eject_cap` at
/// construction; never allocates afterwards.
#[derive(Debug)]
struct EjectRing {
    slots: Box<[Flit]>,
    head: usize,
    len: usize,
}

impl EjectRing {
    fn new(cap: u32) -> Self {
        Self {
            slots: vec![Flit::default(); cap.max(1) as usize].into_boxed_slice(),
            head: 0,
            len: 0,
        }
    }

    #[inline]
    fn len(&self) -> usize {
        self.len
    }

    #[inline]
    fn front(&self) -> Option<&Flit> {
        (self.len > 0).then(|| &self.slots[self.head])
    }

    #[inline]
    fn push(&mut self, f: Flit) {
        debug_assert!(self.len < self.slots.len(), "eject ring overflow");
        let tail = (self.head + self.len) % self.slots.len();
        self.slots[tail] = f;
        self.len += 1;
    }

    #[inline]
    fn pop(&mut self) -> Option<Flit> {
        if self.len == 0 {
            return None;
        }
        let f = self.slots[self.head];
        self.head = (self.head + 1) % self.slots.len();
        self.len -= 1;
        Some(f)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MeshConfig {
    pub width: u8,
    pub height: u8,
    pub in_buf_cap: u32,
    pub eject_cap: u32,
}

impl Default for MeshConfig {
    fn default() -> Self {
        // The paper's 3x3 CONNECT mesh (Fig. 1).
        Self {
            width: 3,
            height: 3,
            in_buf_cap: DEFAULT_IN_BUF,
            eject_cap: DEFAULT_EJECT_CAP,
        }
    }
}

#[derive(Debug)]
pub struct Mesh {
    pub config: MeshConfig,
    routers: Vec<Router>,
    eject: Vec<EjectRing>,
    /// Credits the local injector holds toward each router's local input.
    inject_credits: Vec<u32>,
    /// (router index, output port) credits to apply at the next step.
    pending_credits: Vec<(usize, usize)>,
    /// Scratch to avoid per-cycle allocation.
    moves_scratch: Vec<(usize, Move)>,
    /// Active-router worklist: the only routers `step` visits (§Perf).
    active: Vec<usize>,
    /// Membership flag per router (keeps the worklist duplicate-free).
    queued: Vec<bool>,
    /// Flits buffered in routers (excluding eject queues), maintained
    /// incrementally so `in_flight`/`idle` are O(1).
    buffered_total: u32,
    /// Flits sitting in eject queues, maintained incrementally.
    eject_total: u32,
    pub cycles: u64,
    pub flits_injected: u64,
    pub flits_ejected: u64,
    /// Link fault injection at ejection links ([`crate::fault`]); `None`
    /// (the default) keeps the hot delivery path one branch away from
    /// the fault-free behavior.
    pub fault: Option<Box<LinkFaults>>,
}

impl Mesh {
    pub fn new(config: MeshConfig) -> Self {
        let n = config.width as usize * config.height as usize;
        let mut routers = Vec::with_capacity(n);
        for id in 0..n {
            let x = (id % config.width as usize) as u8;
            let y = (id / config.width as usize) as u8;
            let mut credits = [0u32; PORTS];
            // The Local output's credits ARE the eject cap: allocation
            // stalls Local-port moves on a full eject queue exactly like
            // any other backpressured output (enforced by the assert in
            // phase B and the hotspot regression test below).
            credits[Port::Local as usize] = config.eject_cap;
            if y > 0 {
                credits[Port::North as usize] = config.in_buf_cap;
            }
            if x + 1 < config.width {
                credits[Port::East as usize] = config.in_buf_cap;
            }
            if y + 1 < config.height {
                credits[Port::South as usize] = config.in_buf_cap;
            }
            if x > 0 {
                credits[Port::West as usize] = config.in_buf_cap;
            }
            routers.push(Router::new(id as u8, x, y, config.in_buf_cap, credits));
        }
        Self {
            routers,
            eject: (0..n).map(|_| EjectRing::new(config.eject_cap)).collect(),
            inject_credits: vec![config.in_buf_cap; n],
            pending_credits: Vec::new(),
            moves_scratch: Vec::new(),
            active: Vec::with_capacity(n),
            queued: vec![false; n],
            buffered_total: 0,
            eject_total: 0,
            cycles: 0,
            flits_injected: 0,
            flits_ejected: 0,
            fault: None,
            config,
        }
    }

    pub fn node_count(&self) -> usize {
        self.routers.len()
    }

    fn neighbor(&self, id: usize, out: usize) -> usize {
        let w = self.config.width as usize;
        match Port::from_index(out) {
            Port::North => id - w,
            Port::South => id + w,
            Port::East => id + 1,
            Port::West => id - 1,
            Port::Local => id,
        }
    }

    #[inline]
    fn activate(&mut self, router: usize) {
        if !self.queued[router] {
            self.queued[router] = true;
            self.active.push(router);
        }
    }

    /// Inject a flit at `node`'s NI. Returns false on backpressure.
    pub fn try_inject(&mut self, node: usize, flit: Flit) -> bool {
        if self.inject_credits[node] == 0 {
            return false;
        }
        self.inject_credits[node] -= 1;
        let w = self.config.width;
        self.routers[node].accept(Port::Local as usize, flit, w);
        self.buffered_total += 1;
        self.activate(node);
        self.flits_injected += 1;
        true
    }

    pub fn can_inject(&self, node: usize) -> bool {
        self.inject_credits[node] > 0
    }

    /// Inject a whole packet (head + body* + tail) at `node`'s NI in one
    /// turn, all-or-nothing: succeeds only when the local input holds
    /// credits for every flit, so a wormhole packet is never left
    /// half-offered. Batch hook for rigs and benches; the timed NI in
    /// `sim::system` still moves one flit per NoC cycle.
    pub fn try_inject_packet(&mut self, node: usize, flits: &[Flit]) -> bool {
        if flits.is_empty() || self.inject_credits[node] < flits.len() as u32 {
            return false;
        }
        for f in flits {
            let ok = self.try_inject(node, *f);
            debug_assert!(ok, "credit-checked injection cannot fail");
        }
        true
    }

    /// Pop an ejected flit at `node` (frees a local-output credit).
    pub fn eject_pop(&mut self, node: usize) -> Option<Flit> {
        let f = self.eject[node].pop();
        if f.is_some() {
            self.pending_credits.push((node, Port::Local as usize));
            self.eject_total -= 1;
            self.flits_ejected += 1;
        }
        f
    }

    pub fn eject_peek(&self, node: usize) -> Option<&Flit> {
        self.eject[node].front()
    }

    pub fn eject_len(&self, node: usize) -> usize {
        self.eject[node].len()
    }

    /// Advance the NoC by one clock cycle, visiting only active routers.
    pub fn step(&mut self) {
        self.step_impl(false);
    }

    /// Reference stepper: visits every router every cycle (the
    /// pre-activity-tracking behavior). Exists solely for the equivalence
    /// property test below; release builds carry only the active-set path.
    #[cfg(test)]
    pub fn step_full_scan(&mut self) {
        self.step_impl(true);
    }

    fn step_impl(&mut self, full_scan: bool) {
        self.cycles += 1;
        // Apply credits freed last cycle. No re-activation needed: a
        // credit is only *usable* by a router that still holds flits (or
        // a lock) toward that output, and such a router never retired —
        // retirement requires `!is_active()`.
        for (router, out) in self.pending_credits.drain(..) {
            self.routers[router].return_credit(out);
            debug_assert!(
                self.queued[router] || !self.routers[router].is_active(),
                "credit returned to an active router that fell off the \
                 worklist"
            );
        }
        // Phase A: allocation on the pre-cycle state of every active
        // router (allocation-free: moves land in the reused scratch
        // buffer). Allocation only touches the router's own state and
        // per-(input,output) queues are single-writer, so visit order is
        // state-neutral — the equivalence test pins this.
        let mut moves = std::mem::take(&mut self.moves_scratch);
        moves.clear();
        if full_scan {
            for i in 0..self.routers.len() {
                self.routers[i].allocate_into(i, &mut |tag, m| moves.push((tag, m)));
            }
        } else {
            let mut k = 0;
            while k < self.active.len() {
                let i = self.active[k];
                self.routers[i].allocate_into(i, &mut |tag, m| moves.push((tag, m)));
                if self.routers[i].is_active() {
                    k += 1;
                } else {
                    // Retire drained routers from the worklist.
                    self.queued[i] = false;
                    self.active.swap_remove(k);
                }
            }
        }
        // Phase B: traversal + credit scheduling.
        for (i, m) in moves.drain(..) {
            self.buffered_total -= 1;
            // Credit back to whoever feeds (i, m.in_port).
            if m.in_port == Port::Local as usize {
                self.inject_credits[i] += 1;
            } else {
                let upstream = self.neighbor(i, m.in_port);
                let up_out = Port::from_index(m.in_port).opposite() as usize;
                self.pending_credits.push((upstream, up_out));
            }
            // Deliver.
            if m.out_port == Port::Local as usize {
                // Hard cap even in release builds: the Local output's
                // credits stall allocation on a full queue, so an
                // overflow here means the credit accounting broke.
                assert!(
                    self.eject[i].len() < self.config.eject_cap as usize,
                    "eject overflow at node {i}: Local-port move escaped \
                     eject-credit backpressure"
                );
                // Link fault hook (None in fault-free runs): the flit
                // may be dropped (never delivered) or have a data bit
                // flipped here, at its final ejection-link traversal.
                let mut flit = m.flit;
                if let Some(f) = self.fault.as_deref_mut() {
                    if !f.on_deliver(i, &mut flit) {
                        // Dropped: the allocation consumed a Local/eject
                        // credit that `eject_pop` would normally return;
                        // return it next cycle or the slot leaks.
                        self.pending_credits.push((i, Port::Local as usize));
                        continue;
                    }
                }
                self.eject[i].push(flit);
                self.eject_total += 1;
            } else {
                let j = self.neighbor(i, m.out_port);
                let in_port = Port::from_index(m.out_port).opposite() as usize;
                let w = self.config.width;
                self.routers[j].accept(in_port, m.flit, w);
                self.buffered_total += 1;
                self.activate(j);
            }
        }
        // Full-scan mode must keep the worklist invariant (every active
        // router is queued) so the two steppers stay interchangeable.
        if full_scan {
            let mut k = 0;
            while k < self.active.len() {
                let i = self.active[k];
                if self.routers[i].is_active() {
                    k += 1;
                } else {
                    self.queued[i] = false;
                    self.active.swap_remove(k);
                }
            }
        }
        self.moves_scratch = moves;
    }

    /// Fold `n` NoC cycles the idle-skipping scheduler fast-forwarded past
    /// (the mesh was provably empty, so stepping them would be a no-op).
    pub fn account_idle_cycles(&mut self, n: u64) {
        self.cycles += n;
    }

    /// Flits currently buffered anywhere in the network (excluding eject).
    /// O(1): incrementally maintained counter, not a router scan.
    pub fn in_flight(&self) -> u32 {
        self.buffered_total
    }

    /// True when nothing is buffered and all eject queues are drained.
    /// O(1): both totals are maintained incrementally.
    pub fn idle(&self) -> bool {
        self.buffered_total == 0 && self.eject_total == 0
    }

    pub fn router(&self, id: usize) -> &Router {
        &self.routers[id]
    }

    /// Routers currently on the active worklist (scheduler work metric).
    pub fn active_routers(&self) -> usize {
        self.active.len()
    }

    /// Node id of coordinates.
    pub fn node_at(&self, x: u8, y: u8) -> usize {
        y as usize * self.config.width as usize + x as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{HeadFields, PacketBuilder};
    use crate::util::rng::Pcg32;
    use std::collections::VecDeque;

    fn single(dest: u8, flow: u32) -> Flit {
        let mut b = PacketBuilder::new(flow);
        b.command(HeadFields {
            routing: dest,
            ..HeadFields::default()
        })
        .flits[0]
    }

    #[test]
    fn delivers_across_mesh() {
        let mut mesh = Mesh::new(MeshConfig::default());
        // Corner to corner: (0,0) -> (2,2), 4 hops + eject.
        assert!(mesh.try_inject(0, single(8, 1)));
        let mut delivered = None;
        for cycle in 0..20 {
            mesh.step();
            if let Some(f) = mesh.eject_pop(8) {
                delivered = Some((cycle, f));
                break;
            }
        }
        let (cycle, f) = delivered.expect("flit delivered");
        assert_eq!(f.meta.flow, 1);
        // 4 router hops + local ejection = 5 cycles.
        assert_eq!(cycle + 1, 5);
    }

    #[test]
    fn multi_flit_packet_arrives_in_order_contiguously() {
        let mut mesh = Mesh::new(MeshConfig::default());
        let mut b = PacketBuilder::new(7);
        let p = b.payload(
            HeadFields {
                routing: 4,
                ..HeadFields::default()
            },
            &(0..20).collect::<Vec<u32>>(),
        );
        let mut pending: VecDeque<Flit> = p.flits.iter().copied().collect();
        let mut got = Vec::new();
        for _ in 0..100 {
            if let Some(f) = pending.front() {
                if mesh.try_inject(0, *f) {
                    pending.pop_front();
                }
            }
            mesh.step();
            while let Some(f) = mesh.eject_pop(4) {
                got.push(f);
            }
            if got.len() == p.flits.len() {
                break;
            }
        }
        assert_eq!(got.len(), p.flits.len());
        for (i, f) in got.iter().enumerate() {
            assert_eq!(f.meta.seq, i as u32, "in-order delivery");
        }
    }

    #[test]
    fn try_inject_packet_is_all_or_nothing() {
        let cfg = MeshConfig {
            in_buf_cap: 4,
            ..MeshConfig::default()
        };
        let mut mesh = Mesh::new(cfg);
        let mut b = PacketBuilder::new(3);
        let p = b.payload(
            HeadFields {
                routing: 8,
                ..HeadFields::default()
            },
            &[1, 2, 3, 4, 5, 6, 7, 8], // head + 2 data flits
        );
        // 4 credits: one whole packet fits, a second (3 more flits when
        // only 1 credit remains) must be refused outright.
        assert!(mesh.try_inject_packet(0, &p.flits));
        assert_eq!(mesh.flits_injected, 3);
        assert!(!mesh.try_inject_packet(0, &p.flits), "partial batch refused");
        assert_eq!(mesh.flits_injected, 3, "nothing half-offered");
        // The whole batch arrives contiguously and in order.
        let mut got = Vec::new();
        for _ in 0..50 {
            mesh.step();
            while let Some(f) = mesh.eject_pop(8) {
                got.push(f);
            }
            if got.len() == 3 {
                break;
            }
        }
        assert_eq!(got.len(), 3);
        for (i, f) in got.iter().enumerate() {
            assert_eq!(f.meta.seq, i as u32);
        }
    }

    #[test]
    fn dropped_flits_return_eject_credits() {
        // With certain-drop link faults at the destination, every flit
        // vanishes at its ejection link — but the freed eject credits
        // must flow back, or the Local output wedges after eject_cap
        // drops and the mesh deadlocks.
        let cfg = MeshConfig {
            eject_cap: 2,
            ..MeshConfig::default()
        };
        let mut mesh = Mesh::new(cfg);
        mesh.fault = Some(Box::new(LinkFaults::new(
            1,
            1.0,
            0.0,
            vec![true; 9],
        )));
        let mut sent = 0u64;
        for _ in 0..40 {
            if mesh.try_inject(0, single(4, 1)) {
                sent += 1;
            }
            mesh.step();
        }
        for _ in 0..20 {
            mesh.step();
        }
        assert!(sent > 10, "injection never wedged (credits returned)");
        assert_eq!(mesh.eject_len(4), 0, "everything dropped");
        assert_eq!(mesh.fault.as_ref().unwrap().drops, sent);
        assert!(mesh.idle(), "no flit stuck anywhere");
    }

    #[test]
    fn flipped_body_flit_is_still_delivered() {
        let mut mesh = Mesh::new(MeshConfig::default());
        mesh.fault = Some(Box::new(LinkFaults::new(
            2,
            0.0,
            1.0,
            vec![true; 9],
        )));
        let mut b = PacketBuilder::new(5);
        let p = b.payload(
            HeadFields {
                routing: 4,
                ..HeadFields::default()
            },
            &[1, 2, 3, 4, 5, 6, 7, 8], // head + body + tail
        );
        assert!(mesh.try_inject_packet(0, &p.flits));
        let mut got = Vec::new();
        for _ in 0..50 {
            mesh.step();
            while let Some(f) = mesh.eject_pop(4) {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 3, "every flit delivered (flips never drop)");
        assert_eq!(got[0].raw, p.flits[0].raw, "head untouched");
        assert_ne!(got[1].raw, p.flits[1].raw, "body data bit flipped");
        assert_eq!(got[1].kind(), p.flits[1].kind(), "framing bits intact");
        assert_eq!(got[2].raw, p.flits[2].raw, "tail untouched");
        assert_eq!(mesh.fault.as_ref().unwrap().flips, 1);
    }

    #[test]
    fn backpressure_blocks_injection_not_loses() {
        let cfg = MeshConfig {
            eject_cap: 2,
            in_buf_cap: 2,
            ..MeshConfig::default()
        };
        let mut mesh = Mesh::new(cfg);
        // Saturate node 1's ejection without draining it.
        let mut sent = 0u32;
        let mut rejected = 0u32;
        for _ in 0..50 {
            if mesh.try_inject(0, single(1, 9)) {
                sent += 1;
            } else {
                rejected += 1;
            }
            mesh.step();
        }
        assert!(rejected > 0, "backpressure engaged");
        // Drain and count: every accepted flit must surface.
        let mut got = 0;
        for _ in 0..500 {
            mesh.step();
            while mesh.eject_pop(1).is_some() {
                got += 1;
            }
            if mesh.idle() {
                break;
            }
        }
        assert_eq!(got, sent);
        assert!(mesh.idle());
    }

    #[test]
    fn no_flit_loss_under_random_traffic() {
        let mut mesh = Mesh::new(MeshConfig::default());
        let mut rng = Pcg32::seeded(42);
        let n = mesh.node_count();
        let mut sent = 0u64;
        let mut got = 0u64;
        for _ in 0..2000 {
            let src = rng.range(0, n);
            let dst = rng.range(0, n);
            if src != dst && mesh.try_inject(src, single(dst as u8, src as u32)) {
                sent += 1;
            }
            mesh.step();
            for node in 0..n {
                while mesh.eject_pop(node).is_some() {
                    got += 1;
                }
            }
        }
        for _ in 0..1000 {
            mesh.step();
            for node in 0..n {
                while mesh.eject_pop(node).is_some() {
                    got += 1;
                }
            }
            if mesh.idle() {
                break;
            }
        }
        assert_eq!(got, sent, "conservation of flits");
        assert!(mesh.idle());
    }

    #[test]
    fn dateline_free_xy_has_no_deadlock_under_saturation() {
        // All-to-one hotspot at max injection for many cycles, then drain.
        let mut mesh = Mesh::new(MeshConfig::default());
        let mut sent = 0u64;
        for _ in 0..3000 {
            for src in 0..9 {
                if src != 4 && mesh.try_inject(src, single(4, src as u32)) {
                    sent += 1;
                }
            }
            mesh.step();
            while mesh.eject_pop(4).is_some() {
                sent -= 1;
            }
        }
        for _ in 0..5000 {
            mesh.step();
            while mesh.eject_pop(4).is_some() {
                sent -= 1;
            }
            if mesh.idle() {
                break;
            }
        }
        assert_eq!(sent, 0, "all flits eventually delivered");
        assert!(mesh.idle(), "network drains (no deadlock)");
    }

    /// ISSUE 4 satellite: the eject cap must hold in release builds under
    /// a hotspot that never drains — Local-port moves stall on eject
    /// credits like any other backpressured output. The tiny cap makes
    /// any leak overflow within a few cycles.
    #[test]
    fn eject_cap_enforced_under_undrained_hotspot() {
        let cfg = MeshConfig {
            eject_cap: 2,
            ..MeshConfig::default()
        };
        let mut mesh = Mesh::new(cfg);
        for _ in 0..2000 {
            for src in 0..9 {
                if src != 4 {
                    mesh.try_inject(src, single(4, src as u32));
                }
            }
            mesh.step(); // asserts internally on any eject overflow
            for node in 0..9 {
                assert!(
                    mesh.eject_len(node) <= 2,
                    "eject queue at node {node} exceeded its cap"
                );
            }
            // Never pop node 4: the hotspot's eject queue stays full and
            // every upstream buffer backs up behind it.
        }
        assert_eq!(mesh.eject_len(4), 2, "hotspot eject pinned at cap");
        assert!(!mesh.idle());
    }

    /// The worklist retires drained routers: an idle mesh visits nobody.
    #[test]
    fn active_set_drains_to_empty() {
        let mut mesh = Mesh::new(MeshConfig::default());
        assert_eq!(mesh.active_routers(), 0);
        assert!(mesh.try_inject(0, single(8, 1)));
        assert!(mesh.active_routers() > 0);
        for _ in 0..20 {
            mesh.step();
            while mesh.eject_pop(8).is_some() {}
        }
        // One extra step applies the final eject credit (no re-activation
        // needed) and leaves the worklist drained.
        mesh.step();
        assert!(mesh.idle());
        assert_eq!(mesh.active_routers(), 0, "worklist drained");
        assert_eq!(mesh.in_flight(), 0);
    }

    // ------------------------------------------------------------------
    // Equivalence property test (ISSUE 4): the active-set stepper and
    // the reference full-scan stepper, fed identical seeded random
    // traffic for >= 5k cycles, must agree on every observable — eject
    // streams, per-router credit state, occupancies and cycle counts.
    // ------------------------------------------------------------------

    fn assert_meshes_equal(a: &Mesh, b: &Mesh, ctx: &str) {
        assert_eq!(a.cycles, b.cycles, "{ctx}: cycles");
        assert_eq!(a.flits_injected, b.flits_injected, "{ctx}: injected");
        assert_eq!(a.flits_ejected, b.flits_ejected, "{ctx}: ejected");
        assert_eq!(a.in_flight(), b.in_flight(), "{ctx}: in_flight");
        assert_eq!(a.idle(), b.idle(), "{ctx}: idle");
        let mut scan = 0u32;
        for i in 0..a.node_count() {
            let (ra, rb) = (a.router(i), b.router(i));
            assert_eq!(ra.credits, rb.credits, "{ctx}: credits of router {i}");
            assert_eq!(
                ra.buffered(),
                rb.buffered(),
                "{ctx}: occupancy of router {i}"
            );
            assert_eq!(
                ra.flits_routed, rb.flits_routed,
                "{ctx}: flits routed by router {i}"
            );
            assert_eq!(
                a.inject_credits[i], b.inject_credits[i],
                "{ctx}: inject credits at node {i}"
            );
            assert_eq!(
                a.eject_len(i),
                b.eject_len(i),
                "{ctx}: eject backlog at node {i}"
            );
            scan += ra.buffered();
        }
        assert_eq!(
            scan,
            a.in_flight(),
            "{ctx}: maintained in_flight total matches a router scan"
        );
    }

    #[test]
    fn active_set_matches_full_scan_under_random_traffic() {
        for seed in [1u64, 7, 42, 20260801] {
            let cfg = MeshConfig {
                width: 4,
                height: 4,
                in_buf_cap: 4,
                eject_cap: 4,
            };
            let mut a = Mesh::new(cfg.clone());
            let mut b = Mesh::new(cfg);
            let mut rng = Pcg32::seeded(seed);
            let mut builder = PacketBuilder::new(1);
            let n = a.node_count();
            // Per-node outboxes keep multi-flit packets contiguous at
            // each local input (as every real injector does).
            let mut outbox: Vec<VecDeque<Flit>> =
                (0..n).map(|_| VecDeque::new()).collect();
            for cycle in 0..5500u64 {
                // Random offered traffic: single-flit commands and 1/4/12
                // word wormhole payloads.
                if rng.chance(0.5) {
                    let src = rng.range(0, n);
                    let dst = rng.range(0, n);
                    if src != dst && outbox[src].len() < 32 {
                        let words = [0usize, 1, 4, 12][rng.range(0, 4)];
                        let head = HeadFields {
                            routing: dst as u8,
                            ..HeadFields::default()
                        };
                        let p = if words == 0 {
                            builder.command(head)
                        } else {
                            builder
                                .payload(head, &vec![cycle as u32; words])
                        };
                        outbox[src].extend(p.flits);
                    }
                }
                // One injection attempt per node per cycle, identical on
                // both meshes (their NI state must agree).
                for (node, q) in outbox.iter_mut().enumerate() {
                    if let Some(f) = q.front().copied() {
                        let ok_a = a.try_inject(node, f);
                        let ok_b = b.try_inject(node, f);
                        assert_eq!(ok_a, ok_b, "inject decision diverged");
                        if ok_a {
                            q.pop_front();
                        }
                    }
                }
                a.step();
                b.step_full_scan();
                // Random partial draining exercises credit returns and
                // re-activation.
                for node in 0..n {
                    if rng.chance(0.6) {
                        loop {
                            match (a.eject_pop(node), b.eject_pop(node)) {
                                (Some(x), Some(y)) => {
                                    assert_eq!(x, y, "eject stream diverged")
                                }
                                (None, None) => break,
                                (x, y) => panic!(
                                    "eject length diverged at node \
                                     {node}: {x:?} vs {y:?}"
                                ),
                            }
                        }
                    }
                }
                if cycle % 128 == 0 {
                    assert_meshes_equal(&a, &b, &format!("seed {seed} cycle {cycle}"));
                }
            }
            // Stop offering traffic and drain both meshes completely.
            for _ in 0..4000 {
                a.step();
                b.step_full_scan();
                for node in 0..n {
                    loop {
                        match (a.eject_pop(node), b.eject_pop(node)) {
                            (Some(x), Some(y)) => assert_eq!(x, y),
                            (None, None) => break,
                            (x, y) => panic!("drain diverged: {x:?} vs {y:?}"),
                        }
                    }
                }
                if a.idle() && b.idle() {
                    break;
                }
            }
            assert!(a.idle() && b.idle(), "seed {seed}: both drained");
            assert_meshes_equal(&a, &b, &format!("seed {seed} final"));
        }
    }
}
