//! Hardware accelerator specifications (paper Table 3) and the functional
//! compute hook.
//!
//! The paper derives twelve HWAs from CHStone / SNU benchmarks with Vivado
//! HLS; resource numbers below are Table 3 verbatim. Execution cycles,
//! I/O word counts and fmax are **calibrated constants** (the paper does
//! not tabulate them): they are chosen to reproduce the paper's documented
//! communication patterns —
//!
//! * `Izigzag`: one-cycle execution on a relatively large data set
//!   (§6.2, §6.4 — 64 coefficients -> 17-flit payload packets; the paper
//!   reports 18-flit JPEG payloads including the request framing),
//! * `Dfdiv`: long execution on a small data set (§6.2 — transmission
//!   time << execution time, so one task buffer suffices),
//! * `Gsm`: 3-flit payload packets (§6.5),
//! * everything else between those extremes.

use crate::flit::payload_packet_flits;

/// FPGA resource vector (Table 3 / Table 4 accounting).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Resources {
    pub lut: u32,
    pub bram: u32,
    pub dsp: u32,
    pub ff: u32,
}

impl Resources {
    pub const fn new(lut: u32, bram: u32, dsp: u32, ff: u32) -> Self {
        Self { lut, bram, dsp, ff }
    }

    pub fn add(&self, other: &Resources) -> Resources {
        Resources {
            lut: self.lut + other.lut,
            bram: self.bram + other.bram,
            dsp: self.dsp + other.dsp,
            ff: self.ff + other.ff,
        }
    }
}

/// Virtex-7 xc7vx690t capacity (§6.1) for utilization percentages.
pub const DEVICE_LUTS: u32 = 433_200;
pub const DEVICE_BRAMS: u32 = 1_470;
pub const DEVICE_DSPS: u32 = 3_600;
pub const DEVICE_FFS: u32 = 866_400;

#[derive(Debug, Clone, PartialEq)]
pub struct HwaSpec {
    pub name: &'static str,
    /// Execution cycles per task at the HWA's own clock.
    pub exec_cycles: u64,
    /// Input words (u32) per task.
    pub in_words: usize,
    /// Output words (u32) per task.
    pub out_words: usize,
    /// Vivado-reported fmax the HWA clock runs at (§6.1).
    pub fmax_mhz: f64,
    /// Table 3 resource usage.
    pub resources: Resources,
    /// Name of the AOT artifact implementing this HWA's compute, if any.
    pub artifact: Option<&'static str>,
}

impl HwaSpec {
    /// Flits in one input payload packet (head + data flits).
    pub fn in_packet_flits(&self) -> usize {
        payload_packet_flits(self.in_words)
    }

    /// Flits in one result packet.
    pub fn out_packet_flits(&self) -> usize {
        payload_packet_flits(self.out_words)
    }
}

/// The twelve Table 3 benchmarks.
pub fn table3() -> Vec<HwaSpec> {
    vec![
        HwaSpec {
            name: "aes_enc",
            exec_cycles: 80,
            in_words: 8,
            out_words: 4,
            fmax_mhz: 240.0,
            resources: Resources::new(12259, 116, 0, 7286),
            artifact: None,
        },
        HwaSpec {
            name: "aes_dec",
            exec_cycles: 92,
            in_words: 8,
            out_words: 4,
            fmax_mhz: 230.0,
            resources: Resources::new(15218, 116, 0, 7350),
            artifact: None,
        },
        HwaSpec {
            name: "dfadd",
            exec_cycles: 6,
            in_words: 4,
            out_words: 2,
            fmax_mhz: 300.0,
            resources: Resources::new(4983, 0, 0, 3768),
            artifact: Some("dfadd"),
        },
        HwaSpec {
            name: "dfdiv",
            exec_cycles: 1200,
            in_words: 4,
            out_words: 2,
            fmax_mhz: 250.0,
            resources: Resources::new(9661, 0, 24, 13171),
            artifact: Some("dfdiv"),
        },
        HwaSpec {
            name: "dfmul",
            exec_cycles: 10,
            in_words: 4,
            out_words: 2,
            fmax_mhz: 300.0,
            resources: Resources::new(1927, 0, 16, 2089),
            artifact: Some("dfmul"),
        },
        HwaSpec {
            name: "gsm",
            exec_cycles: 120,
            in_words: 8,
            out_words: 8,
            fmax_mhz: 260.0,
            resources: Resources::new(4257, 0, 12, 2643),
            artifact: Some("gsm"),
        },
        HwaSpec {
            name: "prime",
            exec_cycles: 4000,
            in_words: 2,
            out_words: 2,
            fmax_mhz: 150.0,
            resources: Resources::new(161237, 0, 0, 277026),
            artifact: None,
        },
        HwaSpec {
            name: "sha",
            exec_cycles: 160,
            in_words: 16,
            out_words: 5,
            fmax_mhz: 220.0,
            resources: Resources::new(13147, 1, 0, 9931),
            artifact: None,
        },
        HwaSpec {
            name: "izigzag",
            exec_cycles: 1,
            in_words: 64,
            out_words: 64,
            fmax_mhz: 400.0,
            resources: Resources::new(100, 0, 0, 98),
            artifact: Some("izigzag"),
        },
        HwaSpec {
            name: "iquantize",
            exec_cycles: 8,
            in_words: 64,
            out_words: 64,
            fmax_mhz: 350.0,
            resources: Resources::new(608, 0, 76, 1413),
            artifact: Some("iquantize"),
        },
        HwaSpec {
            name: "idct",
            exec_cycles: 94,
            in_words: 64,
            out_words: 64,
            fmax_mhz: 200.0,
            resources: Resources::new(14552, 0, 368, 12390),
            artifact: Some("idct"),
        },
        HwaSpec {
            name: "shiftbound",
            exec_cycles: 4,
            in_words: 64,
            out_words: 64,
            fmax_mhz: 350.0,
            resources: Resources::new(7133, 0, 0, 7928),
            artifact: Some("shiftbound"),
        },
    ]
}

pub fn spec_by_name(name: &str) -> Option<HwaSpec> {
    table3().into_iter().find(|s| s.name == name)
}

/// Functional compute hook: transforms a task's input words into output
/// words when the (simulated) execution completes. Implementations:
/// [`EchoCompute`] (timing-only), `runtime::NativeCompute` (Rust golden),
/// `runtime::PjrtCompute` (AOT artifacts through PJRT).
///
/// `compute_into` is the required (hot-path) form: it writes the result
/// into a caller-owned buffer so pooled word storage is reused with zero
/// heap allocation. The allocating `compute` stays as a convenience
/// wrapper for tests and one-shot callers.
pub trait HwaCompute {
    /// Clear `out` and fill it with the task's output words.
    fn compute_into(&mut self, spec: &HwaSpec, input: &[u32], out: &mut Vec<u32>);

    /// Allocating convenience wrapper over [`Self::compute_into`].
    fn compute(&mut self, spec: &HwaSpec, input: &[u32]) -> Vec<u32> {
        let mut out = Vec::with_capacity(spec.out_words);
        self.compute_into(spec, input, &mut out);
        out
    }
}

/// Timing-only compute: emits `out_words` words echoing/rotating input.
#[derive(Debug, Default)]
pub struct EchoCompute;

impl HwaCompute for EchoCompute {
    fn compute_into(&mut self, spec: &HwaSpec, input: &[u32], out: &mut Vec<u32>) {
        out.clear();
        for i in 0..spec.out_words {
            out.push(input.get(i % input.len().max(1)).copied().unwrap_or(0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_benchmarks() {
        assert_eq!(table3().len(), 12);
    }

    #[test]
    fn table3_resource_spot_checks() {
        // Verbatim from the paper's Table 3.
        let izz = spec_by_name("izigzag").unwrap();
        assert_eq!(izz.resources, Resources::new(100, 0, 0, 98));
        let idct = spec_by_name("idct").unwrap();
        assert_eq!(idct.resources.dsp, 368);
        let prime = spec_by_name("prime").unwrap();
        assert_eq!(prime.resources.lut, 161237);
        let sha = spec_by_name("sha").unwrap();
        assert_eq!(sha.resources.bram, 1);
    }

    #[test]
    fn izigzag_is_one_cycle_large_data() {
        // §6.2's two extremes are structurally present.
        let izz = spec_by_name("izigzag").unwrap();
        assert_eq!(izz.exec_cycles, 1);
        assert_eq!(izz.in_packet_flits(), 17); // 64 words -> 16 data flits + head
        let dfdiv = spec_by_name("dfdiv").unwrap();
        assert!(dfdiv.exec_cycles >= 50);
        assert_eq!(dfdiv.in_packet_flits(), 2); // small data
    }

    #[test]
    fn average_lut_close_to_paper() {
        // Paper: "The average lookup table (LUT) utilization is 20424."
        let avg = table3().iter().map(|s| s.resources.lut as u64).sum::<u64>()
            / 12;
        assert_eq!(avg, 20423); // integer division of the Table 3 sum
    }

    #[test]
    fn bram_and_dsp_variety_matches_paper() {
        // "Three applications use BRAMs and five applications utilize DSPs."
        let specs = table3();
        assert_eq!(specs.iter().filter(|s| s.resources.bram > 0).count(), 3);
        assert_eq!(specs.iter().filter(|s| s.resources.dsp > 0).count(), 5);
    }

    #[test]
    fn echo_compute_emits_out_words() {
        let spec = spec_by_name("dfadd").unwrap();
        let out = EchoCompute.compute(&spec, &[1, 2, 3, 4]);
        assert_eq!(out.len(), spec.out_words);
        // The in-place form reuses the caller's buffer and agrees with
        // the allocating wrapper.
        let mut buf = vec![99; 16];
        EchoCompute.compute_into(&spec, &[1, 2, 3, 4], &mut buf);
        assert_eq!(buf, out);
    }
}
