//! The FPGA-based multi-accelerator architecture (paper §4): interface
//! block, HWA channels, chaining fabric and HWA models.

pub mod channel;
pub mod fabric;
pub mod hwa;
pub mod iface;

pub use channel::Channel;
pub use fabric::{ChainGroup, Fpga, FpgaConfig, ROUTER_FIFO_CAP};
pub use hwa::{spec_by_name, table3, EchoCompute, HwaCompute, HwaSpec, Resources};
pub use iface::{PrStrategy, PsStrategy};
