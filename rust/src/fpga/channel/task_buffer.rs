//! Task buffers (TBs): BRAM FIFOs staging input packets per HWA channel
//! (§4.2 B.1). The number of TBs is the Fig. 6 design parameter; state
//! transitions implement the request/grant protocol's buffer reservation.

use crate::clock::Ps;
use crate::flit::{HeadFields, PacketArena};

use super::task::Task;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TbState {
    /// Available for granting.
    Free,
    /// Reserved by a grant; awaiting the payload packet head.
    Granted,
    /// Payload streaming in.
    Filling,
    /// Complete task awaiting the task arbiter (visible after CDC sync).
    Ready,
    /// Being drained by the HWA controller.
    InUse,
}

#[derive(Debug)]
pub struct TaskBuffer {
    pub state: TbState,
    head: Option<HeadFields>,
    words: Vec<u32>,
    flow: u32,
    /// Time the task becomes visible to the HWA-clock side (2-stage sync).
    ready_at: Ps,
    t_request: Ps,
}

impl TaskBuffer {
    pub fn new() -> Self {
        Self {
            state: TbState::Free,
            head: None,
            words: Vec::new(),
            flow: 0,
            ready_at: 0,
            t_request: 0,
        }
    }

    pub fn grant(&mut self, t_request: Ps) {
        debug_assert_eq!(self.state, TbState::Free);
        self.state = TbState::Granted;
        self.t_request = t_request;
    }

    /// Payload packet head arrives from the PR.
    pub fn begin_fill(&mut self, head: HeadFields, flow: u32) {
        debug_assert_eq!(self.state, TbState::Granted, "fill without grant");
        self.state = TbState::Filling;
        self.head = Some(head);
        self.flow = flow;
        self.words.clear();
    }

    /// A data flit's words arrive (four u32 lanes per body flit).
    pub fn push_words(&mut self, lanes: &[u32]) {
        debug_assert_eq!(self.state, TbState::Filling);
        self.words.extend_from_slice(lanes);
    }

    /// Tail flit observed: task complete; visible to the HWA clock domain
    /// at `ready_at` (two destination edges later — CDC).
    pub fn finish_fill(&mut self, ready_at: Ps) {
        debug_assert_eq!(self.state, TbState::Filling);
        self.state = TbState::Ready;
        self.ready_at = ready_at;
    }

    pub fn is_ready(&self, now: Ps) -> bool {
        self.state == TbState::Ready && now >= self.ready_at
    }

    /// CDC visibility time of a filled task, if one is waiting: the
    /// scheduler's `next_event_at` lower bound for an otherwise idle HWA
    /// (nothing can leave this buffer before `ready_at`).
    pub fn ready_wake(&self) -> Option<Ps> {
        if self.state == TbState::Ready {
            Some(self.ready_at)
        } else {
            None
        }
    }

    /// The task arbiter hands the buffer to the HWA controller. The staged
    /// words are copied into a pooled arena buffer; the TB keeps (and
    /// reuses) its own BRAM-model `Vec` capacity across fills.
    pub fn take(&mut self, expected_words: usize, now: Ps, arena: &mut PacketArena) -> Task {
        debug_assert!(self.is_ready(now));
        self.state = TbState::InUse;
        let head = self.head.take().expect("filled buffer has a head");
        let handle = arena.alloc_words_from(&self.words);
        // Pad/truncate to the HWA's expected input width (the paper's HWAs
        // have fixed input sizes; data_size in the header is advisory).
        arena.words_mut(handle).resize(expected_words, 0);
        let mut task = Task::new(head, handle, self.flow);
        task.t_request = self.t_request;
        task.t_ready = self.ready_at;
        task
    }

    /// Head of the payload currently staged (CRC verification peeks at
    /// the stamped checksum before the fill is committed).
    pub fn fill_head(&self) -> Option<&HeadFields> {
        self.head.as_ref()
    }

    /// Words staged so far (CRC verification input).
    pub fn fill_words(&self) -> &[u32] {
        &self.words
    }

    /// CRC-mismatch recovery: discard the staged payload but keep the
    /// reservation, so the NACKed sender can retransmit into this same
    /// buffer without a fresh request/grant round trip.
    pub fn reset_to_granted(&mut self) {
        debug_assert_eq!(self.state, TbState::Filling);
        self.state = TbState::Granted;
        self.head = None;
        self.words.clear();
    }

    /// When this buffer's reservation was made (watchdog age baseline
    /// for grants whose payload never arrives).
    pub fn granted_at(&self) -> Ps {
        self.t_request
    }

    /// Watchdog reclaim: a reservation (or partial fill) whose payload
    /// packet was lost in flight goes back to the free pool. A late
    /// flit for this buffer then hits the ordinary rejected-flit path.
    pub fn reclaim(&mut self) {
        debug_assert!(matches!(self.state, TbState::Granted | TbState::Filling));
        self.state = TbState::Free;
        self.head = None;
        self.words.clear();
    }

    /// HWAC finished reading: buffer returns to the free pool.
    pub fn release(&mut self) {
        debug_assert_eq!(self.state, TbState::InUse);
        self.state = TbState::Free;
        self.head = None;
        self.words.clear();
    }
}

impl Default for TaskBuffer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_lifecycle() {
        let mut arena = PacketArena::new();
        let mut tb = TaskBuffer::new();
        assert_eq!(tb.state, TbState::Free);
        tb.grant(100);
        tb.begin_fill(HeadFields::default(), 7);
        tb.push_words(&[1, 2, 3, 4]);
        tb.push_words(&[5, 6]);
        tb.finish_fill(500);
        assert!(!tb.is_ready(400), "not visible before CDC sync");
        assert!(tb.is_ready(500));
        let task = tb.take(8, 500, &mut arena);
        assert_eq!(arena.words(task.words), &[1, 2, 3, 4, 5, 6, 0, 0]);
        assert_eq!(task.flow, 7);
        assert_eq!(task.t_request, 100);
        tb.release();
        assert_eq!(tb.state, TbState::Free);
    }

    #[test]
    fn truncates_excess_words() {
        let mut arena = PacketArena::new();
        let mut tb = TaskBuffer::new();
        tb.grant(0);
        tb.begin_fill(HeadFields::default(), 0);
        tb.push_words(&[9; 16]);
        tb.finish_fill(0);
        let task = tb.take(4, 0, &mut arena);
        assert_eq!(arena.words(task.words).len(), 4);
    }

    #[test]
    #[should_panic]
    fn fill_without_grant_panics() {
        let mut tb = TaskBuffer::new();
        tb.begin_fill(HeadFields::default(), 0);
    }

    #[test]
    fn nack_reset_keeps_reservation_for_retransmit() {
        let mut arena = PacketArena::new();
        let mut tb = TaskBuffer::new();
        tb.grant(50);
        tb.begin_fill(HeadFields::default(), 3);
        tb.push_words(&[1, 2, 3, 4]);
        assert_eq!(tb.fill_words(), &[1, 2, 3, 4]);
        assert!(tb.fill_head().is_some());
        tb.reset_to_granted();
        assert_eq!(tb.state, TbState::Granted);
        assert_eq!(tb.granted_at(), 50);
        // The retransmitted payload fills the same reservation.
        tb.begin_fill(HeadFields::default(), 3);
        tb.push_words(&[5, 6, 7, 8]);
        tb.finish_fill(60);
        let task = tb.take(4, 60, &mut arena);
        assert_eq!(arena.words(task.words), &[5, 6, 7, 8]);
        assert_eq!(task.t_request, 50, "original request time survives");
    }

    #[test]
    fn watchdog_reclaim_frees_stuck_reservation() {
        let mut tb = TaskBuffer::new();
        tb.grant(10);
        tb.reclaim();
        assert_eq!(tb.state, TbState::Free);
        tb.grant(20);
        tb.begin_fill(HeadFields::default(), 1);
        tb.push_words(&[1]);
        tb.reclaim();
        assert_eq!(tb.state, TbState::Free);
        assert!(tb.fill_words().is_empty());
    }
}
