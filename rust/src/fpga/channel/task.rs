//! A task: one HWA invocation's header + data words + timestamps.

use crate::clock::Ps;
use crate::flit::{HeadFields, WordsHandle};

/// Command subtypes carried in the low payload bits of command packets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommandKind {
    Request,
    Grant,
    Notify,
    /// Payload rejected (CRC mismatch at the receiver): the sender
    /// should retransmit into the still-reserved task buffer.
    Nack,
}

impl CommandKind {
    pub fn encode(self) -> u64 {
        match self {
            CommandKind::Request => 0,
            CommandKind::Grant => 1,
            CommandKind::Notify => 2,
            CommandKind::Nack => 3,
        }
    }

    pub fn decode(payload: u64) -> Self {
        match payload & 0b11 {
            1 => CommandKind::Grant,
            2 => CommandKind::Notify,
            3 => CommandKind::Nack,
            _ => CommandKind::Request,
        }
    }
}

/// One in-flight HWA invocation inside the fabric.
#[derive(Debug, Clone)]
pub struct Task {
    /// Current header; chaining fields mutate as the task hops HWAs.
    pub head: HeadFields,
    /// Pooled data-word buffer (input before execution, output after).
    /// The buffer lives in the simulation's [`crate::flit::PacketArena`];
    /// whoever retires the task frees the handle.
    pub words: WordsHandle,
    /// Flow id for metrics (from the payload packet's flits).
    pub flow: u32,
    /// Chain hops completed so far (simulation metadata).
    pub chain_hops: u8,
    /// Fault injection tagged this task's result for corruption: a data
    /// bit of the built result packet flips *after* its CRC is stamped,
    /// so the requester's check fails (see `ChannelFaults`).
    pub corrupted: bool,
    // -- timestamps (ps), 0 = unset --
    pub t_request: Ps,
    pub t_ready: Ps,
    pub t_exec_start: Ps,
    pub t_exec_end: Ps,
}

impl Task {
    pub fn new(head: HeadFields, words: WordsHandle, flow: u32) -> Self {
        Self {
            head,
            words,
            flow,
            chain_hops: 0,
            corrupted: false,
            t_request: 0,
            t_ready: 0,
            t_exec_start: 0,
            t_exec_end: 0,
        }
    }

    /// Remaining chaining hops after the current HWA.
    pub fn chain_remaining(&self) -> u8 {
        self.head.chain_depth
    }

    /// Consume one chaining hop: returns the group-member index of the next
    /// HWA and shifts the index pipeline (the hardware shifts the 6-bit
    /// chain-index field left by one 2-bit lane as depth decrements, §4.2
    /// B.3).
    pub fn advance_chain(&mut self) -> u8 {
        debug_assert!(self.head.chain_depth > 0);
        let next = self.head.chain_index[0];
        self.head.chain_index = [self.head.chain_index[1], self.head.chain_index[2], 0];
        self.head.chain_depth -= 1;
        self.chain_hops += 1;
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{HeadFields, PacketArena};

    #[test]
    fn command_kind_roundtrip() {
        for k in [
            CommandKind::Request,
            CommandKind::Grant,
            CommandKind::Notify,
            CommandKind::Nack,
        ] {
            assert_eq!(CommandKind::decode(k.encode()), k);
        }
    }

    #[test]
    fn chain_advance_shifts_indexes() {
        let mut arena = PacketArena::new();
        let mut t = Task::new(
            HeadFields {
                chain_depth: 3,
                chain_index: [2, 1, 3],
                ..HeadFields::default()
            },
            arena.alloc_words(),
            0,
        );
        assert_eq!(t.advance_chain(), 2);
        assert_eq!(t.advance_chain(), 1);
        assert_eq!(t.advance_chain(), 3);
        assert_eq!(t.chain_remaining(), 0);
        assert_eq!(t.chain_hops, 3);
    }

    /// Exhausting a chain zero-fills the shifted index lanes: a task
    /// whose depth has decremented to 0 carries no stale hop indexes
    /// that a later (buggy or forged) depth bump could act on.
    #[test]
    fn chain_exhaustion_zero_fills_index_lanes() {
        let mut arena = PacketArena::new();
        let mut t = Task::new(
            HeadFields {
                chain_depth: 2,
                chain_index: [1, 3, 0],
                ..HeadFields::default()
            },
            arena.alloc_words(),
            0,
        );
        assert_eq!(t.advance_chain(), 1);
        assert_eq!(t.head.chain_index, [3, 0, 0]);
        assert_eq!(t.chain_remaining(), 1);
        assert_eq!(t.advance_chain(), 3);
        assert_eq!(t.head.chain_index, [0, 0, 0]);
        assert_eq!(t.chain_remaining(), 0);
        assert_eq!(t.chain_hops, 2);
    }
}
