//! The HWA channel (paper Fig. 2b): request buffer + local grant
//! controller, task buffers + task arbiter, HWA controller, the HWA
//! execution model, packet generator, packet output buffer and chaining
//! buffer.
//!
//! Clocking: the request path (RB/LGC) and POB drain run on the interface
//! clock; TA/HWAC/HWA/PG run on the HWA's own clock (§4.2 B.1). Structural
//! latencies follow Table 2: LGC/TA 1 cycle, HWAC and PG `4 + N` cycles,
//! buffers `4 + N` (2-stage CDC + fetch pipeline).

pub mod task;
pub mod task_buffer;

use std::collections::VecDeque;

use crate::clock::{Activity, ClockDomain, Ps};
use crate::fault::{ChannelFaults, HwaFault};
use crate::flit::{
    crc16, payload_crc, payload_packet_flits, Direction, FlitKind, HeadFields,
    Packet, PacketArena, PacketBuilder, PacketHandle, PacketType,
};

use super::hwa::{HwaCompute, HwaSpec};
use task::{CommandKind, Task};
use task_buffer::{TaskBuffer, TbState};

/// Request-buffer depth (requests queued while all TBs are busy).
/// Requests are single-flit headers held in registers, so a deeper RB is
/// cheap; 16 covers 8 sources x 2 outstanding invocations each.
pub const DEFAULT_RB_CAP: usize = 16;
/// Chaining-buffer depth in tasks (paper §4.2 B.3; small by design).
pub const DEFAULT_CB_CAP: usize = 2;
/// Packet-output-buffer capacity in flits.
pub const DEFAULT_POB_CAP_FLITS: usize = 64;

#[derive(Debug, Clone, Copy, Default)]
pub struct ChannelStats {
    pub requests: u64,
    pub grants: u64,
    pub tasks_executed: u64,
    pub chain_forwards: u64,
    pub chain_receives: u64,
    pub busy_cycles: u64,
    pub result_packets: u64,
    /// Cycles the PG stalled on a full CB/POB.
    pub pg_stall_cycles: u64,
    /// Malformed/untrusted header events rejected instead of acted on
    /// (out-of-range `tb_id`/`src_id`, payload without a grant). A
    /// hardware channel drops such flits; the simulator must not panic.
    pub rejected_flits: u64,
}

/// One result packet queued in the POB: arena handle plus the two fields
/// the PS consults without touching flit storage (length for credit math,
/// head priority for arbitration).
#[derive(Debug, Clone, Copy)]
pub struct PobEntry {
    pub handle: PacketHandle,
    pub len: usize,
    pub priority: u8,
}

/// HWA controller FSM (§4.2 B.1).
#[derive(Debug)]
enum Hwac {
    Idle,
    /// Reading a task out of a TB or CB: completes at `done_at`.
    Fetching { task: Task, tb: Option<usize>, done_at: Ps },
    Executing { task: Task, done_at: Ps },
    /// PG forming the output (4 + N_out cycles).
    Draining { task: Task, done_at: Ps },
    /// PG finished but the CB/POB was full; retrying each HWA cycle.
    Blocked { task: Task },
    /// Fault injection wedged the datapath (the task "hangs forever");
    /// the channel watchdog kills it at `kill_at`. The task is never
    /// executed or completed — its requester recovers through its own
    /// timeout/retry machinery.
    Hung { task: Task, kill_at: Ps },
}

pub struct Channel {
    pub hwa_id: u8,
    pub spec: HwaSpec,
    pub hwa_clock: ClockDomain,
    /// Request buffer: (decoded request head, arrival ps).
    rb: VecDeque<(HeadFields, Ps)>,
    rb_cap: usize,
    /// Outgoing command packets (grants/notifies) for the PS — the LGB.
    pub cmd_out: VecDeque<HeadFields>,
    tbs: Vec<TaskBuffer>,
    ta_rr: usize,
    hwac: Hwac,
    /// This channel's chaining buffer: completed tasks awaiting the next
    /// HWA in the group. Header info is visible to all group CCs.
    pub chain_out: VecDeque<Task>,
    cb_cap: usize,
    /// Task handed over by a chaining-controller match, pending fetch.
    pub chain_in: Option<Task>,
    /// Result packets awaiting the PS (arena handles; flit storage lives
    /// in the simulation's [`PacketArena`]).
    pub pob: VecDeque<PobEntry>,
    pob_flits: usize,
    pob_cap_flits: usize,
    /// Map src_id -> NoC node for reply routing.
    reply_route: Vec<u8>,
    /// Map src_id -> assigned MMU node (for grants and HwaToMem results;
    /// the floorplan's per-processor nearest/hashed assignment).
    mmu_route: Vec<u8>,
    builder: PacketBuilder,
    /// Scratch input copy handed to the compute hook so its output can be
    /// written straight back into the task's pooled word buffer.
    exec_in: Vec<u32>,
    pub stats: ChannelStats,
    /// Completed tasks log (drained by the fabric for metrics/compute
    /// checks).
    pub completed: Vec<Task>,
    /// `completed[..recycled]` have had their pooled word buffers freed
    /// (see [`Channel::recycle_completed_words`]).
    recycled: usize,
    /// Reconfiguration fence: when set the LGC issues no new grants
    /// (requests keep queueing in the RB) while in-flight tasks drain —
    /// the first phase of a slot swap ([`crate::reconfig`]).
    fenced: bool,
    /// HWA fault injection + this channel's detection counters
    /// ([`crate::fault`]); `None` (the default) leaves every fault hook
    /// compiled out of the hot path behind one branch.
    pub fault: Option<Box<ChannelFaults>>,
}

impl Channel {
    pub fn new(
        hwa_id: u8,
        spec: HwaSpec,
        n_tbs: usize,
        reply_route: Vec<u8>,
        mmu_route: Vec<u8>,
    ) -> Self {
        assert!(!mmu_route.is_empty(), "at least one MMU node");
        let hwa_clock = ClockDomain::from_mhz(spec.name, spec.fmax_mhz);
        Self {
            hwa_id,
            spec,
            hwa_clock,
            rb: VecDeque::new(),
            rb_cap: DEFAULT_RB_CAP,
            cmd_out: VecDeque::new(),
            tbs: (0..n_tbs).map(|_| TaskBuffer::new()).collect(),
            ta_rr: 0,
            hwac: Hwac::Idle,
            chain_out: VecDeque::new(),
            cb_cap: DEFAULT_CB_CAP,
            chain_in: None,
            pob: VecDeque::new(),
            pob_flits: 0,
            pob_cap_flits: DEFAULT_POB_CAP_FLITS,
            reply_route,
            mmu_route,
            builder: PacketBuilder::new(0x8000_0000 | hwa_id as u32),
            exec_in: Vec::new(),
            stats: ChannelStats::default(),
            // Reserved up front so steady-state task retirement never
            // reallocates the log mid-simulation.
            completed: Vec::with_capacity(1024),
            recycled: 0,
            fenced: false,
            fault: None,
        }
    }

    pub fn n_tbs(&self) -> usize {
        self.tbs.len()
    }

    /// The MMU node serving `src_id` (out-of-range ids fall back to the
    /// first route entry — such traffic is rejected upstream anyway).
    fn mmu_for(&self, src_id: u8) -> u8 {
        self.mmu_route
            .get(src_id as usize)
            .copied()
            .unwrap_or(self.mmu_route[0])
    }

    // ------------------------------------------------------------------
    // Interface-clock side: requests, grants, payload fill
    // ------------------------------------------------------------------

    /// A request command packet arrives from the PR. Returns false when the
    /// RB is full (PR must stall).
    pub fn push_request(&mut self, head: HeadFields, now: Ps) -> bool {
        if self.rb.len() >= self.rb_cap {
            return false;
        }
        self.stats.requests += 1;
        self.rb.push_back((head, now));
        true
    }

    pub fn rb_len(&self) -> usize {
        self.rb.len()
    }

    /// LGC step (one interface cycle): issue at most one grant, gated on
    /// TB availability (§4.2 B.2). Selection is highest-priority-first
    /// over the RB (the 2-bit packet priority class serving tenants
    /// carry), FCFS within a class — with the all-zero priorities every
    /// legacy workload stamps, this degenerates to exact FCFS, so
    /// pre-serving schedules stay bit-identical. A request arriving this
    /// same cycle is served immediately when the RB was otherwise empty
    /// — the RB bypass path.
    pub fn step_lgc(&mut self, _now: Ps) {
        if self.fenced {
            return;
        }
        let Some(free_tb) = self
            .tbs
            .iter()
            .position(|tb| tb.state == TbState::Free)
        else {
            return;
        };
        let Some(pick) = self
            .rb
            .iter()
            .enumerate()
            .max_by_key(|(i, (h, _))| (h.priority, std::cmp::Reverse(*i)))
            .map(|(i, _)| i)
        else {
            return;
        };
        let Some((req, t_req)) = self.rb.remove(pick) else {
            return;
        };
        // An unroutable src_id is an untrusted-header error for EVERY
        // direction — even a memory-access grant ultimately notifies the
        // requesting processor — so drop the request (no TB reserved).
        let Some(reply_node) = self.reply_route.get(req.src_id as usize) else {
            self.stats.rejected_flits += 1;
            return;
        };
        // Grant routed to the requester (direct access) or the MMU
        // (memory access), §5 / Fig. 5.
        let grant_dest = match req.direction {
            Direction::MemToHwa => self.mmu_for(req.src_id),
            _ => *reply_node,
        };
        self.tbs[free_tb].grant(t_req);
        self.stats.grants += 1;
        self.push_command(grant_dest, CommandKind::Grant, &req, free_tb as u8);
    }

    /// The single audited constructor for LGB command heads: every command
    /// packet this channel emits (grant or notify) is funnelled through
    /// here so the wire-visible field set stays reviewable in one place.
    ///
    /// * `Grant` echoes the requester's full reservation context back —
    ///   chain fields, priority, direction, address, size — plus the
    ///   reserved `tb_id` the payload packet must target (§4.2 B.2).
    /// * `Notify` carries only the memory address (§5, Fig. 5b): the
    ///   requesting processor learns where the MMU landed the result;
    ///   every other field stays at its wire default.
    /// * `Nack` (CRC reject) echoes the same reservation context as a
    ///   grant — to the sender it *is* a fresh grant for the kept
    ///   reservation, so retransmission reuses the ordinary payload path.
    fn push_command(
        &mut self,
        routing: u8,
        kind: CommandKind,
        template: &HeadFields,
        tb_id: u8,
    ) {
        let mut head = HeadFields {
            routing,
            kind: FlitKind::Single,
            src_id: template.src_id,
            hwa_id: self.hwa_id,
            pkt_type: PacketType::Command,
            start_addr: template.start_addr,
            payload: kind.encode(),
            ..HeadFields::default()
        };
        if matches!(kind, CommandKind::Grant | CommandKind::Nack) {
            head.tb_id = tb_id;
            head.chain_depth = template.chain_depth;
            head.chain_index = template.chain_index;
            head.priority = template.priority;
            head.direction = template.direction;
            head.data_size = template.data_size;
        }
        self.cmd_out.push_back(head);
    }

    /// Payload packet head from the PR (targets the granted TB). The
    /// `tb_id` field is untrusted wire input: an out-of-range index or a
    /// TB that was never granted rejects the packet (counted) instead of
    /// panicking the simulator.
    pub fn payload_head(&mut self, head: HeadFields, flow: u32) -> bool {
        let Some(tb) = self.tbs.get_mut(head.tb_id as usize) else {
            self.stats.rejected_flits += 1;
            return false;
        };
        if tb.state != TbState::Granted {
            self.stats.rejected_flits += 1;
            return false;
        }
        tb.begin_fill(head, flow);
        true
    }

    /// Payload data flit (four u32 lanes); `is_tail` completes the task.
    /// `ready_at` is the CDC-visible time (computed by the PR from this
    /// channel's HWA clock). Returns false (and counts the rejection)
    /// when `tb_id` is out of range or the TB is not mid-fill.
    pub fn payload_data(&mut self, tb_id: u8, lanes: &[u32], is_tail: bool, ready_at: Ps) -> bool {
        let Some(tb) = self.tbs.get_mut(tb_id as usize) else {
            self.stats.rejected_flits += 1;
            return false;
        };
        if tb.state != TbState::Filling {
            self.stats.rejected_flits += 1;
            return false;
        }
        tb.push_words(lanes);
        if is_tail {
            // End-to-end check at the packet receiver: recompute the
            // CRC16 over the staged words and compare it to the stamp in
            // the payload head (crate::flit::fields::PAYLOAD_CRC_LO). A
            // mismatch (in-flight bit flip) discards the fill, keeps the
            // reservation, and NACKs the sender for a retransmit.
            // Unstamped heads (pre-CRC traffic) are accepted unverified.
            let crc_ok = match tb.fill_head().and_then(|h| payload_crc(h.payload)) {
                Some(stamped) => {
                    let n = tb
                        .fill_head()
                        .map(|h| h.data_size as usize / 4)
                        .unwrap_or(0);
                    let words = tb.fill_words();
                    crc16(&words[..n.min(words.len())]) == stamped
                }
                None => true,
            };
            if crc_ok {
                tb.finish_fill(ready_at);
            } else {
                let head = tb.fill_head().copied();
                tb.reset_to_granted();
                match self.fault.as_deref_mut() {
                    Some(f) => f.crc_rejects += 1,
                    None => self.stats.rejected_flits += 1,
                }
                if let Some(head) = head {
                    // NACK back to whoever streams payloads for this
                    // direction (requester, or the MMU for memory
                    // fetches) — same routing rule as the grant.
                    let dest = match head.direction {
                        Direction::MemToHwa => Some(self.mmu_for(head.src_id)),
                        _ => self.reply_route.get(head.src_id as usize).copied(),
                    };
                    match dest {
                        Some(d) => {
                            self.push_command(d, CommandKind::Nack, &head, tb_id)
                        }
                        None => self.stats.rejected_flits += 1,
                    }
                }
            }
        }
        true
    }

    /// Interface-clock watchdog (armed only when fault injection is on):
    /// reclaim task buffers whose reservation went stale because the
    /// grant or its payload packet was lost in flight. Without this, a
    /// lost payload leaks the TB forever and a fully-leaked channel can
    /// never grant again. A late flit for a reclaimed buffer lands on
    /// the ordinary rejected-flit path.
    pub fn step_tb_watchdog(&mut self, now: Ps) {
        let Some(f) = self.fault.as_deref_mut() else {
            return;
        };
        let mut reclaims = 0;
        for tb in &mut self.tbs {
            if matches!(tb.state, TbState::Granted | TbState::Filling)
                && now.saturating_sub(tb.granted_at()) > f.watchdog_ps
            {
                tb.reclaim();
                reclaims += 1;
            }
        }
        f.tb_reclaims += reclaims;
    }

    /// Earliest TB-watchdog deadline, for the idle-skip horizon fold
    /// (skipping past it would delay a reclaim the scheduler owes).
    pub fn tb_watchdog_wake(&self) -> Option<Ps> {
        let f = self.fault.as_deref()?;
        self.tbs
            .iter()
            .filter(|tb| matches!(tb.state, TbState::Granted | TbState::Filling))
            .map(|tb| tb.granted_at() + f.watchdog_ps)
            .min()
    }

    /// CDC visibility horizon for a fill finishing at `now` (2 HWA edges).
    pub fn cdc_ready_at(&self, now: Ps) -> Ps {
        self.hwa_clock.next_edge_after(now) + self.hwa_clock.period_ps
    }

    // ------------------------------------------------------------------
    // HWA-clock side: TA, HWAC, execution, PG
    // ------------------------------------------------------------------

    /// True when the HWA datapath is mid-task.
    pub fn busy(&self) -> bool {
        !matches!(self.hwac, Hwac::Idle)
    }

    /// Interface-clock work pending: the LGC, chaining controller or
    /// packet sender would act on this channel at the next interface
    /// edge. (TBs that are `Granted`/`Filling` wait on PR input, which
    /// keeps the interface domain busy through `router_out` instead.)
    pub fn iface_pending(&self) -> bool {
        !self.rb.is_empty()
            || !self.cmd_out.is_empty()
            || !self.pob.is_empty()
            || !self.chain_out.is_empty()
    }

    /// Scheduler probe for this channel's HWA clock domain (the
    /// [`Activity`] contract). The pipeline FSM's `done_at` deadlines and
    /// the TBs' CDC visibility edges are exact lower bounds: every HWA
    /// edge before them is a no-op except for the `busy_cycles` counter,
    /// which [`Channel::account_idle_cycles`] folds back in.
    pub fn hwa_activity(&self) -> Activity {
        match &self.hwac {
            Hwac::Idle => {
                if self.chain_in.is_some() {
                    return Activity::Busy;
                }
                let mut act = Activity::Idle;
                for tb in &self.tbs {
                    if let Some(t) = tb.ready_wake() {
                        act = act.join(Activity::NextEventAt(t));
                    }
                }
                act
            }
            Hwac::Fetching { done_at, .. }
            | Hwac::Executing { done_at, .. }
            | Hwac::Draining { done_at, .. } => Activity::NextEventAt(*done_at),
            Hwac::Hung { kill_at, .. } => Activity::NextEventAt(*kill_at),
            Hwac::Blocked { .. } => Activity::Busy,
        }
    }

    /// Fold `n` skipped HWA-clock edges into this channel's counters.
    /// Sound only over a window where `busy()` cannot change (guaranteed
    /// by `System::skip_idle`: the skip target never crosses this
    /// domain's `done_at`/wake horizon).
    pub fn account_idle_cycles(&mut self, n: u64) {
        if self.busy() {
            self.stats.busy_cycles += n;
        }
    }

    /// One HWA-clock cycle. Task word buffers live in `arena`; the
    /// compute hook writes its output back into the task's pooled buffer
    /// via a scratch input copy, so steady state allocates nothing.
    pub fn step_hwa(
        &mut self,
        now: Ps,
        compute: &mut dyn HwaCompute,
        arena: &mut PacketArena,
    ) {
        if self.busy() {
            self.stats.busy_cycles += 1;
        }
        let period = self.hwa_clock.period_ps;
        match std::mem::replace(&mut self.hwac, Hwac::Idle) {
            Hwac::Idle => {
                // Chaining requests take priority over TB tasks (§4.2 B.3).
                if let Some(task) = self.chain_in.take() {
                    self.stats.chain_receives += 1;
                    // Fetch latency reflects the words as forwarded; the
                    // buffer is padded to this HWA's width afterwards.
                    let n_flits =
                        payload_packet_flits(arena.words(task.words).len()) - 1;
                    arena.words_mut(task.words).resize(self.spec.in_words, 0);
                    self.hwac = Hwac::Fetching {
                        task,
                        tb: None,
                        done_at: now + (4 + n_flits as u64) * period,
                    };
                    return;
                }
                // Task arbiter: round-robin over ready TBs (1 cycle,
                // folded into the fetch issued this same edge).
                let n = self.tbs.len();
                for k in 0..n {
                    let idx = (self.ta_rr + k) % n;
                    if self.tbs[idx].is_ready(now) {
                        self.ta_rr = (idx + 1) % n;
                        let task =
                            self.tbs[idx].take(self.spec.in_words, now, arena);
                        let n_flits = self.spec.in_packet_flits() - 1;
                        self.hwac = Hwac::Fetching {
                            task,
                            tb: Some(idx),
                            done_at: now + (4 + n_flits as u64) * period,
                        };
                        return;
                    }
                }
            }
            Hwac::Fetching { mut task, tb, done_at } => {
                if now >= done_at {
                    // TB drained: release it for the next grant.
                    if let Some(idx) = tb {
                        self.tbs[idx].release();
                    }
                    task.t_exec_start = now;
                    // Fault injection draws once per task entering
                    // execution: hang (watchdog kills it later) or tag
                    // the eventual result packet for corruption.
                    match self.fault.as_deref_mut().and_then(|f| f.draw_task()) {
                        Some(HwaFault::Hang) => {
                            let dog = self
                                .fault
                                .as_deref()
                                .map(|f| f.watchdog_ps)
                                .unwrap_or(0);
                            self.hwac = Hwac::Hung { task, kill_at: now + dog };
                        }
                        fault => {
                            task.corrupted =
                                matches!(fault, Some(HwaFault::Corrupt));
                            self.hwac = Hwac::Executing {
                                task,
                                done_at: now + self.spec.exec_cycles * period,
                            };
                        }
                    }
                } else {
                    self.hwac = Hwac::Fetching { task, tb, done_at };
                }
            }
            Hwac::Executing { mut task, done_at } => {
                if now >= done_at {
                    task.t_exec_end = now;
                    self.exec_in.clear();
                    self.exec_in.extend_from_slice(arena.words(task.words));
                    compute.compute_into(
                        &self.spec,
                        &self.exec_in,
                        arena.words_mut(task.words),
                    );
                    self.stats.tasks_executed += 1;
                    let n_out = self.spec.out_packet_flits() - 1;
                    self.hwac = Hwac::Draining {
                        task,
                        done_at: now + (4 + n_out as u64) * period,
                    };
                } else {
                    self.hwac = Hwac::Executing { task, done_at };
                }
            }
            Hwac::Draining { task, done_at } => {
                if now >= done_at {
                    self.finish_or_block(task, arena);
                } else {
                    self.hwac = Hwac::Draining { task, done_at };
                }
            }
            Hwac::Blocked { task } => {
                self.stats.pg_stall_cycles += 1;
                self.finish_or_block(task, arena);
            }
            Hwac::Hung { task, kill_at } => {
                if now >= kill_at {
                    // Watchdog kill: reclaim the buffer and drop the
                    // task (never executed, never completed). Its
                    // requester's own timeout machinery re-issues it.
                    arena.free_words(task.words);
                    if let Some(f) = self.fault.as_deref_mut() {
                        f.watchdog_kills += 1;
                    }
                } else {
                    self.hwac = Hwac::Hung { task, kill_at };
                }
            }
        }
    }

    /// Reply route for an untrusted `src_id`, falling back to the MMU node
    /// (and counting the rejection) when the id is unroutable — chained
    /// tasks can carry arbitrary header bits.
    fn reply_dest(&mut self, src_id: u8) -> u8 {
        match self.reply_route.get(src_id as usize) {
            Some(node) => *node,
            None => {
                self.stats.rejected_flits += 1;
                self.mmu_for(src_id)
            }
        }
    }

    /// PG output routing: chain onward or emit a result packet.
    fn finish_or_block(&mut self, task: Task, arena: &mut PacketArena) {
        if task.chain_remaining() > 0 {
            if self.chain_out.len() < self.cb_cap {
                self.stats.chain_forwards += 1;
                self.chain_out.push_back(task);
            } else {
                self.hwac = Hwac::Blocked { task };
            }
            return;
        }
        let flits = self.spec.out_packet_flits();
        if self.pob_flits + flits <= self.pob_cap_flits {
            let handle = self.make_result_packet(arena, &task);
            let len = arena.flits(handle).len();
            self.pob_flits += len;
            self.stats.result_packets += 1;
            self.pob.push_back(PobEntry {
                handle,
                len,
                priority: task.head.priority,
            });
            // Memory-access scenario (§5, Fig. 5b): results go to the MMU;
            // the invoking processor gets a notifying command packet with
            // the memory address in the header.
            if matches!(task.head.direction, Direction::MemToHwa) {
                // The completion notify must reach the requesting
                // processor; an unroutable src_id (possible only via a
                // forged chained header) drops the notify — routing it
                // anywhere else would hand the MMU a command packet it
                // must treat as a grant.
                match self.reply_route.get(task.head.src_id as usize) {
                    Some(&routing) => {
                        self.push_command(routing, CommandKind::Notify, &task.head, 0)
                    }
                    None => self.stats.rejected_flits += 1,
                }
            }
            self.completed.push(task);
        } else {
            self.hwac = Hwac::Blocked { task };
        }
    }

    fn make_result_packet(
        &mut self,
        arena: &mut PacketArena,
        task: &Task,
    ) -> PacketHandle {
        let dest = match task.head.direction {
            Direction::MemToHwa | Direction::HwaToMem => {
                self.mmu_for(task.head.src_id)
            }
            _ => self.reply_dest(task.head.src_id),
        };
        let head = HeadFields {
            routing: dest,
            kind: FlitKind::Head,
            src_id: task.head.src_id,
            hwa_id: self.hwa_id,
            pkt_type: PacketType::Payload,
            task_head: true,
            task_tail: true,
            priority: task.head.priority,
            direction: if matches!(task.head.direction, Direction::MemToHwa) {
                Direction::HwaToMem
            } else {
                Direction::HwaToProc
            },
            start_addr: task.head.start_addr,
            ..HeadFields::default()
        };
        let handle = arena.build_payload(&mut self.builder, head, task.words);
        if task.corrupted {
            if let Some(f) = self.fault.as_deref_mut() {
                // Injected result corruption flips a data bit *after*
                // the CRC16 was stamped from the word buffer, so the
                // packet is wire-valid but fails the receiver's
                // end-to-end check. (Memory-direction results reach an
                // MMU that does not verify — realistic silent
                // corruption; the serving paths all verify.)
                // Constrain the flip to CRC-covered data bits — a flip
                // in the zero-padding lanes would be a fault with no
                // observable effect.
                let n_bits = (arena.words(task.words).len() as u32 * 32).max(1);
                let bit = f.corrupt_bit() % n_bits;
                let flits = arena.flits_mut(handle);
                let idx = 1 + (bit / 128) as usize;
                if idx < flits.len() {
                    let b = bit % 128;
                    flits[idx].raw.0[(b / 64) as usize] ^= 1u64 << (b % 64);
                }
            }
        }
        handle
    }

    /// Flits the PS still has to drain from this channel's POB.
    pub fn pob_backlog_flits(&self) -> usize {
        self.pob_flits
    }

    /// Enqueue a pre-built result packet (baseline rigs and tests): the
    /// flits are copied into the arena so the POB only ever holds handles.
    pub fn push_result_packet(&mut self, arena: &mut PacketArena, p: &Packet) -> bool {
        if self.pob_flits + p.len() > self.pob_cap_flits {
            return false;
        }
        let handle = arena.alloc_packet();
        arena.flits_mut(handle).extend_from_slice(&p.flits);
        self.pob_flits += p.len();
        self.stats.result_packets += 1;
        self.pob.push_back(PobEntry {
            handle,
            len: p.len(),
            priority: p.head().priority,
        });
        true
    }

    /// PS takes the frontmost result packet (after winning arbitration).
    /// Ownership of the arena handle transfers to the caller, who frees
    /// it once the last flit has left.
    pub fn pop_result(&mut self) -> Option<PobEntry> {
        let e = self.pob.pop_front();
        if let Some(ref e) = e {
            self.pob_flits -= e.len;
        }
        e
    }

    /// Highest priority among queued result packets (for priority RR).
    pub fn pob_priority(&self) -> Option<u8> {
        self.pob.front().map(|e| e.priority)
    }

    /// Free the pooled word buffers of tasks retired since the last call.
    /// The `completed` log keeps every [`Task`]'s header and timestamps
    /// for end-of-run metrics; only the word payloads are recycled, so
    /// callers driving a long simulation return buffers to the pool each
    /// step instead of holding one per retired task.
    pub fn recycle_completed_words(&mut self, arena: &mut PacketArena) {
        for task in &self.completed[self.recycled..] {
            arena.free_words(task.words);
        }
        self.recycled = self.completed.len();
    }

    /// All task buffers are free and nothing is mid-flight.
    pub fn quiescent(&self) -> bool {
        !self.busy()
            && self.rb.is_empty()
            && self.chain_in.is_none()
            && self.chain_out.is_empty()
            && self.pob.is_empty()
            && self.cmd_out.is_empty()
            && self.tbs.iter().all(|tb| tb.state == TbState::Free)
    }

    // ------------------------------------------------------------------
    // Partial reconfiguration (drain / fence / swap carry-over)
    // ------------------------------------------------------------------

    /// Raise or drop the reconfiguration fence (see [`Channel::fenced`]).
    pub fn set_fenced(&mut self, fenced: bool) {
        self.fenced = fenced;
    }

    /// Whether the LGC is currently fenced for reconfiguration.
    pub fn fenced(&self) -> bool {
        self.fenced
    }

    /// Drained enough to swap the slot's accelerator: [`Channel::quiescent`]
    /// *except* for the RB — queued requests survive a swap (they carry
    /// over to the successor channel), but every granted/fetched/executing
    /// task, chained hand-off, pending command and result packet must have
    /// left the channel first. No arena handle may still be owned here.
    pub fn drained_for_reconfig(&self) -> bool {
        !self.busy()
            && self.chain_in.is_none()
            && self.chain_out.is_empty()
            && self.pob.is_empty()
            && self.cmd_out.is_empty()
            && self.tbs.iter().all(|tb| tb.state == TbState::Free)
    }

    /// Seed a freshly built replacement channel with the victim's
    /// accumulated state: counters, the completed-task log (with its
    /// recycle watermark) and every request still queued in the RB — the
    /// drain/quiesce contract is that a swap never drops or reorders
    /// work. The slot's clock tree is part of the static region, so the
    /// successor inherits the victim's HWA clock period too.
    pub fn inherit_for_reconfig(&mut self, old: &mut Channel) {
        debug_assert!(old.drained_for_reconfig());
        self.stats = old.stats;
        std::mem::swap(&mut self.completed, &mut old.completed);
        self.recycled = old.recycled;
        self.hwa_clock = old.hwa_clock.clone();
        // The successor slot keeps the victim's fault stream and
        // detection counters — injection follows the physical slot, not
        // the accelerator occupying it.
        std::mem::swap(&mut self.fault, &mut old.fault);
        while let Some(e) = old.rb.pop_front() {
            self.rb.push_back(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::hwa::{spec_by_name, EchoCompute};

    fn channel(name: &str, tbs: usize) -> Channel {
        Channel::new(0, spec_by_name(name).unwrap(), tbs, vec![0; 8], vec![7; 8])
    }

    fn request(src: u8) -> HeadFields {
        HeadFields {
            src_id: src,
            pkt_type: PacketType::Command,
            direction: Direction::ProcToHwa,
            ..HeadFields::default()
        }
    }

    /// Drive the channel's HWA clock until predicate or timeout.
    fn run_hwa(
        ch: &mut Channel,
        arena: &mut PacketArena,
        cycles: u64,
        mut until: impl FnMut(&Channel) -> bool,
    ) -> u64 {
        let mut compute = EchoCompute;
        let period = ch.hwa_clock.period_ps;
        let mut now = 0;
        for c in 0..cycles {
            now += period;
            ch.step_hwa(now, &mut compute, arena);
            if until(ch) {
                return c + 1;
            }
        }
        cycles
    }

    fn fill_tb(ch: &mut Channel, tb_id: u8, words: usize) {
        let head = HeadFields {
            tb_id,
            task_head: true,
            task_tail: true,
            ..HeadFields::default()
        };
        assert!(ch.payload_head(head, 1));
        let lanes: Vec<u32> = (0..words as u32).collect();
        for (i, chunk) in lanes.chunks(4).enumerate() {
            let tail = (i + 1) * 4 >= words;
            ch.payload_data(tb_id, chunk, tail, 0);
        }
    }

    #[test]
    fn grant_issued_fcfs_when_tb_free() {
        let mut ch = channel("dfadd", 2);
        assert!(ch.push_request(request(1), 100));
        assert!(ch.push_request(request(2), 100));
        assert!(ch.push_request(request(3), 100));
        ch.step_lgc(200);
        ch.step_lgc(300);
        ch.step_lgc(400); // no TB left: queued
        assert_eq!(ch.cmd_out.len(), 2);
        let g1 = ch.cmd_out.pop_front().unwrap();
        assert_eq!(g1.src_id, 1);
        assert_eq!(CommandKind::decode(g1.payload), CommandKind::Grant);
        assert_eq!(g1.tb_id, 0);
        let g2 = ch.cmd_out.pop_front().unwrap();
        assert_eq!(g2.src_id, 2);
        assert_eq!(g2.tb_id, 1);
        assert_eq!(ch.rb_len(), 1, "third request waits");
    }

    #[test]
    fn lgc_grants_highest_priority_first_fcfs_within_class() {
        // One TB: the serving tier's priority classes reorder the RB.
        // Arrival order lo(1), hi(2), hi(3), mid(4); grant order must be
        // hi(2), hi(3) (FCFS within the class), mid(4), lo(1).
        let mut ch = channel("dfadd", 1);
        for (src, prio) in [(1u8, 0u8), (2, 3), (3, 3), (4, 2)] {
            let mut r = request(src);
            r.priority = prio;
            assert!(ch.push_request(r, 0));
        }
        let mut order = Vec::new();
        for _ in 0..4 {
            ch.step_lgc(100);
            let g = ch.cmd_out.pop_front().expect("grant issued");
            order.push(g.src_id);
            // Free the TB again (bypass the datapath for this test).
            ch.tbs[0].state = TbState::Free;
        }
        assert_eq!(order, vec![2, 3, 4, 1]);
    }

    #[test]
    fn grant_gated_on_tb_availability() {
        let mut ch = channel("dfadd", 1);
        ch.push_request(request(1), 0);
        ch.push_request(request(2), 0);
        ch.step_lgc(100);
        ch.step_lgc(200);
        assert_eq!(ch.cmd_out.len(), 1, "second grant held until TB frees");
    }

    #[test]
    fn task_executes_and_produces_result_packet() {
        let mut arena = PacketArena::new();
        let mut ch = channel("dfadd", 2);
        ch.push_request(request(1), 0);
        ch.step_lgc(0);
        fill_tb(&mut ch, 0, 4);
        let cycles = run_hwa(&mut ch, &mut arena, 1000, |c| !c.pob.is_empty());
        assert!(cycles < 1000, "task completed");
        let e = ch.pop_result().unwrap();
        let p = arena.to_packet(e.handle);
        assert_eq!(p.len(), e.len);
        assert!(p.is_well_formed());
        assert_eq!(p.head().hwa_id, 0);
        assert_eq!(p.head().direction, Direction::HwaToProc);
        assert_eq!(e.priority, p.head().priority);
        assert_eq!(ch.stats.tasks_executed, 1);
        // dfadd: fetch(4+1) + exec(6) + drain(4+1) = 16 cycles minimum.
        assert!(cycles >= 16, "cycles={cycles}");
    }

    #[test]
    fn table2_hwac_pg_latency_structure() {
        // HWAC fetch = 4 + N_in cycles; PG = 4 + N_out cycles; exec between.
        let mut arena = PacketArena::new();
        let mut ch = channel("izigzag", 2);
        ch.push_request(request(0), 0);
        ch.step_lgc(0);
        fill_tb(&mut ch, 0, 64); // 16 data flits
        let cycles = run_hwa(&mut ch, &mut arena, 1000, |c| !c.pob.is_empty());
        // fetch 4+16, exec 1, drain 4+16 = 41; TA/pipeline edges may add 1.
        assert!((41..=43).contains(&cycles), "cycles={cycles}");
    }

    #[test]
    fn chaining_task_goes_to_cb_not_pob() {
        let mut ch = channel("izigzag", 2);
        let mut req = request(1);
        req.chain_depth = 1;
        req.chain_index = [2, 0, 0];
        ch.push_request(req, 0);
        ch.step_lgc(0);
        // Payload head must carry the chain fields (echoed from grant).
        let head = HeadFields {
            tb_id: 0,
            chain_depth: 1,
            chain_index: [2, 0, 0],
            task_head: true,
            task_tail: true,
            ..HeadFields::default()
        };
        assert!(ch.payload_head(head, 1));
        let lanes: Vec<u32> = (0..64).collect();
        for (i, chunk) in lanes.chunks(4).enumerate() {
            ch.payload_data(0, chunk, i == 15, 0);
        }
        let mut arena = PacketArena::new();
        run_hwa(&mut ch, &mut arena, 1000, |c| !c.chain_out.is_empty());
        assert_eq!(ch.chain_out.len(), 1);
        assert!(ch.pob.is_empty());
        assert_eq!(ch.stats.chain_forwards, 1);
    }

    #[test]
    fn chain_in_has_priority_over_tb() {
        let mut arena = PacketArena::new();
        let mut ch = channel("dfadd", 2);
        // Ready TB task:
        ch.push_request(request(1), 0);
        ch.step_lgc(0);
        fill_tb(&mut ch, 0, 4);
        // And a chained task:
        let chained =
            Task::new(HeadFields::default(), arena.alloc_words_from(&[7, 7]), 9);
        ch.chain_in = Some(chained);
        let mut compute = EchoCompute;
        ch.step_hwa(ch.hwa_clock.period_ps, &mut compute, &mut arena);
        assert_eq!(ch.stats.chain_receives, 1, "chained task picked first");
        assert!(matches!(ch.hwac, Hwac::Fetching { tb: None, .. }));
    }

    #[test]
    fn pg_blocks_on_full_cb_until_space() {
        let mut arena = PacketArena::new();
        let mut ch = channel("izigzag", 2);
        // Fill the CB to capacity manually.
        for _ in 0..DEFAULT_CB_CAP {
            ch.chain_out.push_back(Task::new(
                HeadFields::default(),
                arena.alloc_words(),
                0,
            ));
        }
        let mut t = Task::new(
            HeadFields {
                chain_depth: 1,
                ..HeadFields::default()
            },
            arena.alloc_words_from(&[1]),
            0,
        );
        t.t_exec_end = 1;
        ch.hwac = Hwac::Blocked { task: t };
        let mut compute = EchoCompute;
        ch.step_hwa(100, &mut compute, &mut arena);
        assert!(matches!(ch.hwac, Hwac::Blocked { .. }), "still blocked");
        ch.chain_out.pop_front();
        ch.step_hwa(200, &mut compute, &mut arena);
        assert!(matches!(ch.hwac, Hwac::Idle));
        assert_eq!(ch.chain_out.len(), DEFAULT_CB_CAP);
    }

    #[test]
    fn two_tbs_overlap_fill_and_exec() {
        // With 2 TBs, a second grant is issued while the first task runs.
        let mut ch = channel("dfdiv", 2);
        ch.push_request(request(1), 0);
        ch.push_request(request(2), 0);
        ch.step_lgc(0);
        ch.step_lgc(0);
        assert_eq!(ch.cmd_out.len(), 2, "both grants out with 2 TBs");
        let mut ch1 = channel("dfdiv", 1);
        ch1.push_request(request(1), 0);
        ch1.push_request(request(2), 0);
        ch1.step_lgc(0);
        ch1.step_lgc(0);
        assert_eq!(ch1.cmd_out.len(), 1, "single TB serializes grants");
    }

    #[test]
    fn quiescent_reflects_state() {
        let mut ch = channel("dfadd", 2);
        assert!(ch.quiescent());
        ch.push_request(request(1), 0);
        assert!(!ch.quiescent());
    }

    #[test]
    fn out_of_range_tb_id_is_rejected_not_a_panic() {
        // tb_id is a 2-bit wire field; with 2 TBs configured, 3 is out of
        // range. Both the head and data paths must reject and count it.
        let mut ch = channel("dfadd", 2);
        let head = HeadFields {
            tb_id: 3,
            task_head: true,
            task_tail: true,
            ..HeadFields::default()
        };
        assert!(!ch.payload_head(head, 1));
        assert!(!ch.payload_data(3, &[1, 2, 3, 4], true, 0));
        assert_eq!(ch.stats.rejected_flits, 2);
        assert!(ch.quiescent(), "rejected traffic leaves no state behind");
    }

    #[test]
    fn crc_mismatch_nacks_and_keeps_reservation() {
        use crate::flit::payload_with_crc;
        let mut ch = channel("dfadd", 2);
        ch.push_request(request(1), 0);
        ch.step_lgc(0);
        ch.cmd_out.clear(); // drop the grant; we drive the fill directly
        let words = [10u32, 11, 12, 13];
        let good = crc16(&words);
        let bad_head = HeadFields {
            tb_id: 0,
            task_head: true,
            task_tail: true,
            data_size: 16,
            payload: payload_with_crc(0, good ^ 1),
            ..HeadFields::default()
        };
        assert!(ch.payload_head(bad_head, 1));
        assert!(ch.payload_data(0, &words, true, 0));
        // Rejected: NACK queued, reservation kept, nothing ready.
        assert_eq!(ch.tbs[0].state, TbState::Granted);
        let nack = ch.cmd_out.pop_front().expect("nack emitted");
        assert_eq!(CommandKind::decode(nack.payload), CommandKind::Nack);
        assert_eq!(nack.tb_id, 0, "nack names the kept reservation");
        assert_eq!(ch.stats.rejected_flits, 1, "counted (no fault state)");
        // Retransmit with a matching stamp completes the fill.
        let good_head = HeadFields {
            payload: payload_with_crc(0, good),
            ..bad_head
        };
        assert!(ch.payload_head(good_head, 1));
        assert!(ch.payload_data(0, &words, true, 0));
        assert_eq!(ch.tbs[0].state, TbState::Ready);
    }

    #[test]
    fn hung_task_is_killed_by_watchdog_not_executed() {
        use crate::fault::ChannelFaults;
        let mut arena = PacketArena::new();
        let mut ch = channel("dfadd", 2);
        let watchdog = 40 * ch.hwa_clock.period_ps;
        ch.fault = Some(Box::new(ChannelFaults::new(1, 0, 1.0, 0.0, watchdog)));
        ch.push_request(request(1), 0);
        ch.step_lgc(0);
        fill_tb(&mut ch, 0, 4);
        let cycles = run_hwa(&mut ch, &mut arena, 1000, |c| {
            c.fault.as_ref().is_some_and(|f| f.watchdog_kills == 1)
        });
        assert!(cycles < 1000, "watchdog fired");
        assert_eq!(ch.stats.tasks_executed, 0, "hung task never executed");
        assert!(ch.pob.is_empty(), "no result packet");
        assert!(!ch.busy(), "channel recovered to idle");
        let f = ch.fault.as_ref().unwrap();
        assert_eq!(f.hangs, 1);
        assert_eq!(f.stats().injected, 1);
        assert_eq!(f.stats().detected, 1);
    }

    #[test]
    fn corrupted_result_fails_the_receiver_crc_check() {
        use crate::fault::ChannelFaults;
        let mut arena = PacketArena::new();
        let mut ch = channel("dfadd", 2);
        ch.fault = Some(Box::new(ChannelFaults::new(2, 0, 0.0, 1.0, 1_000)));
        ch.push_request(request(1), 0);
        ch.step_lgc(0);
        fill_tb(&mut ch, 0, 4);
        let cycles = run_hwa(&mut ch, &mut arena, 1000, |c| !c.pob.is_empty());
        assert!(cycles < 1000);
        let e = ch.pop_result().unwrap();
        let p = arena.to_packet(e.handle);
        assert!(p.is_well_formed(), "corruption keeps wire framing intact");
        let stamped = crate::flit::payload_crc(p.head().payload)
            .expect("result heads carry a CRC");
        let n = p.head().data_size as usize / 4;
        assert_ne!(
            crc16(&p.data_words(n)),
            stamped,
            "receiver-side check detects the flip"
        );
        assert_eq!(ch.fault.as_ref().unwrap().corrupts, 1);
    }

    #[test]
    fn stale_tb_reservation_reclaimed_by_watchdog() {
        use crate::fault::ChannelFaults;
        let mut ch = channel("dfadd", 2);
        ch.fault = Some(Box::new(ChannelFaults::new(3, 0, 0.0, 0.0, 5_000)));
        ch.push_request(request(1), 100);
        ch.step_lgc(100);
        assert_eq!(ch.tbs[0].state, TbState::Granted);
        assert_eq!(ch.tb_watchdog_wake(), Some(100 + 5_000));
        ch.step_tb_watchdog(2_000); // too early
        assert_eq!(ch.tbs[0].state, TbState::Granted);
        ch.step_tb_watchdog(10_000);
        assert_eq!(ch.tbs[0].state, TbState::Free, "reservation reclaimed");
        assert_eq!(ch.fault.as_ref().unwrap().tb_reclaims, 1);
        assert_eq!(ch.tb_watchdog_wake(), None);
        // A late payload head for the reclaimed TB is plain rejection.
        assert!(!ch.payload_head(
            HeadFields {
                tb_id: 0,
                ..HeadFields::default()
            },
            1
        ));
        assert_eq!(ch.stats.rejected_flits, 1);
    }

    #[test]
    fn payload_for_ungranted_tb_is_rejected() {
        let mut ch = channel("dfadd", 2);
        // TB 0 exists but was never granted.
        assert!(!ch.payload_head(
            HeadFields {
                tb_id: 0,
                ..HeadFields::default()
            },
            1
        ));
        // Data for a TB that is not filling.
        assert!(!ch.payload_data(0, &[9, 9, 9, 9], false, 0));
        assert_eq!(ch.stats.rejected_flits, 2);
    }

    #[test]
    fn unroutable_src_id_request_is_dropped_without_reserving_a_tb() {
        // A short reply route (2 entries) with a 3-bit src_id of 5: the
        // LGC must drop the request, reserve nothing and count it.
        let mut ch = Channel::new(
            0,
            spec_by_name("dfadd").unwrap(),
            2,
            vec![0; 2],
            vec![7; 8],
        );
        assert!(ch.push_request(request(5), 0));
        ch.step_lgc(100);
        assert_eq!(ch.cmd_out.len(), 0, "no grant for an unroutable source");
        assert_eq!(ch.stats.rejected_flits, 1);
        assert_eq!(ch.stats.grants, 0);
        assert!(
            ch.tbs.iter().all(|tb| tb.state == TbState::Free),
            "no TB leaked"
        );
        // A routable request still succeeds afterwards.
        assert!(ch.push_request(request(1), 200));
        ch.step_lgc(300);
        assert_eq!(ch.cmd_out.len(), 1);
        assert_eq!(ch.stats.grants, 1);
        // Memory-access requests validate src_id too: the completion
        // notify must eventually reach the requesting processor.
        let mut mem_req = request(6);
        mem_req.direction = Direction::MemToHwa;
        assert!(ch.push_request(mem_req, 400));
        ch.step_lgc(500);
        assert_eq!(ch.stats.rejected_flits, 2);
        assert_eq!(ch.stats.grants, 1, "no grant for the forged mem request");
    }
}
