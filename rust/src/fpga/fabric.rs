//! The FPGA node (paper Fig. 2a): router input/output asynchronous FIFOs,
//! distributed packet receivers, hierarchical packet sender, HWA channels
//! and the chaining-controller fabric.
//!
//! Clocking: `step_noc_*` run on the NoC clock (router-buffer sides),
//! `step_iface` on the interface clock (PR, LGC, PS, CC), and
//! `step_channel` per HWA clock domain. The simulation system drives
//! these from a [`crate::clock::MultiClock`].

use crate::clock::{Activity, AsyncFifo, ClockDomain, Ps};
use crate::flit::{Flit, PacketArena};

use super::channel::Channel;
use super::hwa::{EchoCompute, HwaCompute, HwaSpec};
use super::iface::pr::{PacketReceiver, PrStrategy};
use super::iface::ps::{PacketSender, PsStrategy};

/// Router-buffer depth in flits (asynchronous FIFOs, Fig. 2a).
pub const ROUTER_FIFO_CAP: usize = 32;

/// A chaining group: ordered set of channel indices whose HWAs may chain
/// (§4.2 B.3). `chain_index` values in headers index into `members`.
#[derive(Debug, Clone)]
pub struct ChainGroup {
    pub members: Vec<usize>,
    rr: usize,
}

impl ChainGroup {
    pub fn new(members: Vec<usize>) -> Self {
        assert!(members.len() <= 4, "chain_index is 2 bits per hop");
        Self { members, rr: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct FpgaConfig {
    pub n_tbs: usize,
    pub pr: PrStrategy,
    pub ps: PsStrategy,
    pub iface_mhz: f64,
    /// NoC node this FPGA interface tile occupies.
    pub node: u8,
    /// Map src_id (processor id) -> assigned MMU node (floorplans may
    /// carry several MMU tiles; single-MMU systems repeat one node).
    pub mmu_route: Vec<u8>,
    /// Map src_id (processor id) -> NoC node, for reply routing.
    pub reply_route: Vec<u8>,
}

impl FpgaConfig {
    /// Paper defaults: 2 TBs (§6.2), PR4-PS4 (§6.3), 300 MHz (§6.1),
    /// every processor served by the one `mmu_node`.
    pub fn paper_defaults(node: u8, mmu_node: u8, reply_route: Vec<u8>) -> Self {
        Self {
            n_tbs: 2,
            pr: PrStrategy::distributed(4),
            ps: PsStrategy::hierarchical(4),
            iface_mhz: 300.0,
            node,
            mmu_route: vec![mmu_node; 8],
            reply_route,
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct FabricStats {
    /// Flits received from the NoC (injection side of §6.4's metrics).
    pub flits_from_noc: u64,
    /// Flits sent to the NoC (throughput side of §6.4's metrics).
    pub flits_to_noc: u64,
    /// Interface cycles with at least one busy HWA.
    pub busy_iface_cycles: u64,
    pub iface_cycles: u64,
    /// Completed accelerator slot swaps ([`crate::reconfig`]).
    pub reconfig_swaps: u64,
    /// Interface cycles some slot spent fenced, waiting for its in-flight
    /// tasks to drain before reprogramming.
    pub reconfig_drain_cycles: u64,
    /// Interface cycles some slot spent busy-reconfiguring (bitstream
    /// programming; the slot serves nothing, requests queue in its RB).
    pub reconfig_blocked_cycles: u64,
}

/// Controller FSM phase of one in-flight slot swap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReconfigPhase {
    /// Victim channel fenced; waiting for
    /// [`Channel::drained_for_reconfig`].
    Draining,
    /// Bitstream streaming into the slot; swap lands at `done_at`.
    Programming { done_at: Ps },
}

/// One in-flight slot swap (see [`crate::reconfig`] for the policy layer
/// and latency model that feed this).
#[derive(Debug, Clone)]
pub struct ActiveReconfig {
    /// Victim channel index.
    pub channel: usize,
    /// The accelerator type being programmed in.
    pub target: HwaSpec,
    /// Programming latency applied once the drain completes.
    pub latency_ps: Ps,
    pub phase: ReconfigPhase,
}

pub struct Fpga {
    pub config: FpgaConfig,
    pub iface_clock: ClockDomain,
    /// NoC -> fabric (read on the interface clock).
    router_out: AsyncFifo<Flit>,
    /// Fabric -> NoC (read on the NoC clock).
    router_in: AsyncFifo<Flit>,
    prs: Vec<PacketReceiver>,
    ps: PacketSender,
    pub channels: Vec<Channel>,
    /// hwa_id -> channel index.
    id_map: Vec<Option<usize>>,
    chain_groups: Vec<ChainGroup>,
    compute: Box<dyn HwaCompute>,
    /// PR currently holding the input stream (payload packets span cycles).
    active_pr: Option<usize>,
    /// In-flight slot swaps (at most one per channel).
    reconfigs: Vec<ActiveReconfig>,
    /// Swaps that landed since the last [`Fpga::take_completed_swaps`]
    /// (channel index, new spec) — the system layer uses these to update
    /// its inventory view and retarget serving sources.
    completed_swaps: Vec<(usize, HwaSpec)>,
    pub stats: FabricStats,
}

impl Fpga {
    pub fn new(config: FpgaConfig, specs: Vec<HwaSpec>, noc_clock: &ClockDomain) -> Self {
        let iface_clock = ClockDomain::from_mhz("iface", config.iface_mhz);
        let n = specs.len();
        assert!(n <= 32, "hwa_id is 5 bits");
        let mut id_map = vec![None; 32];
        let channels: Vec<Channel> = specs
            .into_iter()
            .enumerate()
            .map(|(i, spec)| {
                id_map[i] = Some(i);
                Channel::new(
                    i as u8,
                    spec,
                    config.n_tbs,
                    config.reply_route.clone(),
                    config.mmu_route.clone(),
                )
            })
            .collect();
        let n_prs = config.pr.n_prs(n);
        Self {
            router_out: AsyncFifo::new(ROUTER_FIFO_CAP, &iface_clock),
            router_in: AsyncFifo::new(ROUTER_FIFO_CAP, noc_clock),
            prs: (0..n_prs).map(|_| PacketReceiver::new()).collect(),
            ps: PacketSender::new(config.ps, n),
            channels,
            id_map,
            chain_groups: Vec::new(),
            compute: Box::new(EchoCompute),
            active_pr: None,
            reconfigs: Vec::new(),
            completed_swaps: Vec::new(),
            iface_clock,
            config,
            stats: FabricStats::default(),
        }
    }

    /// Install the functional compute hook (PJRT/native/echo).
    pub fn set_compute(&mut self, compute: Box<dyn HwaCompute>) {
        self.compute = compute;
    }

    /// Register a chaining group over channel indices.
    pub fn add_chain_group(&mut self, members: Vec<usize>) {
        self.chain_groups.push(ChainGroup::new(members));
    }

    pub fn chain_group_members(&self, group: usize) -> &[usize] {
        &self.chain_groups[group].members
    }

    // ------------------------------------------------------------------
    // NoC-clock side
    // ------------------------------------------------------------------

    /// Can the FPGA absorb one more flit from the NoC this cycle?
    pub fn can_accept_from_noc(&self) -> bool {
        self.router_out.can_push()
    }

    /// Deliver a flit ejected at the FPGA node.
    pub fn push_from_noc(&mut self, now: Ps, flit: Flit) {
        let ok = self.router_out.push(now, flit);
        debug_assert!(ok, "caller must check can_accept_from_noc");
        self.stats.flits_from_noc += 1;
    }

    /// Test/bench hook: push a flit directly into the router-output
    /// buffer, bypassing the mesh (used by micro-rigs).
    pub fn router_out_push_for_test(&mut self, now: Ps, flit: Flit) -> bool {
        self.router_out.push(now, flit)
    }

    /// One flit (if any) for NoC injection this cycle.
    pub fn pop_to_noc(&mut self, now: Ps) -> Option<Flit> {
        let f = self.router_in.pop(now);
        if f.is_some() {
            self.stats.flits_to_noc += 1;
        }
        f
    }

    pub fn peek_to_noc(&self, now: Ps) -> Option<&Flit> {
        self.router_in.peek(now)
    }

    /// NoC-side scheduler probe: flits queued (even if not yet CDC-
    /// visible) toward the interconnect keep the NoC domain busy.
    pub fn noc_tx_pending(&self) -> bool {
        !self.router_in.is_empty()
    }

    // ------------------------------------------------------------------
    // Interface-clock side
    // ------------------------------------------------------------------

    /// Fold `n` skipped interface cycles into the busy-fraction counters.
    /// The numerator folds too: with per-domain event horizons the
    /// interface domain skips edges while an HWA is mid-execution (its
    /// channel reports `NextEventAt(done_at)`), and naive stepping would
    /// have counted every one of those edges as busy. Sound because
    /// `busy()` cannot change inside a skipped window (no HWA edge is
    /// skipped past its horizon).
    pub fn account_idle_iface_cycles(&mut self, n: u64) {
        self.stats.iface_cycles += n;
        if self.channels.iter().any(|c| c.busy()) {
            self.stats.busy_iface_cycles += n;
        }
    }

    /// Interface-domain scheduler probe (the [`Activity`] contract): the
    /// PR path (router_out + receivers), PS path (sender + every
    /// channel's grant/result queues) and chaining controllers all run on
    /// the interface clock; any of them holding work makes every
    /// interface edge meaningful. With all of them drained the domain is
    /// purely event-driven — channels mid-execution only affect the
    /// busy-cycle statistics, which the idle fold reproduces.
    pub fn iface_activity(&self) -> Activity {
        if !self.router_out.is_empty()
            || self.prs.iter().any(|p| !p.idle())
            || !self.ps.idle()
            || self.channels.iter().any(|c| c.iface_pending())
            || !self.reconfigs.is_empty()
        {
            return Activity::Busy;
        }
        // Under fault injection, granted-but-never-filled task buffers
        // schedule a watchdog reclaim; skipping past it would leak the
        // reservation for the rest of the window.
        let mut act = Activity::Idle;
        for c in &self.channels {
            if let Some(t) = c.tb_watchdog_wake() {
                act = act.join(Activity::NextEventAt(t));
            }
        }
        act
    }

    /// Scheduler probe for one HWA clock domain (`chans` = the channels
    /// sharing it, from [`Fpga::hwa_domains`]).
    pub fn hwa_domain_activity(&self, chans: &[usize]) -> Activity {
        let mut act = Activity::Idle;
        for &i in chans {
            act = act.join(self.channels[i].hwa_activity());
            if act == Activity::Busy {
                break;
            }
        }
        act
    }

    /// Fold `n` skipped HWA-clock edges into each of `chans`' counters.
    pub fn account_idle_hwa_cycles(&mut self, chans: &[usize], n: u64) {
        for &i in chans {
            self.channels[i].account_idle_cycles(n);
        }
    }

    pub fn step_iface(&mut self, now: Ps, arena: &mut PacketArena) {
        self.stats.iface_cycles += 1;
        if self.channels.iter().any(|c| c.busy()) {
            self.stats.busy_iface_cycles += 1;
        }
        // Reconfiguration controllers (one FSM per in-flight swap).
        self.step_reconfigs(now);
        // Chaining controllers (combinational, §4.2 B.3).
        self.step_chain_controllers(arena);
        // Packet receiver(s): the input stream is serial; the PR owning
        // the in-flight packet (or the one selected by the head flit's
        // hwa_id) advances.
        self.step_pr(now);
        // Local grant controllers (1/cycle each, §4.2 B.2), plus the
        // stuck-reservation watchdog (a no-op unless fault injection
        // armed the channel).
        for ch in self.channels.iter_mut() {
            ch.step_lgc(now);
            ch.step_tb_watchdog(now);
        }
        // Packet sender into the router input buffer.
        let router_in = &mut self.router_in;
        let mut pushed = |f: Flit| router_in.push(now, f);
        self.ps.step(&mut self.channels, arena, &mut pushed);
    }

    // ------------------------------------------------------------------
    // Dynamic partial reconfiguration ([`crate::reconfig`])
    // ------------------------------------------------------------------

    /// Start swapping `channel`'s accelerator for `target`: the slot is
    /// fenced (no new grants; requests keep queueing in its RB), drains
    /// its in-flight tasks, then spends `latency_ps` busy-reconfiguring
    /// before the new core goes live. Errors if the channel index is out
    /// of range or the slot is already mid-swap.
    pub fn begin_reconfig(
        &mut self,
        channel: usize,
        target: HwaSpec,
        latency_ps: Ps,
    ) -> Result<(), String> {
        if channel >= self.channels.len() {
            return Err(format!(
                "reconfig: channel {channel} out of range (fabric has {})",
                self.channels.len()
            ));
        }
        if self.reconfiguring(channel) {
            return Err(format!("reconfig: channel {channel} already mid-swap"));
        }
        self.channels[channel].set_fenced(true);
        self.reconfigs.push(ActiveReconfig {
            channel,
            target,
            latency_ps,
            phase: ReconfigPhase::Draining,
        });
        Ok(())
    }

    /// Is `channel` currently draining or programming?
    pub fn reconfiguring(&self, channel: usize) -> bool {
        self.reconfigs.iter().any(|r| r.channel == channel)
    }

    /// In-flight swaps (read-only view for topology/state reporting).
    pub fn active_reconfigs(&self) -> &[ActiveReconfig] {
        &self.reconfigs
    }

    /// Take the swaps that completed since the last call.
    pub fn take_completed_swaps(&mut self) -> Vec<(usize, HwaSpec)> {
        std::mem::take(&mut self.completed_swaps)
    }

    /// Advance every in-flight swap by one interface cycle: count drain
    /// or blocked cycles, move Draining slots to Programming once the
    /// victim channel is quiescent-except-RB, and land finished swaps by
    /// rebuilding the channel around the new spec (stats, queued
    /// requests, completions and the slot's fixed clock tree carry over
    /// via [`Channel::inherit_for_reconfig`]).
    fn step_reconfigs(&mut self, now: Ps) {
        if self.reconfigs.is_empty() {
            return;
        }
        let mut landed: Vec<usize> = Vec::new();
        for (i, r) in self.reconfigs.iter_mut().enumerate() {
            match r.phase {
                ReconfigPhase::Draining => {
                    self.stats.reconfig_drain_cycles += 1;
                    if self.channels[r.channel].drained_for_reconfig() {
                        r.phase = ReconfigPhase::Programming {
                            done_at: now + r.latency_ps,
                        };
                    }
                }
                ReconfigPhase::Programming { done_at } => {
                    self.stats.reconfig_blocked_cycles += 1;
                    if now >= done_at {
                        landed.push(i);
                    }
                }
            }
        }
        // Land in reverse index order so swap_remove-style removal by
        // index stays valid.
        for &i in landed.iter().rev() {
            let r = self.reconfigs.remove(i);
            let mut ch = Channel::new(
                r.channel as u8,
                r.target.clone(),
                self.config.n_tbs,
                self.config.reply_route.clone(),
                self.config.mmu_route.clone(),
            );
            ch.inherit_for_reconfig(&mut self.channels[r.channel]);
            self.channels[r.channel] = ch;
            self.stats.reconfig_swaps += 1;
            self.completed_swaps.push((r.channel, r.target));
        }
    }

    fn step_pr(&mut self, now: Ps) {
        let pr_idx = match self.active_pr {
            Some(i) if !self.prs[i].idle() => i,
            _ => {
                // Select by the head flit waiting at the router buffer.
                let Some(flit) = self.router_out.peek(now) else {
                    return;
                };
                debug_assert!(flit.is_head());
                let hwa = flit.head_fields().hwa_id;
                // Unknown HWA ids go to PR 0 to be consumed/dropped.
                let i = match self.id_map[hwa as usize] {
                    Some(chan) => self.config.pr.pr_for(chan),
                    None => 0,
                };
                self.active_pr = Some(i);
                i
            }
        };
        let id_map = &self.id_map;
        let lookup = move |id: u8| id_map[id as usize];
        self.prs[pr_idx].step(now, &mut self.router_out, &mut self.channels, &lookup);
    }

    fn step_chain_controllers(&mut self, arena: &mut PacketArena) {
        for group in self.chain_groups.iter_mut() {
            let m = group.members.len();
            if m == 0 {
                continue;
            }
            // RR over producer CBs; one transfer per group per cycle.
            for k in 0..m {
                let prod = group.members[(group.rr + k) % m];
                let Some(task) = self.channels[prod].chain_out.front() else {
                    continue;
                };
                let next_idx = task.head.chain_index[0] as usize;
                if next_idx >= m {
                    // Malformed index (a hop naming no group member — the
                    // driver rejects these at construction, so only forged
                    // wire traffic reaches here): drop the task and count
                    // it like every other untrusted-header rejection.
                    // Keeps the fabric live. The dropped task's pooled
                    // word buffer goes back to the arena.
                    self.channels[prod].stats.rejected_flits += 1;
                    if let Some(task) = self.channels[prod].chain_out.pop_front() {
                        arena.free_words(task.words);
                    }
                    continue;
                }
                let target = group.members[next_idx];
                // A fenced (reconfiguring) consumer accepts no hand-offs;
                // the task waits in the producer's CB until the fence
                // lifts, preserving order.
                if self.channels[target].chain_in.is_none()
                    && !self.channels[target].fenced()
                {
                    let mut task =
                        self.channels[prod].chain_out.pop_front().expect("peeked");
                    task.advance_chain();
                    self.channels[target].chain_in = Some(task);
                    group.rr = (group.rr + k + 1) % m;
                    break; // one CC hand-off per group per cycle
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // HWA-clock side
    // ------------------------------------------------------------------

    /// Step one channel on its own clock edge.
    pub fn step_channel(&mut self, idx: usize, now: Ps, arena: &mut PacketArena) {
        self.channels[idx].step_hwa(now, self.compute.as_mut(), arena);
    }

    /// Return every newly-retired task's pooled word buffer to the arena
    /// (called once per system step; see
    /// [`Channel::recycle_completed_words`]).
    pub fn recycle_completed_words(&mut self, arena: &mut PacketArena) {
        for ch in self.channels.iter_mut() {
            ch.recycle_completed_words(arena);
        }
    }

    /// Distinct HWA clock periods (for MultiClock registration):
    /// (period_ps, channel indices).
    pub fn hwa_domains(&self) -> Vec<(u64, Vec<usize>)> {
        let mut domains: Vec<(u64, Vec<usize>)> = Vec::new();
        for (i, ch) in self.channels.iter().enumerate() {
            let p = ch.hwa_clock.period_ps;
            match domains.iter_mut().find(|(q, _)| *q == p) {
                Some((_, v)) => v.push(i),
                None => domains.push((p, vec![i])),
            }
        }
        domains
    }

    /// Everything drained: no task anywhere in the fabric (an in-flight
    /// slot swap counts as work — the fabric is not quiescent until the
    /// new core lands).
    pub fn quiescent(&self, now: Ps) -> bool {
        self.router_out.is_empty()
            && self.router_in.is_empty()
            && self.prs.iter().all(|p| p.idle())
            && self.ps.idle()
            && self.channels.iter().all(|c| c.quiescent())
            && self.reconfigs.is_empty()
            && now > 0
    }

    /// Total tasks executed across channels.
    pub fn tasks_executed(&self) -> u64 {
        self.channels.iter().map(|c| c.stats.tasks_executed).sum()
    }

    pub fn ps_stats(&self) -> super::iface::ps::PsStats {
        self.ps.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::MultiClock;
    use crate::flit::{Direction, HeadFields, Packet, PacketBuilder, PacketType};
    use crate::fpga::channel::task::CommandKind;
    use crate::fpga::hwa::{spec_by_name, table3};

    /// A self-contained harness driving the fabric's clocks directly
    /// (no NoC): feeds flits into router_out, drains router_in.
    struct Rig {
        fpga: Fpga,
        arena: PacketArena,
        mc: MultiClock,
        iface_dom: crate::clock::DomainId,
        noc_dom: crate::clock::DomainId,
        hwa_doms: Vec<(crate::clock::DomainId, Vec<usize>)>,
        out: Vec<Flit>,
        builder: PacketBuilder,
    }

    impl Rig {
        fn new(specs: Vec<HwaSpec>) -> Self {
            let mut mc = MultiClock::new();
            let noc_clock = ClockDomain::from_mhz("noc", 1000.0);
            let noc_dom = mc.add(noc_clock.clone());
            let cfg = FpgaConfig::paper_defaults(5, 7, vec![0; 8]);
            let fpga = Fpga::new(cfg, specs, &noc_clock);
            let iface_dom = mc.add(fpga.iface_clock.clone());
            let hwa_doms = fpga
                .hwa_domains()
                .into_iter()
                .enumerate()
                .map(|(i, (p, chans))| {
                    let d = mc.add(ClockDomain {
                        name: format!("hwa{i}"),
                        period_ps: p,
                        phase_ps: 0,
                    });
                    (d, chans)
                })
                .collect();
            Self {
                fpga,
                arena: PacketArena::new(),
                mc,
                iface_dom,
                noc_dom,
                hwa_doms,
                out: Vec::new(),
                builder: PacketBuilder::new(1),
            }
        }

        fn inject(&mut self, p: &Packet) {
            for f in &p.flits {
                let now = self.mc.now();
                assert!(self.fpga.router_out.push(now, *f));
            }
        }

        fn run(&mut self, until_ps: Ps) {
            let mut ticking = Vec::new();
            while self.mc.now() < until_ps {
                let t = self.mc.advance(&mut ticking);
                for d in ticking.clone() {
                    if d == self.iface_dom {
                        self.fpga.step_iface(t, &mut self.arena);
                    } else if d == self.noc_dom {
                        if let Some(f) = self.fpga.pop_to_noc(t) {
                            self.out.push(f);
                        }
                    } else if let Some((_, chans)) =
                        self.hwa_doms.iter().find(|(dd, _)| *dd == d)
                    {
                        for i in chans.clone() {
                            self.fpga.step_channel(i, t, &mut self.arena);
                        }
                    }
                }
            }
        }

        fn request(&mut self, hwa_id: u8, src: u8, chain: Option<(u8, [u8; 3])>) {
            let (depth, index) = chain.unwrap_or((0, [0; 3]));
            let p = self.builder.command(HeadFields {
                routing: 5,
                hwa_id,
                src_id: src,
                direction: Direction::ProcToHwa,
                chain_depth: depth,
                chain_index: index,
                payload: CommandKind::Request.encode(),
                ..HeadFields::default()
            });
            self.inject(&p);
        }

        fn payload_for_grant(&mut self, grant: &HeadFields, words: &[u32]) {
            let p = self.builder.payload(
                HeadFields {
                    routing: 5,
                    hwa_id: grant.hwa_id,
                    src_id: grant.src_id,
                    tb_id: grant.tb_id,
                    task_head: true,
                    task_tail: true,
                    chain_depth: grant.chain_depth,
                    chain_index: grant.chain_index,
                    direction: Direction::ProcToHwa,
                    ..HeadFields::default()
                },
                words,
            );
            self.inject(&p);
        }

        fn take_grants(&mut self) -> Vec<HeadFields> {
            let mut grants = Vec::new();
            self.out.retain(|f| {
                if f.is_head() {
                    let h = f.head_fields();
                    if h.pkt_type == PacketType::Command
                        && CommandKind::decode(h.payload) == CommandKind::Grant
                    {
                        grants.push(h);
                        return false;
                    }
                }
                true
            });
            grants
        }
    }

    #[test]
    fn request_grant_payload_result_roundtrip() {
        let mut rig = Rig::new(vec![spec_by_name("dfadd").unwrap()]);
        rig.request(0, 1, None);
        rig.run(1_000_000); // 1 µs
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1, "grant issued");
        assert_eq!(grants[0].hwa_id, 0);
        rig.payload_for_grant(&grants[0], &[1, 2, 3, 4]);
        rig.run(3_000_000);
        // Result packet: head + 1 data flit (dfadd out_words=2).
        let heads: Vec<HeadFields> = rig
            .out
            .iter()
            .filter(|f| f.is_head())
            .map(|f| f.head_fields())
            .collect();
        assert_eq!(heads.len(), 1, "one result packet: {:?}", rig.out.len());
        assert_eq!(heads[0].direction, Direction::HwaToProc);
        assert_eq!(rig.fpga.tasks_executed(), 1);
        assert!(rig.fpga.quiescent(rig.mc.now()));
    }

    #[test]
    fn grants_deferred_until_tb_free() {
        // 3 requests, 2 TBs: third grant must wait for a completion.
        let mut rig = Rig::new(vec![spec_by_name("dfdiv").unwrap()]);
        for src in 0..3 {
            rig.request(0, src, None);
        }
        rig.run(1_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 2, "only as many grants as TBs");
        // Feed both granted payloads; after one completes, grant 3 arrives.
        for g in &grants {
            rig.payload_for_grant(&g.clone(), &[1, 2, 3, 4]);
        }
        rig.run(rig.mc.now() + 4_000_000);
        let more = rig.take_grants();
        assert_eq!(more.len(), 1, "third grant after TB freed");
    }

    #[test]
    fn chaining_two_hwas_single_result() {
        // izigzag (idx 0) chains into iquantize (idx 1): one request,
        // one payload, ONE result packet, no intermediate NoC traffic.
        let specs = vec![
            spec_by_name("izigzag").unwrap(),
            spec_by_name("iquantize").unwrap(),
        ];
        let mut rig = Rig::new(specs);
        rig.fpga.add_chain_group(vec![0, 1]);
        rig.request(0, 1, Some((1, [1, 0, 0])));
        rig.run(1_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1);
        let words: Vec<u32> = (0..64).collect();
        rig.payload_for_grant(&grants[0], &words);
        rig.run(rig.mc.now() + 8_000_000);
        let result_heads: Vec<HeadFields> = rig
            .out
            .iter()
            .filter(|f| f.is_head() && f.head_fields().pkt_type == PacketType::Payload)
            .map(|f| f.head_fields())
            .collect();
        assert_eq!(result_heads.len(), 1, "single chained result");
        assert_eq!(result_heads[0].hwa_id, 1, "result from the LAST hwa");
        assert_eq!(rig.fpga.channels[0].stats.chain_forwards, 1);
        assert_eq!(rig.fpga.channels[1].stats.chain_receives, 1);
        assert_eq!(rig.fpga.tasks_executed(), 2);
        assert!(rig.fpga.quiescent(rig.mc.now()));
    }

    #[test]
    fn full_depth3_jpeg_chain() {
        // izigzag -> iquantize -> idct -> shiftbound (§6.6's pipeline).
        let specs = vec![
            spec_by_name("izigzag").unwrap(),
            spec_by_name("iquantize").unwrap(),
            spec_by_name("idct").unwrap(),
            spec_by_name("shiftbound").unwrap(),
        ];
        let mut rig = Rig::new(specs);
        rig.fpga.add_chain_group(vec![0, 1, 2, 3]);
        rig.request(0, 2, Some((3, [1, 2, 3])));
        rig.run(1_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1);
        let words: Vec<u32> = (0..64).collect();
        rig.payload_for_grant(&grants[0], &words);
        rig.run(rig.mc.now() + 20_000_000);
        assert_eq!(rig.fpga.tasks_executed(), 4, "all four stages ran");
        let result_heads: Vec<HeadFields> = rig
            .out
            .iter()
            .filter(|f| f.is_head() && f.head_fields().pkt_type == PacketType::Payload)
            .map(|f| f.head_fields())
            .collect();
        assert_eq!(result_heads.len(), 1);
        assert_eq!(result_heads[0].hwa_id, 3, "shiftbound emits the result");
        assert!(rig.fpga.quiescent(rig.mc.now()));
    }

    #[test]
    fn chain_hop_to_out_of_range_member_is_dropped_and_counted() {
        // A forged header chains izigzag (group member 0) to member 3 of
        // a 2-member group: no such accelerator exists. The chaining
        // controller must drop the task, count the rejection against the
        // producing channel, and keep the fabric live for well-formed
        // traffic. (The accel::Chain builder rejects this at
        // construction; only raw wire traffic can carry it.)
        let specs = vec![
            spec_by_name("izigzag").unwrap(),
            spec_by_name("iquantize").unwrap(),
        ];
        let mut rig = Rig::new(specs);
        rig.fpga.add_chain_group(vec![0, 1]);
        rig.request(0, 1, Some((1, [3, 0, 0])));
        rig.run(1_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1);
        let words: Vec<u32> = (0..64).collect();
        rig.payload_for_grant(&grants[0], &words);
        rig.run(rig.mc.now() + 8_000_000);
        assert_eq!(
            rig.fpga.tasks_executed(),
            1,
            "first hop ran, forged hand-off did not"
        );
        assert_eq!(
            rig.fpga.channels[0].stats.rejected_flits,
            1,
            "dropped chain hand-off counted"
        );
        assert_eq!(rig.fpga.channels[1].stats.chain_receives, 0);
        assert!(rig.fpga.quiescent(rig.mc.now()), "fabric stays live");
        // A well-formed chained invocation still works afterwards.
        rig.request(0, 1, Some((1, [1, 0, 0])));
        rig.run(rig.mc.now() + 1_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1);
        let words: Vec<u32> = (0..64).collect();
        rig.payload_for_grant(&grants[0], &words);
        rig.run(rig.mc.now() + 8_000_000);
        assert_eq!(rig.fpga.tasks_executed(), 3, "both chain hops ran");
        assert_eq!(rig.fpga.channels[1].stats.chain_receives, 1);
    }

    #[test]
    fn malformed_tb_id_payload_is_dropped_not_a_panic() {
        // A payload packet forged against a TB id the channel never
        // granted (and beyond its TB array) must be rejected and counted,
        // with the fabric still live for well-formed traffic.
        let mut rig = Rig::new(vec![spec_by_name("dfadd").unwrap()]);
        rig.request(0, 1, None);
        rig.run(1_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1);
        let mut forged = grants[0];
        forged.tb_id = 3; // 2 TBs configured: index 3 is out of range
        rig.payload_for_grant(&forged, &[1, 2, 3, 4]);
        rig.run(rig.mc.now() + 2_000_000);
        assert_eq!(rig.fpga.tasks_executed(), 0, "forged task dropped");
        assert!(
            rig.fpga.channels[0].stats.rejected_flits > 0,
            "rejection counted"
        );
        // The grant's real TB still works.
        rig.payload_for_grant(&grants[0], &[1, 2, 3, 4]);
        rig.run(rig.mc.now() + 3_000_000);
        assert_eq!(rig.fpga.tasks_executed(), 1, "fabric still live");
    }

    #[test]
    fn reconfig_drains_in_flight_tasks_then_swaps() {
        let mut rig = Rig::new(vec![spec_by_name("izigzag").unwrap()]);
        rig.request(0, 1, None);
        rig.run(1_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1);
        // Begin the swap while the granted task is still in flight: the
        // slot must drain (task completes, result emitted) before the
        // bitstream programs.
        let target = spec_by_name("iquantize").unwrap();
        rig.fpga.begin_reconfig(0, target, 5_000_000).unwrap();
        assert!(rig.fpga.reconfiguring(0));
        assert!(
            rig.fpga.begin_reconfig(0, spec_by_name("idct").unwrap(), 1).is_err(),
            "double swap on one slot rejected"
        );
        let words: Vec<u32> = (0..64).collect();
        rig.payload_for_grant(&grants[0], &words);
        rig.run(rig.mc.now() + 20_000_000);
        assert_eq!(rig.fpga.tasks_executed(), 1, "in-flight task completed");
        assert_eq!(rig.fpga.stats.reconfig_swaps, 1);
        assert!(!rig.fpga.reconfiguring(0));
        assert_eq!(rig.fpga.channels[0].spec.name, "iquantize");
        assert!(rig.fpga.stats.reconfig_drain_cycles > 0);
        assert!(rig.fpga.stats.reconfig_blocked_cycles > 0);
        let swaps = rig.fpga.take_completed_swaps();
        assert_eq!(swaps.len(), 1);
        assert_eq!(swaps[0].0, 0);
        assert_eq!(swaps[0].1.name, "iquantize");
        // The reprogrammed slot serves new requests.
        rig.request(0, 2, None);
        rig.run(rig.mc.now() + 2_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1, "post-swap grant");
        rig.payload_for_grant(&grants[0], &words);
        rig.run(rig.mc.now() + 8_000_000);
        assert_eq!(rig.fpga.tasks_executed(), 2);
        assert!(rig.fpga.quiescent(rig.mc.now()));
    }

    #[test]
    fn requests_queued_during_reconfig_are_granted_after_swap() {
        let mut rig = Rig::new(vec![spec_by_name("dfadd").unwrap()]);
        rig.fpga
            .begin_reconfig(0, spec_by_name("dfmul").unwrap(), 3_000_000)
            .unwrap();
        // A request arriving mid-swap queues in the slot's RB; the fence
        // blocks the grant until the new core lands.
        rig.request(0, 1, None);
        rig.run(2_000_000);
        assert!(rig.take_grants().is_empty(), "fence blocks grants");
        rig.run(rig.mc.now() + 8_000_000);
        assert_eq!(rig.fpga.stats.reconfig_swaps, 1);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 1, "queued request granted after the swap");
        assert_eq!(rig.fpga.channels[0].spec.name, "dfmul");
    }

    #[test]
    fn eight_hwas_parallel_requests() {
        let specs: Vec<HwaSpec> = table3().into_iter().take(8).collect();
        let mut rig = Rig::new(specs.clone());
        for (i, _) in specs.iter().enumerate() {
            rig.request(i as u8, (i % 8) as u8, None);
        }
        rig.run(1_000_000);
        let grants = rig.take_grants();
        assert_eq!(grants.len(), 8, "each channel granted independently");
        for g in grants {
            let spec = &specs[g.hwa_id as usize];
            let words: Vec<u32> = (0..spec.in_words as u32).collect();
            rig.payload_for_grant(&g, &words);
        }
        rig.run(rig.mc.now() + 30_000_000);
        assert_eq!(rig.fpga.tasks_executed(), 8);
        assert!(rig.fpga.quiescent(rig.mc.now()));
    }
}
