//! Interface block (paper §4.1): packet receivers and packet senders.

pub mod pr;
pub mod ps;
pub mod source;

pub use pr::{PacketReceiver, PrStrategy};
pub use ps::{PacketSender, PsStrategy};
pub use source::FlitSource;
