//! Flit source abstraction: what a packet receiver reads from.
//!
//! Implemented by the router-output [`AsyncFifo`] (the real datapath) and
//! by plain `VecDeque`s in unit tests.

use std::collections::VecDeque;

use crate::clock::{AsyncFifo, Ps};
use crate::flit::Flit;

pub trait FlitSource {
    fn peek_at(&self, now: Ps) -> Option<Flit>;
    fn pop_at(&mut self, now: Ps) -> Option<Flit>;
}

impl FlitSource for AsyncFifo<Flit> {
    fn peek_at(&self, now: Ps) -> Option<Flit> {
        self.peek(now).copied()
    }

    fn pop_at(&mut self, now: Ps) -> Option<Flit> {
        self.pop(now)
    }
}

impl FlitSource for VecDeque<Flit> {
    fn peek_at(&self, _now: Ps) -> Option<Flit> {
        self.front().copied()
    }

    fn pop_at(&mut self, _now: Ps) -> Option<Flit> {
        self.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ClockDomain;

    #[test]
    fn vecdeque_source() {
        let mut q: VecDeque<Flit> = VecDeque::new();
        q.push_back(Flit::default());
        assert!(q.peek_at(0).is_some());
        assert!(q.pop_at(0).is_some());
        assert!(q.pop_at(0).is_none());
    }

    #[test]
    fn async_fifo_source_respects_visibility() {
        let rd = ClockDomain::from_mhz("rd", 100.0);
        let mut f: AsyncFifo<Flit> = AsyncFifo::new(4, &rd);
        f.push(0, Flit::default());
        assert!(f.peek_at(10_000).is_none(), "one edge: not visible yet");
        assert!(f.peek_at(20_000).is_some(), "two edges: visible");
    }
}
