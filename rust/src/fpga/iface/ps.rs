//! Packet sender (PS), §4.1 A.2: arbitrates among HWA channels and
//! streams the selected packets into the router input buffer.
//!
//! * Command packets (grants, notifies) are single-flit and strictly
//!   higher priority than result packets; round-robin among channels.
//! * Result packets use priority-based round-robin (priority bits from
//!   the head flit; all-zero priorities degrade to plain round-robin).
//! * Strategy (global vs. hierarchical, Fig. 3b) groups channels for
//!   two-level arbitration; in cycle terms both meet Table 2 (command 1
//!   cycle, payload 4 + N: 3 arbitration/handshake cycles, then the head
//!   and the N data flits at one per cycle). The strategy's fmax impact is
//!   modelled by `synth::delay` (Fig. 7).

use crate::flit::{Flit, PacketArena, PacketBuilder, PacketHandle};

use super::super::channel::Channel;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PsStrategy {
    /// Channels per first-level arbitration group (== n_channels for the
    /// global strategy).
    pub group_size: usize,
}

impl PsStrategy {
    pub fn hierarchical(group_size: usize) -> Self {
        assert!(group_size > 0);
        Self { group_size }
    }

    pub fn global(n_channels: usize) -> Self {
        Self {
            group_size: n_channels.max(1),
        }
    }

    pub fn n_groups(&self, n_channels: usize) -> usize {
        n_channels.div_ceil(self.group_size)
    }
}

/// Arbitration/handshake cycles before a result packet's head flit leaves.
const RESULT_ARB_CYCLES: u32 = 3;

#[derive(Debug, Clone, Copy, Default)]
pub struct PsStats {
    pub command_flits: u64,
    pub result_packets: u64,
    pub result_flits: u64,
    pub stall_cycles: u64,
    pub busy_cycles: u64,
}

#[derive(Debug)]
enum PsState {
    Idle,
    Arbitrating { channel: usize, cycles_left: u32 },
    /// Streaming an arena-backed result packet: the PS owns the handle
    /// from `pop_result` until the tail flit is accepted, then frees it.
    Streaming { handle: PacketHandle, len: usize, next: usize },
}

#[derive(Debug)]
pub struct PacketSender {
    strategy: PsStrategy,
    state: PsState,
    cmd_rr: usize,
    group_rr: usize,
    within_rr: Vec<usize>,
    builder: PacketBuilder,
    pub stats: PsStats,
}

impl PacketSender {
    pub fn new(strategy: PsStrategy, n_channels: usize) -> Self {
        Self {
            strategy,
            state: PsState::Idle,
            cmd_rr: 0,
            group_rr: 0,
            within_rr: vec![0; strategy.n_groups(n_channels)],
            builder: PacketBuilder::new(0x4000_0000),
            stats: PsStats::default(),
        }
    }

    /// One interface cycle. `out_push` pushes a flit toward the router
    /// input buffer, returning false when it is full. Result-packet flit
    /// storage lives in `arena`; the PS frees each packet's handle once
    /// its tail flit has been accepted.
    pub fn step(
        &mut self,
        channels: &mut [Channel],
        arena: &mut PacketArena,
        out_push: &mut dyn FnMut(Flit) -> bool,
    ) {
        match std::mem::replace(&mut self.state, PsState::Idle) {
            PsState::Idle => {
                // 1) Command packets first (RR over channels).
                let n = channels.len();
                for k in 0..n {
                    let idx = (self.cmd_rr + k) % n;
                    if let Some(head) = channels[idx].cmd_out.front() {
                        let flit = self.builder.command_flit(*head);
                        if out_push(flit) {
                            channels[idx].cmd_out.pop_front();
                            self.cmd_rr = (idx + 1) % n;
                            self.stats.command_flits += 1;
                            self.stats.busy_cycles += 1;
                        } else {
                            self.stats.stall_cycles += 1;
                        }
                        return;
                    }
                }
                // 2) Result packets: two-level priority round-robin.
                if let Some(winner) = self.arbitrate_result(channels) {
                    self.state = PsState::Arbitrating {
                        channel: winner,
                        cycles_left: RESULT_ARB_CYCLES,
                    };
                    self.stats.busy_cycles += 1;
                }
            }
            PsState::Arbitrating {
                channel,
                cycles_left,
            } => {
                self.stats.busy_cycles += 1;
                if cycles_left > 1 {
                    self.state = PsState::Arbitrating {
                        channel,
                        cycles_left: cycles_left - 1,
                    };
                } else {
                    match channels[channel].pop_result() {
                        Some(entry) => {
                            self.stats.result_packets += 1;
                            self.state = PsState::Streaming {
                                handle: entry.handle,
                                len: entry.len,
                                next: 0,
                            };
                            // Handshake's final cycle coincides with head
                            // issue.
                            self.emit(arena, out_push);
                        }
                        None => { /* drained by reset: drop */ }
                    }
                }
            }
            PsState::Streaming { handle, len, next } => {
                self.stats.busy_cycles += 1;
                self.state = PsState::Streaming { handle, len, next };
                self.emit(arena, out_push);
            }
        }
    }

    fn emit(
        &mut self,
        arena: &mut PacketArena,
        out_push: &mut dyn FnMut(Flit) -> bool,
    ) {
        if let PsState::Streaming { handle, len, next } =
            std::mem::replace(&mut self.state, PsState::Idle)
        {
            if next < len {
                if out_push(arena.flits(handle)[next]) {
                    self.stats.result_flits += 1;
                    if next + 1 < len {
                        self.state = PsState::Streaming {
                            handle,
                            len,
                            next: next + 1,
                        };
                    } else {
                        // Tail accepted: storage returns to the pool.
                        arena.free_packet(handle);
                    }
                } else {
                    self.stats.stall_cycles += 1;
                    self.state = PsState::Streaming { handle, len, next };
                }
            } else {
                arena.free_packet(handle);
            }
        }
    }

    /// Two-level arbitration: per-group priority-RR, then RR over groups.
    fn arbitrate_result(&mut self, channels: &[Channel]) -> Option<usize> {
        let n = channels.len();
        let g = self.strategy.group_size;
        let n_groups = self.strategy.n_groups(n);
        for gk in 0..n_groups {
            let group = (self.group_rr + gk) % n_groups;
            let lo = group * g;
            let hi = (lo + g).min(n);
            let best_prio = (lo..hi)
                .filter_map(|i| channels[i].pob_priority())
                .max();
            let Some(best_prio) = best_prio else {
                continue;
            };
            let span = hi - lo;
            for k in 0..span {
                let idx = lo + (self.within_rr[group] + k) % span;
                if channels[idx].pob_priority() == Some(best_prio) {
                    self.within_rr[group] = (idx - lo + 1) % span;
                    self.group_rr = (group + 1) % n_groups;
                    return Some(idx);
                }
            }
        }
        None
    }

    pub fn idle(&self) -> bool {
        matches!(self.state, PsState::Idle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{FlitKind, HeadFields, PacketType};
    use crate::fpga::hwa::spec_by_name;

    fn mk_channel(hwa_id: u8) -> Channel {
        Channel::new(hwa_id, spec_by_name("dfadd").unwrap(), 2, vec![0; 8], vec![7; 8])
    }

    fn result_packet(ch: &mut Channel, arena: &mut PacketArena, priority: u8, words: usize) {
        let mut b = crate::flit::PacketBuilder::new(100 + ch.hwa_id as u32);
        let p = b.payload(
            HeadFields {
                routing: 0,
                priority,
                pkt_type: PacketType::Payload,
                ..HeadFields::default()
            },
            &vec![1u32; words],
        );
        assert!(ch.push_result_packet(arena, &p));
    }

    fn run(
        ps: &mut PacketSender,
        channels: &mut [Channel],
        arena: &mut PacketArena,
        cycles: usize,
    ) -> Vec<Flit> {
        let mut out = Vec::new();
        for _ in 0..cycles {
            let mut push = |f: Flit| {
                out.push(f);
                true
            };
            ps.step(channels, arena, &mut push);
        }
        out
    }

    #[test]
    fn command_beats_result() {
        let mut arena = PacketArena::new();
        let mut chans = vec![mk_channel(0), mk_channel(1)];
        result_packet(&mut chans[0], &mut arena, 0, 4);
        chans[1].cmd_out.push_back(HeadFields {
            pkt_type: PacketType::Command,
            ..HeadFields::default()
        });
        let mut ps = PacketSender::new(PsStrategy::hierarchical(2), 2);
        let out = run(&mut ps, &mut chans, &mut arena, 1);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].kind(), FlitKind::Single, "command went first");
    }

    #[test]
    fn result_packet_takes_4_plus_n_cycles() {
        let mut arena = PacketArena::new();
        let mut chans = vec![mk_channel(0)];
        result_packet(&mut chans[0], &mut arena, 0, 4); // head + 1 data flit => N=1
        let mut ps = PacketSender::new(PsStrategy::global(1), 1);
        let mut emitted_at = Vec::new();
        for cycle in 1..=20 {
            let mut push = |_f: Flit| {
                emitted_at.push(cycle);
                true
            };
            ps.step(&mut chans, &mut arena, &mut push);
        }
        // Head on cycle 4 (3 arb + issue), tail on cycle 5 => 4+N total.
        assert_eq!(emitted_at, vec![4, 5]);
        // The streamed packet's storage went back to the pool.
        assert_eq!(arena.live(), (0, 0));
    }

    #[test]
    fn priority_wins_within_group() {
        let mut arena = PacketArena::new();
        let mut chans = vec![mk_channel(0), mk_channel(1)];
        result_packet(&mut chans[0], &mut arena, 0, 4);
        result_packet(&mut chans[1], &mut arena, 3, 4);
        let mut ps = PacketSender::new(PsStrategy::global(2), 2);
        let out = run(&mut ps, &mut chans, &mut arena, 6);
        assert!(!out.is_empty());
        assert_eq!(out[0].head_fields().priority, 3, "high priority first");
    }

    #[test]
    fn round_robin_when_priorities_equal() {
        let mut arena = PacketArena::new();
        let mut chans = vec![mk_channel(0), mk_channel(1)];
        for _ in 0..2 {
            result_packet(&mut chans[0], &mut arena, 1, 4);
            result_packet(&mut chans[1], &mut arena, 1, 4);
        }
        let mut ps = PacketSender::new(PsStrategy::global(2), 2);
        let out = run(&mut ps, &mut chans, &mut arena, 40);
        let heads: Vec<u32> = out
            .iter()
            .filter(|f| f.is_head())
            .map(|f| f.meta.flow)
            .collect();
        assert_eq!(heads.len(), 4);
        assert_ne!(heads[0], heads[1], "alternates between channels");
    }

    #[test]
    fn streaming_not_preempted_by_command() {
        let mut arena = PacketArena::new();
        let mut chans = vec![mk_channel(0), mk_channel(1)];
        result_packet(&mut chans[0], &mut arena, 0, 16); // head + 4 data flits
        let mut ps = PacketSender::new(PsStrategy::global(2), 2);
        run(&mut ps, &mut chans, &mut arena, 4); // arb + head out
        chans[1].cmd_out.push_back(HeadFields {
            pkt_type: PacketType::Command,
            ..HeadFields::default()
        });
        let out = run(&mut ps, &mut chans, &mut arena, 10);
        let kinds: Vec<FlitKind> = out.iter().map(|f| f.kind()).collect();
        let cmd_pos = kinds.iter().position(|k| *k == FlitKind::Single).unwrap();
        let last_data = kinds
            .iter()
            .rposition(|k| matches!(k, FlitKind::Body | FlitKind::Tail))
            .unwrap();
        assert!(cmd_pos > last_data, "packet finished before command");
    }

    #[test]
    fn backpressure_stalls_without_loss() {
        let mut arena = PacketArena::new();
        let mut chans = vec![mk_channel(0)];
        result_packet(&mut chans[0], &mut arena, 0, 8);
        let mut ps = PacketSender::new(PsStrategy::global(1), 1);
        let mut accepted = Vec::new();
        for cycle in 1..=30 {
            let mut push = |f: Flit| {
                if cycle < 6 {
                    false
                } else {
                    accepted.push(f);
                    true
                }
            };
            ps.step(&mut chans, &mut arena, &mut push);
        }
        // head + 2 data flits all delivered despite early rejects.
        assert_eq!(accepted.len(), 3);
        assert!(ps.stats.stall_cycles > 0);
    }

    #[test]
    fn hierarchical_groups_served_round_robin() {
        let mut arena = PacketArena::new();
        let mut chans: Vec<Channel> = (0..4).map(mk_channel).collect();
        for ch in chans.iter_mut() {
            result_packet(ch, &mut arena, 0, 4);
        }
        let mut ps = PacketSender::new(PsStrategy::hierarchical(2), 4);
        let out = run(&mut ps, &mut chans, &mut arena, 40);
        let heads: Vec<u32> = out
            .iter()
            .filter(|f| f.is_head())
            .map(|f| f.meta.flow - 100)
            .collect();
        assert_eq!(heads.len(), 4);
        // Group alternation: channel from group 0 then group 1 then ...
        assert_eq!(heads[0] / 2, 0);
        assert_eq!(heads[1] / 2, 1);
        assert_eq!(heads[2] / 2, 0);
        assert_eq!(heads[3] / 2, 1);
    }
}
