//! Packet receiver (PR), §4.1 A.1: an FSM reading flits from the router
//! output buffer and dispatching them to HWA channels.
//!
//! Strategy (centralized vs. distributed, Fig. 3a) determines how many
//! channels each PR instance serves. In cycle terms every PR processes one
//! flit per interface cycle; the strategy's payoff is the achievable clock
//! frequency (fan-out-driven — reproduced by `synth::delay`, Fig. 7) while
//! the dispatch FSM below realizes Table 2's latencies: command packets
//! dispatch in 1 cycle, payload packets in 2 + N (head pop, decode/setup,
//! then one data flit per cycle).

use crate::clock::Ps;
use crate::flit::{FlitKind, HeadFields, PacketType};

use super::super::channel::task::CommandKind;
use super::super::channel::Channel;
use super::source::FlitSource;

/// PR strategy: number of HWA channels per PR instance
/// (`group_size == n_channels` models the centralized strategy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrStrategy {
    pub group_size: usize,
}

impl PrStrategy {
    pub fn distributed(group_size: usize) -> Self {
        assert!(group_size > 0);
        Self { group_size }
    }

    pub fn centralized(n_channels: usize) -> Self {
        Self {
            group_size: n_channels.max(1),
        }
    }

    pub fn n_prs(&self, n_channels: usize) -> usize {
        n_channels.div_ceil(self.group_size)
    }

    /// PR instance responsible for a channel index.
    pub fn pr_for(&self, channel_idx: usize) -> usize {
        channel_idx / self.group_size
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct PrStats {
    pub flits_in: u64,
    pub commands_dispatched: u64,
    pub payload_packets: u64,
    pub stall_cycles: u64,
}

#[derive(Debug)]
enum PrState {
    Idle,
    /// Head popped; decode/setup cycle before data flits stream.
    Decode { head: HeadFields, known: bool },
    /// Streaming data flits of the packet into the TB.
    Stream { head: HeadFields, known: bool },
}

/// One PR instance.
#[derive(Debug)]
pub struct PacketReceiver {
    state: PrState,
    pub stats: PrStats,
}

impl PacketReceiver {
    pub fn new() -> Self {
        Self {
            state: PrState::Idle,
            stats: PrStats::default(),
        }
    }

    /// One interface-clock cycle: consume at most one flit from `input`
    /// and dispatch into `channels`. `chan_index` maps an HWA id to a
    /// channel slot in `channels` (None = not ours / unknown).
    pub fn step(
        &mut self,
        now: Ps,
        input: &mut dyn FlitSource,
        channels: &mut [Channel],
        chan_index: &dyn Fn(u8) -> Option<usize>,
    ) {
        match std::mem::replace(&mut self.state, PrState::Idle) {
            PrState::Idle => {
                let Some(flit) = input.peek_at(now) else {
                    return;
                };
                debug_assert!(flit.is_head(), "stream must start with a head");
                let head = flit.head_fields();
                match head.pkt_type {
                    PacketType::Command => {
                        debug_assert_eq!(
                            CommandKind::decode(head.payload),
                            CommandKind::Request
                        );
                        let Some(idx) = chan_index(head.hwa_id) else {
                            input.pop_at(now); // unknown HWA: drop
                            return;
                        };
                        if channels[idx].push_request(head, now) {
                            input.pop_at(now);
                            self.stats.flits_in += 1;
                            self.stats.commands_dispatched += 1;
                        } else {
                            self.stats.stall_cycles += 1; // RB full: retry
                        }
                    }
                    PacketType::Payload => {
                        input.pop_at(now).expect("peeked");
                        self.stats.flits_in += 1;
                        let known = chan_index(head.hwa_id).is_some();
                        self.state = PrState::Decode { head, known };
                    }
                }
            }
            PrState::Decode { head, known } => {
                // Decode/setup cycle: claim the granted TB.
                let mut known = known;
                if known {
                    let idx = chan_index(head.hwa_id).expect("known");
                    // flow id comes from the head flit's builder; recover it
                    // lazily from the first data flit instead (meta is
                    // uniform across a packet) — here we pass 0 and patch
                    // on the first data flit.
                    if channels[idx].payload_head(head, 0) {
                        self.stats.payload_packets += 1;
                    } else {
                        // Malformed header (out-of-range or ungranted
                        // tb_id): the channel rejected and counted it;
                        // consume the rest of the packet and drop it.
                        known = false;
                    }
                }
                self.state = PrState::Stream { head, known };
            }
            PrState::Stream { head, known } => {
                let Some(flit) = input.pop_at(now) else {
                    self.stats.stall_cycles += 1;
                    self.state = PrState::Stream { head, known };
                    return;
                };
                self.stats.flits_in += 1;
                let is_tail = flit.kind() == FlitKind::Tail;
                if known {
                    let idx = chan_index(head.hwa_id).expect("known channel");
                    let [a, b] = flit.body_payload();
                    let lanes =
                        [a as u32, (a >> 32) as u32, b as u32, (b >> 32) as u32];
                    let ready_at = channels[idx].cdc_ready_at(now);
                    let _ = channels[idx].payload_data(head.tb_id, &lanes, is_tail, ready_at);
                }
                if is_tail {
                    self.state = PrState::Idle;
                } else {
                    self.state = PrState::Stream { head, known };
                }
            }
        }
    }

    pub fn idle(&self) -> bool {
        matches!(self.state, PrState::Idle)
    }
}

impl Default for PacketReceiver {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{Direction, Flit, PacketBuilder};
    use crate::fpga::hwa::spec_by_name;
    use std::collections::VecDeque;

    fn mk_channels() -> Vec<Channel> {
        vec![Channel::new(
            0,
            spec_by_name("dfadd").unwrap(),
            2,
            vec![0; 8],
            vec![7; 8],
        )]
    }

    fn drive(pr: &mut PacketReceiver, chans: &mut [Channel], flits: Vec<Flit>) -> u64 {
        let mut queue: VecDeque<Flit> = flits.into_iter().collect();
        let mut cycles = 0;
        for _ in 0..1000 {
            cycles += 1;
            let now = cycles * 3333;
            pr.step(now, &mut queue, chans, &|id| {
                if id == 0 {
                    Some(0)
                } else {
                    None
                }
            });
            if queue.is_empty() && pr.idle() {
                break;
            }
        }
        cycles
    }

    #[test]
    fn command_dispatches_in_one_cycle() {
        let mut pr = PacketReceiver::new();
        let mut chans = mk_channels();
        let mut b = PacketBuilder::new(1);
        let req = b.command(HeadFields {
            hwa_id: 0,
            direction: Direction::ProcToHwa,
            ..HeadFields::default()
        });
        let cycles = drive(&mut pr, &mut chans, req.flits.clone());
        assert_eq!(cycles, 1);
        assert_eq!(chans[0].rb_len(), 1);
        assert_eq!(pr.stats.commands_dispatched, 1);
    }

    #[test]
    fn payload_takes_two_plus_n_cycles() {
        let mut pr = PacketReceiver::new();
        let mut chans = mk_channels();
        chans[0].push_request(
            HeadFields {
                hwa_id: 0,
                ..HeadFields::default()
            },
            0,
        );
        chans[0].step_lgc(0);
        chans[0].cmd_out.clear();
        let mut b = PacketBuilder::new(2);
        let p = b.payload(
            HeadFields {
                hwa_id: 0,
                tb_id: 0,
                task_head: true,
                task_tail: true,
                ..HeadFields::default()
            },
            &[1, 2, 3, 4], // 1 data flit
        );
        let n = p.len() - 1;
        let cycles = drive(&mut pr, &mut chans, p.flits.clone());
        assert_eq!(cycles as usize, 2 + n, "Table 2: payload = 2+N");
    }

    #[test]
    fn payload_words_reach_execution() {
        let mut pr = PacketReceiver::new();
        let mut chans = mk_channels();
        chans[0].push_request(HeadFields::default(), 0);
        chans[0].step_lgc(0);
        chans[0].cmd_out.clear();
        let mut b = PacketBuilder::new(3);
        let p = b.payload(
            HeadFields {
                hwa_id: 0,
                tb_id: 0,
                task_head: true,
                task_tail: true,
                ..HeadFields::default()
            },
            &[10, 20, 30, 40],
        );
        drive(&mut pr, &mut chans, p.flits.clone());
        use crate::flit::PacketArena;
        use crate::fpga::hwa::EchoCompute;
        let mut arena = PacketArena::new();
        let mut compute = EchoCompute;
        let mut now = 1_000_000;
        for _ in 0..200 {
            now += chans[0].hwa_clock.period_ps;
            chans[0].step_hwa(now, &mut compute, &mut arena);
            if !chans[0].pob.is_empty() {
                break;
            }
        }
        assert_eq!(chans[0].completed.len(), 1);
        // dfadd out_words
        assert_eq!(arena.words(chans[0].completed[0].words).len(), 2);
    }

    #[test]
    fn unknown_hwa_command_dropped() {
        let mut pr = PacketReceiver::new();
        let mut chans = mk_channels();
        let mut b = PacketBuilder::new(4);
        let req = b.command(HeadFields {
            hwa_id: 31,
            ..HeadFields::default()
        });
        drive(&mut pr, &mut chans, req.flits.clone());
        assert_eq!(chans[0].rb_len(), 0);
    }
}
