//! Memory subsystem: MMU + DMA (paper §5, Fig. 5b) and the DRAM model.

pub mod dram;
pub mod mmu;

pub use dram::{Dram, DRAM_LATENCY_CYCLES, DRAM_WORDS_PER_CYCLE};
pub use mmu::Mmu;
