//! MMU node (paper §5, Fig. 5b): decodes grant packets from HWAs, fetches
//! input data from memory via DMA and streams payload packets to the FPGA;
//! receives result packets and writes them back to memory.

use std::collections::VecDeque;

use crate::clock::{Activity, Ps};
use crate::flit::{
    Direction, Flit, FlitKind, HeadFields, PacketBuilder, PacketType,
};
use crate::fpga::channel::task::CommandKind;

use super::dram::Dram;

#[derive(Debug, Clone, Copy, Default)]
pub struct MmuStats {
    pub grants_decoded: u64,
    pub dma_reads: u64,
    pub results_written: u64,
}

/// A DMA job waiting on memory.
#[derive(Debug)]
struct DmaJob {
    grant: HeadFields,
    /// Interface tile the payload streams back to: the granting fabric
    /// (stamped into the grant's command payload by the system), falling
    /// back to the configured default for pre-floorplan traffic.
    reply_to: u8,
    ready_at: Ps,
}

pub struct Mmu {
    pub node: u8,
    fpga_node: u8,
    noc_period_ps: u64,
    pub dram: Dram,
    jobs: VecDeque<DmaJob>,
    /// Flits being streamed toward the FPGA (one per cycle).
    outbox: VecDeque<Flit>,
    /// Result packet in reception.
    rx_head: Option<HeadFields>,
    rx_words: Vec<u32>,
    /// Reusable DMA read buffer: cleared and refilled per job so the
    /// steady-state fetch path performs no heap allocation.
    dma_scratch: Vec<u32>,
    builder: PacketBuilder,
    pub stats: MmuStats,
}

impl Mmu {
    pub fn new(node: u8, fpga_node: u8, noc_period_ps: u64) -> Self {
        Self {
            node,
            fpga_node,
            noc_period_ps,
            dram: Dram::new(),
            jobs: VecDeque::new(),
            outbox: VecDeque::new(),
            rx_head: None,
            rx_words: Vec::new(),
            dma_scratch: Vec::new(),
            builder: PacketBuilder::new(0x2000_0000),
            stats: MmuStats::default(),
        }
    }

    /// Deliver a flit ejected at the MMU node.
    pub fn deliver(&mut self, flit: Flit, now: Ps) {
        if flit.is_head() {
            let h = flit.head_fields();
            match h.pkt_type {
                PacketType::Command => {
                    // Grants start a DMA fetch; a NACK (the channel's
                    // CRC check rejected our payload) echoes the same
                    // reservation context and means "send it again", so
                    // it re-runs the identical DMA job. Anything else
                    // is a misroute: ignore it rather than fetch.
                    match CommandKind::decode(h.payload) {
                        CommandKind::Grant | CommandKind::Nack => {}
                        _ => return,
                    }
                    self.stats.grants_decoded += 1;
                    let reply_to = crate::flit::command_payload_origin(
                        h.payload,
                    )
                    .unwrap_or(self.fpga_node);
                    let n_words = (h.data_size as usize) / 4;
                    let ready_at =
                        self.dram
                            .access_done_at(now, n_words, self.noc_period_ps);
                    self.stats.dma_reads += 1;
                    self.jobs.push_back(DmaJob {
                        grant: h,
                        reply_to,
                        ready_at,
                    });
                }
                PacketType::Payload => {
                    // Result packet (HwaToMem): start accumulating.
                    self.rx_head = Some(h);
                    self.rx_words.clear();
                }
            }
            return;
        }
        // Data flit of a result packet.
        let [a, b] = flit.body_payload();
        self.rx_words.extend_from_slice(&[
            a as u32,
            (a >> 32) as u32,
            b as u32,
            (b >> 32) as u32,
        ]);
        if flit.kind() == FlitKind::Tail {
            if let Some(h) = self.rx_head.take() {
                self.dram.write_words(h.start_addr, &self.rx_words);
                self.stats.results_written += 1;
            }
            self.rx_words.clear();
        }
    }

    /// One NoC cycle: pop at most one flit to inject toward the FPGA.
    pub fn step(&mut self, now: Ps, can_inject: bool) -> Option<Flit> {
        // Promote completed DMA jobs into payload packets.
        while let Some(job) = self.jobs.front() {
            if job.ready_at > now {
                break;
            }
            let job = self.jobs.pop_front().unwrap();
            let n_words = (job.grant.data_size as usize) / 4;
            self.dram.read_words_into(
                job.grant.start_addr,
                n_words,
                &mut self.dma_scratch,
            );
            let outbox = &mut self.outbox;
            self.builder.payload_with(
                HeadFields {
                    routing: job.reply_to,
                    hwa_id: job.grant.hwa_id,
                    src_id: job.grant.src_id,
                    tb_id: job.grant.tb_id,
                    task_head: true,
                    task_tail: true,
                    chain_depth: job.grant.chain_depth,
                    chain_index: job.grant.chain_index,
                    priority: job.grant.priority,
                    direction: Direction::MemToHwa,
                    start_addr: job.grant.start_addr,
                    ..HeadFields::default()
                },
                &self.dma_scratch,
                |f| outbox.push_back(f),
            );
        }
        if can_inject {
            self.outbox.pop_front()
        } else {
            None
        }
    }

    pub fn idle(&self) -> bool {
        self.jobs.is_empty() && self.outbox.is_empty() && self.rx_head.is_none()
    }

    /// Scheduler activity probe (the [`Activity`] contract): mid-stream
    /// work needs every NoC edge; queued DMA jobs bound the next event by
    /// the earliest memory completion.
    pub fn activity(&self) -> Activity {
        if !self.outbox.is_empty() || self.rx_head.is_some() {
            return Activity::Busy;
        }
        match self.jobs.iter().map(|j| j.ready_at).min() {
            None => Activity::Idle,
            Some(t) => Activity::NextEventAt(t),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grant(addr: u32, bytes: u16) -> Flit {
        let mut b = PacketBuilder::new(1);
        b.command(HeadFields {
            hwa_id: 2,
            src_id: 1,
            tb_id: 1,
            start_addr: addr,
            data_size: bytes,
            direction: Direction::MemToHwa,
            payload: CommandKind::Grant.encode(),
            ..HeadFields::default()
        })
        .flits[0]
    }

    #[test]
    fn grant_triggers_dma_payload() {
        let mut mmu = Mmu::new(7, 5, 1000);
        mmu.dram.write_words(0x100, &[5, 6, 7, 8]);
        mmu.deliver(grant(0x100, 16), 0);
        // Before DRAM latency: nothing.
        assert!(mmu.step(1000, true).is_none());
        // After: payload streams out, head first.
        let done = mmu.dram.access_done_at(0, 4, 1000);
        let head = mmu.step(done, true).expect("head flit");
        let h = head.head_fields();
        assert_eq!(h.routing, 5);
        assert_eq!(h.tb_id, 1);
        assert_eq!(h.direction, Direction::MemToHwa);
        let data = mmu.step(done + 1000, true).expect("data flit");
        assert_eq!(data.kind(), FlitKind::Tail);
        let [a, b] = data.body_payload();
        assert_eq!(a as u32, 5);
        assert_eq!((b >> 32) as u32, 8);
        assert!(mmu.idle());
    }

    #[test]
    fn grant_with_stamped_origin_routes_payload_to_that_fabric() {
        // Floorplanned systems stamp the granting interface tile into
        // the grant; the DMA payload must stream back to it, not to the
        // configured default fabric.
        let mut mmu = Mmu::new(7, 5, 1000);
        mmu.dram.write_words(0x40, &[9, 9, 9, 9]);
        let mut flit = grant(0x40, 16);
        flit.stamp_origin(11);
        mmu.deliver(flit, 0);
        let done = mmu.dram.access_done_at(0, 4, 1000);
        let head = mmu.step(done, true).expect("head flit");
        assert_eq!(head.head_fields().routing, 11, "origin wins");
    }

    #[test]
    fn result_written_to_memory() {
        let mut mmu = Mmu::new(7, 5, 1000);
        let mut b = PacketBuilder::new(9);
        let result = b.payload(
            HeadFields {
                routing: 7,
                start_addr: 0x200,
                direction: Direction::HwaToMem,
                ..HeadFields::default()
            },
            &[42, 43],
        );
        for f in &result.flits {
            mmu.deliver(*f, 10);
        }
        assert_eq!(mmu.stats.results_written, 1);
        assert_eq!(mmu.dram.read_words(0x200, 2), vec![42, 43]);
    }

    #[test]
    fn backpressure_holds_outbox() {
        let mut mmu = Mmu::new(7, 5, 1000);
        mmu.dram.write_words(0, &[1]);
        mmu.deliver(grant(0, 4), 0);
        let done = mmu.dram.access_done_at(0, 1, 1000);
        assert!(mmu.step(done, false).is_none());
        assert!(!mmu.idle());
        assert!(mmu.step(done, true).is_some());
    }
}
