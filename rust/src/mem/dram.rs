//! Simple main-memory model: fixed access latency plus word-serial
//! bandwidth, with a backing store for functional reads/writes.

use std::collections::BTreeMap;

use crate::clock::Ps;

/// Fixed DRAM access latency in NoC cycles (CAS + controller), a common
/// MPSoC-prototype figure.
pub const DRAM_LATENCY_CYCLES: u64 = 30;
/// Words transferred per cycle once a burst is streaming.
pub const DRAM_WORDS_PER_CYCLE: u64 = 2;

#[derive(Debug, Default)]
pub struct Dram {
    store: BTreeMap<u32, u32>,
    pub reads: u64,
    pub writes: u64,
}

impl Dram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write_words(&mut self, addr: u32, words: &[u32]) {
        for (i, w) in words.iter().enumerate() {
            self.store.insert(addr + (i as u32) * 4, *w);
        }
        self.writes += 1;
    }

    pub fn read_words(&mut self, addr: u32, n: usize) -> Vec<u32> {
        self.reads += 1;
        (0..n)
            .map(|i| {
                self.store
                    .get(&(addr + (i as u32) * 4))
                    .copied()
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Like [`read_words`](Self::read_words) but fills a caller-owned
    /// buffer, so steady-state DMA paths can reuse one scratch allocation.
    pub fn read_words_into(&mut self, addr: u32, n: usize, out: &mut Vec<u32>) {
        self.reads += 1;
        out.clear();
        out.extend((0..n).map(|i| {
            self.store
                .get(&(addr + (i as u32) * 4))
                .copied()
                .unwrap_or(0)
        }));
    }

    /// Completion time of an `n_words` access starting at `now`,
    /// given the NoC clock period.
    pub fn access_done_at(&self, now: Ps, n_words: usize, period_ps: u64) -> Ps {
        let cycles =
            DRAM_LATENCY_CYCLES + (n_words as u64).div_ceil(DRAM_WORDS_PER_CYCLE);
        now + cycles * period_ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_back_written_words() {
        let mut d = Dram::new();
        d.write_words(0x1000, &[1, 2, 3]);
        assert_eq!(d.read_words(0x1000, 3), vec![1, 2, 3]);
        assert_eq!(d.read_words(0x1000, 5), vec![1, 2, 3, 0, 0]);
    }

    #[test]
    fn read_into_matches_read_and_counts_one_access() {
        let mut d = Dram::new();
        d.write_words(0x20, &[7, 8]);
        let mut buf = vec![99; 16];
        d.read_words_into(0x20, 3, &mut buf);
        assert_eq!(buf, vec![7, 8, 0]);
        assert_eq!(d.reads, 1);
    }

    #[test]
    fn access_time_scales_with_size() {
        let d = Dram::new();
        let t1 = d.access_done_at(0, 4, 1000);
        let t2 = d.access_done_at(0, 64, 1000);
        assert!(t2 > t1);
        assert_eq!(t1, (30 + 2) * 1000);
    }
}
