//! Shared FPGA cache baseline (paper §6.8, Fig. 12): the same system but
//! with TBs, POBs and CBs removed — a single shared system cache
//! (Xilinx LogiCORE System Cache-class) stores all input and output
//! packets, and the HWAs access it directly.
//!
//! The structural hazard the paper measures is the **single cache port**:
//! every payload flit is written into the cache on arrival, read back by
//! the HWA, written again as a result and read once more by the PS — all
//! serialized through one port with hit/miss latencies. Under multi-HWA
//! load the port queue grows and "boosts the average access time",
//! producing the 22.5%/28.2% throughput losses of Fig. 13 and the 1.63x
//! latency gap of Fig. 14.

use std::collections::VecDeque;

use crate::clock::{AsyncFifo, ClockDomain, Ps};
use crate::flit::{
    Direction, Flit, FlitKind, HeadFields, Packet, PacketBuilder, PacketType,
};
use crate::fpga::channel::task::CommandKind;
use crate::fpga::hwa::{EchoCompute, HwaCompute, HwaSpec};
use crate::fpga::ROUTER_FIFO_CAP;

/// Cache hit latency (interface cycles) — BRAM array + tag check.
pub const CACHE_HIT_CYCLES: u64 = 1;
/// Miss penalty (external memory refill), interface cycles.
pub const CACHE_MISS_CYCLES: u64 = 24;
/// Line size: 32 B (two 128-bit flit payloads per access — the System
/// Cache's wide BRAM array side).
pub const LINE_BYTES: u32 = 32;
/// Data flits per cache line access.
pub const FLITS_PER_LINE: usize = 2;

/// Cache-line accesses needed for `data_flits` flits of payload.
pub fn lines_for(data_flits: usize) -> usize {
    data_flits.div_ceil(FLITS_PER_LINE).max(1)
}
/// Concurrent ports (LogiCORE System Cache supports a few optimized
/// ports; contention beyond them serializes — the §6.8 bottleneck).
pub const CACHE_PORTS: usize = 2;

/// Set-associative cache with a small number of serialized ports.
#[derive(Debug)]
pub struct SysCache {
    sets: Vec<VecDeque<u32>>, // per-set LRU stack of tags (front = MRU)
    ways: usize,
    /// Pending accesses (FIFO toward the ports).
    queue: VecDeque<CacheAccess>,
    /// Priority accesses (PS/PR-side port group: TxRead + RxWrite) —
    /// the System Cache's separate optimized ports for the interconnect
    /// side; serviced before HWA-side bulk accesses.
    prio_queue: VecDeque<CacheAccess>,
    /// (completes_at, access) per port.
    in_service: Vec<Option<(Ps, CacheAccess)>>,
    pub hits: u64,
    pub misses: u64,
    pub max_queue: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct CacheAccess {
    pub line_addr: u32,
    pub write: bool,
    /// Channel that issued the access.
    pub owner: usize,
    /// Which pipeline stage the completion unblocks.
    pub purpose: AccessPurpose,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPurpose {
    RxWrite,
    HwaRead,
    HwaWrite,
    TxRead,
}

impl SysCache {
    /// `capacity_bytes` in [32 KiB, 512 KiB] (paper §6.8), 2-way default.
    pub fn new(capacity_bytes: u32, ways: usize) -> Self {
        let n_lines = capacity_bytes / LINE_BYTES;
        let n_sets = (n_lines as usize / ways).max(1);
        Self {
            sets: (0..n_sets).map(|_| VecDeque::new()).collect(),
            ways,
            queue: VecDeque::new(),
            prio_queue: VecDeque::new(),
            in_service: vec![None; CACHE_PORTS],
            hits: 0,
            misses: 0,
            max_queue: 0,
        }
    }

    pub fn enqueue(&mut self, access: CacheAccess) {
        match access.purpose {
            AccessPurpose::TxRead | AccessPurpose::RxWrite => {
                self.prio_queue.push_back(access)
            }
            _ => self.queue.push_back(access),
        }
        self.max_queue = self.max_queue.max(self.queue_len());
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
            + self.prio_queue.len()
            + self.in_service.iter().filter(|s| s.is_some()).count()
    }

    fn lookup(&mut self, line_addr: u32) -> bool {
        let set = (line_addr as usize) % self.sets.len();
        let s = &mut self.sets[set];
        if let Some(pos) = s.iter().position(|t| *t == line_addr) {
            s.remove(pos);
            s.push_front(line_addr);
            self.hits += 1;
            true
        } else {
            s.push_front(line_addr);
            while s.len() > self.ways {
                s.pop_back();
            }
            self.misses += 1;
            false
        }
    }

    /// One interface cycle: returns accesses completing *this* cycle
    /// (at most one per port) via the `done` buffer.
    pub fn step(&mut self, now: Ps, period_ps: u64, done: &mut Vec<CacheAccess>) {
        for slot in self.in_service.iter_mut() {
            if let Some((done_at, acc)) = slot {
                if now >= *done_at {
                    done.push(*acc);
                    *slot = None;
                }
            }
        }
        for slot in 0..self.in_service.len() {
            if self.in_service[slot].is_none() {
                if let Some(acc) = self
                    .prio_queue
                    .pop_front()
                    .or_else(|| self.queue.pop_front())
                {
                    let hit = self.lookup(acc.line_addr);
                    let cycles =
                        if hit { CACHE_HIT_CYCLES } else { CACHE_MISS_CYCLES };
                    self.in_service[slot] =
                        Some((now + cycles * period_ps, acc));
                } else {
                    break;
                }
            }
        }
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty()
            && self.prio_queue.is_empty()
            && self.in_service.iter().all(|s| s.is_none())
    }

    /// Any access by `owner` with `purpose` queued or in service?
    pub fn has_outstanding(&self, owner: usize, purpose: AccessPurpose) -> bool {
        self.queue
            .iter()
            .chain(self.prio_queue.iter())
            .any(|a| a.owner == owner && a.purpose == purpose)
            || self.in_service.iter().any(|s| {
                matches!(s, Some((_, a)) if a.owner == owner && a.purpose == purpose)
            })
    }
}

/// Per-channel pipeline state in the cache-based fabric.
#[derive(Debug)]
enum CacheChanState {
    Idle,
    /// HWA reading input lines back from cache.
    HwaReading { left: usize },
    Executing { done_at: Ps },
    /// HWA writing result lines.
    HwaWriting { left: usize },
    /// Result in cache; PS may pick it up.
    ResultReady,
}

/// An input packet staged *in the cache* (the cache-design analogue of a
/// task buffer: the cache "is used to store input and output packets").
#[derive(Debug)]
struct StagedTask {
    head: HeadFields,
    words: Vec<u32>,
    /// RxWrite cache accesses still outstanding for this packet.
    writes_left: usize,
    /// All flits received (tail seen)?
    complete: bool,
}

struct CacheChannel {
    spec: HwaSpec,
    hwa_clock: ClockDomain,
    state: CacheChanState,
    /// Requests pending grant (no TBs: bounded by outstanding limit).
    rb: VecDeque<HeadFields>,
    cmd_out: VecDeque<HeadFields>,
    /// Granted invocations not yet fully returned.
    outstanding: usize,
    /// Input packets staged in the cache awaiting the HWA.
    staged: VecDeque<StagedTask>,
    /// The in-flight task's data (functional path).
    head: Option<HeadFields>,
    words: Vec<u32>,
    tasks_executed: u64,
    /// Result packet flits pending TX cache reads, then emission.
    tx: VecDeque<Flit>,
    tx_reads_left: usize,
}

/// Outstanding invocations per channel (mirrors the 2-TB main design for
/// a fair comparison).
const OUTSTANDING_LIMIT: usize = 2;

pub struct CacheFpgaStats {
    pub flits_from_noc: u64,
    pub flits_to_noc: u64,
}

/// The shared-cache FPGA node: same NoC-facing interface as `fpga::Fpga`.
pub struct CacheFpga {
    pub node: u8,
    /// Map src_id -> assigned MMU node (the floorplan's per-processor
    /// nearest/hashed assignment; single-MMU systems repeat one node).
    mmu_route: Vec<u8>,
    reply_route: Vec<u8>,
    pub iface_clock: ClockDomain,
    router_out: AsyncFifo<Flit>,
    router_in: AsyncFifo<Flit>,
    pub cache: SysCache,
    channels: Vec<CacheChannel>,
    /// RX stream demux state (single serial input stream).
    rx_active: Option<(usize, HeadFields)>,
    builder: PacketBuilder,
    compute: Box<dyn HwaCompute>,
    ps_rr: usize,
    /// Channel currently streaming a result packet (commands must not
    /// interleave mid-packet — wormhole contiguity on the NoC).
    tx_active: Option<usize>,
    pub stats: CacheFpgaStats,
}

impl CacheFpga {
    pub fn new(
        node: u8,
        mmu_route: Vec<u8>,
        reply_route: Vec<u8>,
        specs: Vec<HwaSpec>,
        cache_bytes: u32,
        noc_clock: &ClockDomain,
    ) -> Self {
        assert!(!mmu_route.is_empty(), "at least one MMU node");
        let iface_clock = ClockDomain::from_mhz("iface", 300.0);
        Self {
            node,
            mmu_route,
            reply_route,
            router_out: AsyncFifo::new(ROUTER_FIFO_CAP, &iface_clock),
            router_in: AsyncFifo::new(ROUTER_FIFO_CAP, noc_clock),
            iface_clock,
            cache: SysCache::new(cache_bytes, 2),
            channels: specs
                .into_iter()
                .map(|spec| CacheChannel {
                    hwa_clock: ClockDomain::from_mhz(spec.name, spec.fmax_mhz),
                    spec,
                    state: CacheChanState::Idle,
                    rb: VecDeque::new(),
                    cmd_out: VecDeque::new(),
                    outstanding: 0,
                    staged: VecDeque::new(),
                    head: None,
                    words: Vec::new(),
                    tasks_executed: 0,
                    tx: VecDeque::new(),
                    tx_reads_left: 0,
                })
                .collect(),
            rx_active: None,
            builder: PacketBuilder::new(0x6000_0000),
            compute: Box::new(EchoCompute),
            ps_rr: 0,
            tx_active: None,
            stats: CacheFpgaStats {
                flits_from_noc: 0,
                flits_to_noc: 0,
            },
        }
    }

    pub fn set_compute(&mut self, compute: Box<dyn HwaCompute>) {
        self.compute = compute;
    }

    pub fn can_accept_from_noc(&self) -> bool {
        self.router_out.can_push()
    }

    pub fn push_from_noc(&mut self, now: Ps, flit: Flit) {
        let ok = self.router_out.push(now, flit);
        debug_assert!(ok);
        self.stats.flits_from_noc += 1;
    }

    pub fn pop_to_noc(&mut self, now: Ps) -> Option<Flit> {
        let f = self.router_in.pop(now);
        if f.is_some() {
            self.stats.flits_to_noc += 1;
        }
        f
    }

    /// NoC-side scheduler probe: flits queued toward the interconnect
    /// (even if not yet CDC-visible) keep the NoC domain busy.
    pub fn noc_tx_pending(&self) -> bool {
        !self.router_in.is_empty()
    }

    pub fn tasks_executed(&self) -> u64 {
        self.channels.iter().map(|c| c.tasks_executed).sum()
    }

    /// Cache region for a channel's staging area. Channels reuse fixed
    /// per-channel regions (the system cache's write-allocate keeps them
    /// resident, so steady state is hit-dominated; the cost the paper
    /// measures is the single port's serialization, plus capacity misses
    /// when the working set outgrows small cache configurations).
    fn fresh_region(&mut self, idx: usize, _lines: usize) -> u32 {
        (idx as u32) * 64
    }

    /// One interface-clock cycle.
    pub fn step_iface(&mut self, now: Ps) {
        let period = self.iface_clock.period_ps;
        // 1) Cache port progress; completions unblock pipeline stages.
        let mut dones = Vec::new();
        self.cache.step(now, period, &mut dones);
        for done in dones {
            let ch = &mut self.channels[done.owner];
            match (&mut ch.state, done.purpose) {
                (_, AccessPurpose::RxWrite) => {
                    if let Some(t) = ch
                        .staged
                        .iter_mut()
                        .find(|t| t.writes_left > 0)
                    {
                        t.writes_left -= 1;
                    }
                }
                (CacheChanState::HwaReading { left }, AccessPurpose::HwaRead) => {
                    *left -= 1;
                    if *left == 0 {
                        let exec =
                            ch.spec.exec_cycles * ch.hwa_clock.period_ps;
                        ch.state = CacheChanState::Executing {
                            done_at: now + exec,
                        };
                    }
                }
                (CacheChanState::HwaWriting { left }, AccessPurpose::HwaWrite) => {
                    *left -= 1;
                    if *left == 0 {
                        ch.state = CacheChanState::ResultReady;
                    }
                }
                (_, AccessPurpose::TxRead) => {
                    ch.tx_reads_left = ch.tx_reads_left.saturating_sub(1);
                }
                _ => {}
            }
        }
        // Dispatch: an idle HWA picks the oldest fully-cached staged task.
        let mut pending_reads: Vec<(u32, usize)> = Vec::new();
        for (idx_of, ch) in self.channels.iter_mut().enumerate() {
            if matches!(ch.state, CacheChanState::Idle) {
                let ready = ch
                    .staged
                    .front()
                    .map(|t| t.complete && t.writes_left == 0)
                    .unwrap_or(false);
                if ready {
                    let t = ch.staged.pop_front().expect("checked");
                    let start = t.head.start_addr;
                    ch.head = Some(t.head);
                    ch.words = t.words;
                    ch.words.resize(ch.spec.in_words, 0);
                    let lines = lines_for(ch.spec.in_packet_flits() - 1);
                    ch.state = CacheChanState::HwaReading { left: lines };
                    // The HWA's read port pipelines its line fetches.
                    for line in 0..lines {
                        pending_reads.push((start + line as u32, idx_of));
                    }
                }
            }
        }
        for (addr, owner) in pending_reads {
            self.cache.enqueue(CacheAccess {
                line_addr: addr,
                write: false,
                owner,
                purpose: AccessPurpose::HwaRead,
            });
        }
        // 2) Execution completions -> burst-enqueue the result writes
        // (the HWA's write port pipelines its line stores).
        for (i, ch) in self.channels.iter_mut().enumerate() {
            if let CacheChanState::Executing { done_at } = ch.state {
                if now >= done_at {
                    ch.words = self.compute.compute(&ch.spec, &ch.words);
                    ch.tasks_executed += 1;
                    let lines = lines_for(ch.spec.out_packet_flits() - 1);
                    ch.state = CacheChanState::HwaWriting { left: lines };
                    let base = 0x8000_0000
                        + ch.head.map(|h| h.start_addr).unwrap_or(0);
                    for line in 0..lines {
                        self.cache.enqueue(CacheAccess {
                            line_addr: base + line as u32,
                            write: true,
                            owner: i,
                            purpose: AccessPurpose::HwaWrite,
                        });
                    }
                }
            }
        }
        // 3) RX: parse the serial input stream.
        self.step_rx(now);
        // 4) Grants (no TBs: bounded by OUTSTANDING_LIMIT).
        for (i, ch) in self.channels.iter_mut().enumerate() {
            let _ = i;
            if ch.outstanding < OUTSTANDING_LIMIT {
                if let Some(req) = ch.rb.pop_front() {
                    ch.outstanding += 1;
                    // Field accesses keep the borrow disjoint from the
                    // &mut channel iteration (a &self helper would not).
                    let dest = match req.direction {
                        Direction::MemToHwa => self
                            .mmu_route
                            .get(req.src_id as usize)
                            .copied()
                            .unwrap_or(self.mmu_route[0]),
                        _ => self.reply_route[req.src_id as usize],
                    };
                    ch.cmd_out.push_back(HeadFields {
                        routing: dest,
                        kind: FlitKind::Single,
                        src_id: req.src_id,
                        hwa_id: req.hwa_id,
                        pkt_type: PacketType::Command,
                        priority: req.priority,
                        direction: req.direction,
                        data_size: req.data_size,
                        payload: CommandKind::Grant.encode(),
                        ..HeadFields::default()
                    });
                }
            }
        }
        // 5) TX: commands first, then result packets via cache reads.
        self.step_tx(now);
    }

    fn step_rx(&mut self, now: Ps) {
        let Some(flit) = self.router_out.peek(now).copied() else {
            return;
        };
        match self.rx_active {
            None => {
                debug_assert!(flit.is_head());
                let head = flit.head_fields();
                let idx = head.hwa_id as usize;
                if idx >= self.channels.len() {
                    self.router_out.pop(now);
                    return;
                }
                match head.pkt_type {
                    PacketType::Command => {
                        self.router_out.pop(now);
                        self.channels[idx].rb.push_back(head);
                    }
                    PacketType::Payload => {
                        // Stage the packet in the cache (grants bound the
                        // number of staged packets per channel).
                        if self.channels[idx].staged.len() < OUTSTANDING_LIMIT {
                            self.router_out.pop(now);
                            let lines = self.channels[idx].spec.in_packet_flits() - 1;
                            let slot = self.channels[idx].staged.len();
                            let mut h = head;
                            h.start_addr = self.fresh_region(idx, lines * 2)
                                + (slot as u32) * 32;
                            self.channels[idx].staged.push_back(StagedTask {
                                head: h,
                                words: Vec::new(),
                                writes_left: 0,
                                complete: false,
                            });
                            self.rx_active = Some((idx, h));
                        }
                        // else: head waits in the router buffer
                        // (backpressure onto the NoC).
                    }
                }
            }
            Some((idx, head)) => {
                self.router_out.pop(now);
                let [a, b] = flit.body_payload();
                let ch = &mut self.channels[idx];
                let in_words = ch.spec.in_words;
                let task = ch.staged.back_mut().expect("head staged first");
                for w in [a as u32, (a >> 32) as u32, b as u32, (b >> 32) as u32] {
                    if task.words.len() < in_words {
                        task.words.push(w);
                    }
                }
                // A cache write per filled line (FLITS_PER_LINE flits).
                let flits_in = task.words.len().div_ceil(4);
                if flits_in % FLITS_PER_LINE == 0 || flit.kind() == FlitKind::Tail {
                    task.writes_left += 1;
                    self.cache.enqueue(CacheAccess {
                        line_addr: head.start_addr
                            + (flits_in as u32 / FLITS_PER_LINE as u32),
                        write: true,
                        owner: idx,
                        purpose: AccessPurpose::RxWrite,
                    });
                }
                if flit.kind() == FlitKind::Tail {
                    task.complete = true;
                    self.rx_active = None;
                }
            }
        }
    }

    fn step_tx(&mut self, now: Ps) {
        let n = self.channels.len();
        // A result packet mid-stream owns the link: commands must not
        // interleave inside it (wormhole contiguity on the NoC).
        if let Some(idx) = self.tx_active {
            let ch = &mut self.channels[idx];
            if ch.tx_reads_left * FLITS_PER_LINE < ch.tx.len() {
                if let Some(f) = ch.tx.front().copied() {
                    if self.router_in.push(now, f) {
                        ch.tx.pop_front();
                        if ch.tx.is_empty() {
                            ch.outstanding -= 1;
                            ch.state = CacheChanState::Idle;
                            ch.head = None;
                            self.tx_active = None;
                        }
                    }
                }
            }
            return;
        }
        // Commands (grants) first.
        for k in 0..n {
            let idx = (self.ps_rr + k) % n;
            if let Some(head) = self.channels[idx].cmd_out.pop_front() {
                let pkt = self.builder.command(head);
                if self.router_in.push(now, pkt.flits[0]) {
                    self.ps_rr = (idx + 1) % n;
                    return;
                } else {
                    self.channels[idx].cmd_out.push_front(head);
                    return;
                }
            }
        }
        // Select the next result packet to stream.
        for k in 0..n {
            let idx = (self.ps_rr + k) % n;
            let ch = &mut self.channels[idx];
            if matches!(ch.state, CacheChanState::ResultReady) {
                // Form the packet; TX reads happen as it streams.
                let head = ch.head.expect("task head");
                let dest = match head.direction {
                    Direction::MemToHwa | Direction::HwaToMem => self
                        .mmu_route
                        .get(head.src_id as usize)
                        .copied()
                        .unwrap_or(self.mmu_route[0]),
                    _ => self.reply_route[head.src_id as usize],
                };
                let pkt: Packet = self.builder.payload(
                    HeadFields {
                        routing: dest,
                        src_id: head.src_id,
                        hwa_id: head.hwa_id,
                        priority: head.priority,
                        direction: Direction::HwaToProc,
                        task_head: true,
                        task_tail: true,
                        ..HeadFields::default()
                    },
                    &ch.words,
                );
                ch.tx_reads_left = lines_for(pkt.len() - 1);
                for line in 0..lines_for(pkt.len() - 1) {
                    self.cache.enqueue(CacheAccess {
                        line_addr: 0x8000_0000 + head.start_addr + line as u32,
                        write: false,
                        owner: idx,
                        purpose: AccessPurpose::TxRead,
                    });
                }
                ch.tx = pkt.flits.into();
                self.ps_rr = (idx + 1) % n;
                self.tx_active = Some(idx);
                return;
            }
        }
    }

    /// Debug: per-channel state labels.
    pub fn debug_states(&self) -> Vec<String> {
        self.channels
            .iter()
            .map(|c| {
                format!(
                    "{:?}/st{}/out{}/rb{}/tx{}",
                    std::mem::discriminant(&c.state),
                    c.staged.len(),
                    c.outstanding,
                    c.rb.len(),
                    c.tx.len()
                )
            })
            .collect()
    }

    /// Debug: (grants issued, tasks executed) per channel.
    pub fn debug_grants(&self) -> Vec<(u64, u64)> {
        self.channels
            .iter()
            .map(|c| (c.outstanding as u64, c.tasks_executed))
            .collect()
    }

    pub fn quiescent(&self) -> bool {
        self.router_out.is_empty()
            && self.router_in.is_empty()
            && self.cache.idle()
            && self.rx_active.is_none()
            && self.channels.iter().all(|c| {
                matches!(c.state, CacheChanState::Idle)
                    && c.rb.is_empty()
                    && c.cmd_out.is_empty()
                    && c.tx.is_empty()
                    && c.staged.is_empty()
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::hwa::spec_by_name;

    #[test]
    fn cache_lru_hits_and_misses() {
        let mut c = SysCache::new(1024, 2); // 64 lines, 32 sets
        assert!(!c.lookup(5), "cold miss");
        assert!(c.lookup(5), "hit after fill");
        // Two-way set: 5, 5+32, then 5+64 evicts LRU (5).
        assert!(!c.lookup(5 + 32));
        assert!(!c.lookup(5 + 64));
        assert!(!c.lookup(5), "evicted");
    }

    #[test]
    fn cache_port_serializes() {
        let mut c = SysCache::new(1024, 2);
        for i in 0..4 {
            c.enqueue(CacheAccess {
                line_addr: i,
                write: true,
                owner: 0,
                purpose: AccessPurpose::RxWrite,
            });
        }
        let period = 3333;
        let mut completions = 0;
        let mut now = 0;
        let mut done = Vec::new();
        for _ in 0..300 {
            now += period;
            done.clear();
            c.step(now, period, &mut done);
            completions += done.len();
        }
        assert_eq!(completions, 4);
        // All cold misses: >= 4 * CACHE_MISS_CYCLES cycles of service.
        assert_eq!(c.misses, 4);
    }

    #[test]
    fn end_to_end_invocation_through_cache() {
        let noc = ClockDomain::from_mhz("noc", 1000.0);
        let mut f = CacheFpga::new(
            5,
            vec![7; 8],
            vec![0; 8],
            vec![spec_by_name("dfadd").unwrap()],
            32 * 1024,
            &noc,
        );
        // Request.
        let mut b = PacketBuilder::new(1);
        let req = b.command(HeadFields {
            routing: 5,
            hwa_id: 0,
            src_id: 1,
            direction: Direction::ProcToHwa,
            payload: CommandKind::Request.encode(),
            ..HeadFields::default()
        });
        f.push_from_noc(0, req.flits[0]);
        let mut now = 0;
        let mut grant = None;
        for _ in 0..1000 {
            now += f.iface_clock.period_ps;
            f.step_iface(now);
            if let Some(flit) = f.pop_to_noc(now) {
                grant = Some(flit.head_fields());
                break;
            }
        }
        let grant = grant.expect("grant");
        assert_eq!(CommandKind::decode(grant.payload), CommandKind::Grant);
        // Payload.
        let p = b.payload(
            HeadFields {
                routing: 5,
                hwa_id: 0,
                src_id: 1,
                task_head: true,
                task_tail: true,
                direction: Direction::ProcToHwa,
                ..HeadFields::default()
            },
            &[1, 2, 3, 4],
        );
        for flit in &p.flits {
            f.push_from_noc(now, *flit);
        }
        let mut result_flits = Vec::new();
        for _ in 0..5000 {
            now += f.iface_clock.period_ps;
            f.step_iface(now);
            while let Some(flit) = f.pop_to_noc(now) {
                result_flits.push(flit);
            }
            if result_flits.iter().any(|fl| fl.is_tail() && !fl.is_head()) {
                break;
            }
        }
        assert!(
            result_flits.iter().any(|fl| fl.is_head()),
            "result head seen"
        );
        assert_eq!(f.tasks_executed(), 1);
        assert!(f.cache.hits + f.cache.misses > 0, "cache was exercised");
        assert!(f.quiescent());
    }
}
