//! Comparison baselines: AXI4 bus integration (§6.7, Fig. 11) and the
//! shared FPGA cache design (§6.8, Fig. 12).

pub mod axi;
pub mod shared_cache;

pub use axi::{AxiBus, AXI_BURST_OVERHEAD};
pub use shared_cache::{CacheFpga, SysCache, CACHE_HIT_CYCLES, CACHE_MISS_CYCLES};
