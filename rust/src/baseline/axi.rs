//! AXI4 bus-based integration baseline (paper §6.7, Fig. 11): the NoC is
//! replaced by an AMBA AXI4 interconnect between the processors, the MMU
//! and the FPGA.
//!
//! Model: a crossbar-less shared interconnect (ARM CoreLink NIC-class)
//! with independent request (toward the FPGA slave) and response (from
//! the FPGA master port) channels. Each channel moves one data beat
//! (= one flit's worth) per bus cycle; each packet (burst) pays an
//! address-phase arbitration overhead. Masters are arbitrated round-robin
//! per burst. The bus clock equals the CMP clock (1 GHz modelled — §6.7
//! sets the AXI frequency identical to the processors "to obtain the
//! upper limit of throughput").
//!
//! Against the mesh NoC the structural differences are exactly the
//! paper's: (1) all traffic serializes onto one medium instead of many
//! concurrent links, and (2) every burst pays the shared-address-channel
//! handshake.

use std::collections::VecDeque;

use crate::flit::Flit;

/// Address-phase + handshake cycles per burst, occupying the interconnect
/// switch (AR/AW decode + crossbar grant + slave ready; CoreLink
/// NIC-class pipelines). Small-packet traffic is overhead-dominated —
/// why the paper's Eight-HWA loses more on the bus than Izigzag-HWA.
pub const AXI_BURST_OVERHEAD: u64 = 8;
/// Bus beats per 137-bit flit: a 64-bit AXI4 data path ([34]-class
/// interconnect) moves ~one half-flit per beat where a NoC link moves a
/// full flit per cycle — the bandwidth asymmetry behind Fig. 13.
pub const BEATS_PER_FLIT: u64 = 2;
/// Per-master inject queue depth (write-data FIFO in the NIC).
pub const AXI_QUEUE_CAP: usize = 16;
/// Per-node response queue depth.
pub const AXI_EJECT_CAP: usize = 32;

#[derive(Debug)]
struct BusChannel {
    /// Per-source pending bursts (flit streams).
    queues: Vec<VecDeque<Flit>>,
    rr: usize,
    /// Currently streaming source and remaining overhead.
    active: Option<usize>,
    overhead_left: u64,
    /// Beats still to transfer for the flit at the queue front.
    beats_left: u64,
    pub beats: u64,
    pub bursts: u64,
}

impl BusChannel {
    fn new(n_sources: usize) -> Self {
        Self {
            queues: (0..n_sources).map(|_| VecDeque::new()).collect(),
            rr: 0,
            active: None,
            overhead_left: 0,
            beats_left: BEATS_PER_FLIT,
            beats: 0,
            bursts: 0,
        }
    }

    fn can_push(&self, src: usize) -> bool {
        self.queues[src].len() < AXI_QUEUE_CAP
    }

    fn push(&mut self, src: usize, flit: Flit) -> bool {
        if !self.can_push(src) {
            return false;
        }
        self.queues[src].push_back(flit);
        true
    }

    /// Burst acquisition (runs every cycle; arbitration itself is free,
    /// but the acquired burst's address phase consumes switch cycles in
    /// [`BusChannel::take_beat`]).
    fn tick(&mut self) {
        if self.active.is_none() {
            let n = self.queues.len();
            for k in 0..n {
                let src = (self.rr + k) % n;
                match self.queues[src].front() {
                    Some(f) if f.is_head() => {
                        self.active = Some(src);
                        self.overhead_left = AXI_BURST_OVERHEAD;
                        self.bursts += 1;
                        self.rr = (src + 1) % n;
                        break;
                    }
                    Some(_) => {
                        // Continuation without ownership cannot happen:
                        // bursts are enqueued atomically per source.
                        self.active = Some(src);
                        self.overhead_left = 0;
                        break;
                    }
                    None => {}
                }
            }
        }
    }

    /// True when this channel wants the shared switch this cycle
    /// (address-phase cycles included).
    fn beat_ready(&self) -> bool {
        matches!(self.active, Some(src) if self.overhead_left > 0
            || !self.queues[src].is_empty())
    }

    /// Use the switch for one cycle: burn an address-phase cycle or
    /// transfer one data beat; a flit completes (and is returned) after
    /// BEATS_PER_FLIT beats.
    fn take_beat(&mut self) -> Option<Flit> {
        let src = self.active?;
        if self.overhead_left > 0 {
            self.overhead_left -= 1;
            return None;
        }
        self.beats += 1;
        if self.beats_left > 1 {
            self.beats_left -= 1;
            return None;
        }
        self.beats_left = BEATS_PER_FLIT;
        let flit = self.queues[src].pop_front()?;
        if flit.is_tail() {
            self.active = None;
        }
        Some(flit)
    }

    fn is_empty(&self) -> bool {
        self.active.is_none() && self.queues.iter().all(|q| q.is_empty())
    }
}

/// Why an AXI bus could not be built from a floorplan: the model is one
/// FPGA slave/master pair (§6.7), so exactly one fabric endpoint is
/// supported. Returned as a typed error — never a panic — so the sweep
/// harness can reject `net = axi` multi-FPGA specs with a message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AxiTopologyError {
    /// How many fabric endpoints the floorplan asked for.
    pub endpoints: usize,
}

impl AxiTopologyError {
    pub fn endpoints(&self) -> usize {
        self.endpoints
    }
}

impl std::fmt::Display for AxiTopologyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "the AXI4 bus prototype models exactly one FPGA endpoint, \
             got {} (use the NoC for multi-FPGA floorplans)",
            self.endpoints
        )
    }
}

impl std::error::Error for AxiTopologyError {}

/// The AXI interconnect: request channel (masters -> FPGA) and response
/// channel (FPGA -> masters), each one beat per cycle.
pub struct AxiBus {
    pub n_nodes: usize,
    pub fpga_node: usize,
    request: BusChannel,
    response: BusChannel,
    eject: Vec<VecDeque<Flit>>,
    pub cycles: u64,
    pub flits_injected: u64,
    pub flits_ejected: u64,
}

impl AxiBus {
    /// Build the bus for a floorplan's fabric endpoint list. The model
    /// supports exactly one endpoint (the lone FPGA slave/master pair);
    /// anything else is a typed [`AxiTopologyError`].
    pub fn new(
        n_nodes: usize,
        endpoints: &[usize],
    ) -> Result<Self, AxiTopologyError> {
        let [fpga_node] = endpoints else {
            return Err(AxiTopologyError {
                endpoints: endpoints.len(),
            });
        };
        Ok(Self {
            n_nodes,
            fpga_node: *fpga_node,
            request: BusChannel::new(n_nodes),
            response: BusChannel::new(1),
            eject: (0..n_nodes).map(|_| VecDeque::new()).collect(),
            cycles: 0,
            flits_injected: 0,
            flits_ejected: 0,
        })
    }

    pub fn can_inject(&self, node: usize) -> bool {
        if node == self.fpga_node {
            self.response.can_push(0)
        } else {
            self.request.can_push(node)
        }
    }

    pub fn try_inject(&mut self, node: usize, flit: Flit) -> bool {
        let ok = if node == self.fpga_node {
            self.response.push(0, flit)
        } else {
            self.request.push(node, flit)
        };
        if ok {
            self.flits_injected += 1;
        }
        ok
    }

    pub fn eject_pop(&mut self, node: usize) -> Option<Flit> {
        let f = self.eject[node].pop_front();
        if f.is_some() {
            self.flits_ejected += 1;
        }
        f
    }

    pub fn eject_len(&self, node: usize) -> usize {
        self.eject[node].len()
    }

    pub fn step(&mut self) {
        self.cycles += 1;
        // Address-phase handshakes progress in parallel...
        self.request.tick();
        self.response.tick();
        // ...but the interconnect's data switch moves ONE beat per cycle,
        // shared between the request and response directions (the NIC's
        // single crossbar slice toward the lone FPGA slave/master pair) —
        // the serialization the paper's Figs. 13/14 measure against the
        // NoC's concurrent links. Round-robin between directions, derived
        // from the cycle counter so that idle cycles fast-forwarded by the
        // event-driven scheduler (folded in via `account_idle_cycles`)
        // leave the arbitration parity identical to per-edge stepping.
        let req_first = self.cycles % 2 == 0;
        let req_ok = self.request.beat_ready()
            && self.eject[self.fpga_node].len() < AXI_EJECT_CAP;
        let resp_ok = self.response.beat_ready();
        let take_req = req_ok && (req_first || !resp_ok);
        if take_req {
            if let Some(f) = self.request.take_beat() {
                self.eject[self.fpga_node].push_back(f);
            }
        } else if resp_ok {
            // Response bursts are contiguous per destination (the FPGA's
            // PS emits whole packets), so routing by each flit's dest
            // field keeps bursts intact.
            if let Some(f) = self.response.take_beat() {
                let dest = f.dest() as usize;
                debug_assert!(dest < self.n_nodes);
                self.eject[dest].push_back(f);
            }
        }
    }

    pub fn idle(&self) -> bool {
        self.request.is_empty()
            && self.response.is_empty()
            && self.eject.iter().all(|q| q.is_empty())
    }

    /// Fold `n` bus cycles the idle-skipping scheduler fast-forwarded past
    /// (the bus was provably empty; keeps stats and arbitration parity
    /// identical to per-edge stepping).
    pub fn account_idle_cycles(&mut self, n: u64) {
        self.cycles += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{HeadFields, PacketBuilder};

    fn packet(dest: u8, words: usize, flow: u32) -> Vec<Flit> {
        let mut b = PacketBuilder::new(flow);
        b.payload(
            HeadFields {
                routing: dest,
                ..HeadFields::default()
            },
            &vec![1u32; words],
        )
        .flits
    }

    #[test]
    fn single_burst_delivered_with_overhead() {
        let mut bus = AxiBus::new(4, &[3]).unwrap();
        let flits = packet(3, 8, 1); // head + 2 data
        for f in &flits {
            assert!(bus.try_inject(0, *f));
        }
        let mut got = Vec::new();
        for _ in 0..20 {
            bus.step();
            while let Some(f) = bus.eject_pop(3) {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 3);
        assert!(bus.idle());
    }

    #[test]
    fn bursts_serialize_across_masters() {
        // Two masters inject simultaneously: total time ~= sum of bursts,
        // unlike a mesh where disjoint paths run concurrently.
        let mut bus = AxiBus::new(4, &[3]).unwrap();
        for src in 0..2 {
            for f in packet(3, 8, src as u32) {
                bus.try_inject(src, f);
            }
        }
        let mut done_at = 0;
        let mut got = 0;
        for cycle in 1..100 {
            bus.step();
            while bus.eject_pop(3).is_some() {
                got += 1;
                done_at = cycle;
            }
            if got == 6 {
                break;
            }
        }
        // 2 bursts x (overlapped 1-cycle visible overhead + 3 beats) = 8+.
        assert_eq!(got, 6);
        assert!(done_at >= 8, "done_at={done_at}");
    }

    #[test]
    fn burst_contiguity_preserved() {
        let mut bus = AxiBus::new(3, &[2]).unwrap();
        for src in 0..2 {
            for f in packet(2, 12, src as u32) {
                bus.try_inject(src, f);
            }
        }
        let mut flows = Vec::new();
        for _ in 0..50 {
            bus.step();
            while let Some(f) = bus.eject_pop(2) {
                flows.push(f.meta.flow);
            }
        }
        assert_eq!(flows.len(), 8);
        // First burst fully before second.
        assert!(flows[..4].iter().all(|f| *f == flows[0]));
        assert!(flows[4..].iter().all(|f| *f == flows[4]));
    }

    #[test]
    fn response_channel_routes_by_dest() {
        let mut bus = AxiBus::new(4, &[3]).unwrap();
        for f in packet(1, 4, 7) {
            bus.try_inject(3, f);
        }
        let mut got = 0;
        for _ in 0..20 {
            bus.step();
            while bus.eject_pop(1).is_some() {
                got += 1;
            }
        }
        assert_eq!(got, 2);
    }

    #[test]
    fn multi_endpoint_floorplans_are_a_typed_error_not_a_panic() {
        let err = AxiBus::new(9, &[2, 8]).unwrap_err();
        assert_eq!(err, AxiTopologyError { endpoints: 2 });
        assert!(err.to_string().contains("exactly one FPGA endpoint"));
        assert_eq!(
            AxiBus::new(9, &[]).unwrap_err().endpoints(),
            0,
            "an empty endpoint list is rejected too"
        );
    }

    #[test]
    fn backpressure_on_full_queue() {
        let mut bus = AxiBus::new(2, &[1]).unwrap();
        let mut accepted = 0;
        for f in std::iter::repeat(packet(1, 0, 1)).flatten().take(64) {
            if bus.try_inject(0, f) {
                accepted += 1;
            }
        }
        assert!(accepted <= AXI_QUEUE_CAP);
    }
}
