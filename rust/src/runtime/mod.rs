//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (`artifacts/*.hlo.txt` + `manifest.txt`) and executes them from the
//! simulator's HWA-completion hook — Python is never on this path.
//!
//! Interchange is HLO **text**: jax >= 0.5 emits HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Feature gating: everything touching the `xla` crate ([`Runtime`],
//! [`PjrtCompute`]) lives behind the off-by-default `pjrt` feature so the
//! default build is fully offline. [`NativeCompute`] (the golden Rust
//! implementations) and the manifest parser compile unconditionally and
//! are the default compute path.

pub mod native;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::fpga::hwa::{HwaCompute, HwaSpec};
use native::DEFAULT_QTABLE;

/// One tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    fn parse(s: &str) -> Result<Self> {
        let (dtype, dims) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("bad tensor sig {s:?}"))?;
        let dims = dims
            .split('x')
            .filter(|d| !d.is_empty())
            .map(|d| d.parse::<usize>().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            dtype: dtype.to_string(),
            dims,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Parse `manifest.txt` lines: `name | in sig,sig | out sig`.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSig>> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split('|').map(|p| p.trim()).collect();
        if parts.len() != 3 {
            bail!("bad manifest line: {line:?}");
        }
        let ins = parts[1]
            .strip_prefix("in ")
            .ok_or_else(|| anyhow!("missing 'in': {line:?}"))?;
        let outs = parts[2]
            .strip_prefix("out ")
            .ok_or_else(|| anyhow!("missing 'out': {line:?}"))?;
        out.push(ArtifactSig {
            name: parts[0].to_string(),
            inputs: ins
                .split(',')
                .map(TensorSig::parse)
                .collect::<Result<Vec<_>>>()?,
            outputs: outs
                .split(',')
                .map(TensorSig::parse)
                .collect::<Result<Vec<_>>>()?,
        });
    }
    Ok(out)
}

/// The PJRT runtime: CPU client + lazily compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    dir: PathBuf,
    client: xla::PjRtClient,
    sigs: HashMap<String, ArtifactSig>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load from an artifacts directory (must contain `manifest.txt`).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| {
                format!(
                    "{}/manifest.txt missing — run `make artifacts`",
                    dir.display()
                )
            })?;
        let sigs = parse_manifest(&manifest)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        Ok(Self {
            dir: dir.to_path_buf(),
            client: xla::PjRtClient::cpu()?,
            sigs,
            executables: HashMap::new(),
        })
    }

    /// Default location: `$ACCNOC_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("ACCNOC_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    pub fn signature(&self, name: &str) -> Option<&ArtifactSig> {
        self.sigs.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.sigs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute artifact `name` on f32/i32 inputs already shaped per the
    /// manifest (flattened row-major). Returns flattened outputs.
    pub fn execute(&mut self, name: &str, inputs: &[TensorValue]) -> Result<Vec<TensorValue>> {
        let sig = self
            .sigs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone();
        if inputs.len() != sig.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (tv, ts) in inputs.iter().zip(&sig.inputs) {
            if tv.len() != ts.elements() {
                bail!(
                    "{name}: input size {} != manifest {}",
                    tv.len(),
                    ts.elements()
                );
            }
            let dims: Vec<i64> = ts.dims.iter().map(|d| *d as i64).collect();
            let lit = match tv {
                TensorValue::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
                TensorValue::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            };
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let elements = result.to_tuple()?;
        let mut out = Vec::with_capacity(elements.len());
        for (lit, ts) in elements.into_iter().zip(&sig.outputs) {
            let tv = match ts.dtype.as_str() {
                "float32" => TensorValue::F32(lit.to_vec::<f32>()?),
                "int32" => TensorValue::I32(lit.to_vec::<i32>()?),
                other => bail!("unsupported dtype {other}"),
            };
            out.push(tv);
        }
        Ok(out)
    }
}

/// A flattened tensor value.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorValue {
    pub fn len(&self) -> usize {
        match self {
            TensorValue::F32(v) => v.len(),
            TensorValue::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            TensorValue::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            TensorValue::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }
}

// ---------------------------------------------------------------------------
// HwaCompute implementations
// ---------------------------------------------------------------------------

/// Marshal a task's 64 words into one artifact invocation (row 0 of the
/// batched artifact shape) and back. The quantization table input of the
/// iquantize/chain artifacts is the baked-in ROM table, as in the FPGA.
#[cfg(feature = "pjrt")]
fn words_to_i32(words: &[u32], n: usize) -> Vec<i32> {
    let mut v: Vec<i32> = words.iter().map(|w| *w as i32).collect();
    v.resize(n, 0);
    v
}

#[cfg(feature = "pjrt")]
fn words_to_f32(words: &[u32], n: usize) -> Vec<f32> {
    let mut v: Vec<f32> = words.iter().map(|w| f32::from_bits(*w)).collect();
    v.resize(n, 0.0);
    v
}

/// Compute through the PJRT-loaded AOT artifacts; HWAs without an
/// artifact fall back to the native golden implementations.
#[cfg(feature = "pjrt")]
pub struct PjrtCompute {
    pub runtime: Runtime,
    native: NativeCompute,
    pub invocations: u64,
}

#[cfg(feature = "pjrt")]
impl PjrtCompute {
    pub fn new(runtime: Runtime) -> Self {
        Self {
            runtime,
            native: NativeCompute::default(),
            invocations: 0,
        }
    }

    fn run(&mut self, spec: &HwaSpec, input: &[u32]) -> Result<Vec<u32>> {
        let name = spec.artifact.ok_or_else(|| anyhow!("no artifact"))?;
        let sig = self
            .runtime
            .signature(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .clone();
        // Build inputs: first input carries the task's words (padded into
        // the batched shape); a second int32 input of 64 elements is the
        // quantization ROM; df* artifacts take (a, b) split from words.
        let inputs: Vec<TensorValue> = match name {
            "iquantize" | "jpeg_chain" | "jpeg_depth1" | "jpeg_depth2" => {
                vec![
                    TensorValue::I32(words_to_i32(input, sig.inputs[0].elements())),
                    TensorValue::I32(DEFAULT_QTABLE.to_vec()),
                ]
            }
            "izigzag" => vec![TensorValue::I32(words_to_i32(
                input,
                sig.inputs[0].elements(),
            ))],
            // idct's wire format is i32 dequantized coefficients (what
            // iquantize emits); the artifact takes f32 values.
            "idct" => {
                let mut v: Vec<f32> =
                    input.iter().map(|w| (*w as i32) as f32).collect();
                v.resize(sig.inputs[0].elements(), 0.0);
                vec![TensorValue::F32(v)]
            }
            "shiftbound" | "gsm" => vec![TensorValue::F32(
                words_to_f32(input, sig.inputs[0].elements()),
            )],
            "dfadd" | "dfmul" | "dfdiv" => {
                let half = input.len() / 2;
                vec![
                    TensorValue::F32(words_to_f32(
                        &input[..half],
                        sig.inputs[0].elements(),
                    )),
                    TensorValue::F32(words_to_f32(
                        &input[half..],
                        sig.inputs[1].elements(),
                    )),
                ]
            }
            other => bail!("no marshalling rule for artifact {other}"),
        };
        let outputs = self.runtime.execute(name, &inputs)?;
        self.invocations += 1;
        let out0 = &outputs[0];
        let mut words: Vec<u32> = match out0 {
            TensorValue::I32(v) => v.iter().map(|x| *x as u32).collect(),
            TensorValue::F32(v) => v.iter().map(|x| x.to_bits()).collect(),
        };
        words.truncate(spec.out_words.max(1));
        words.resize(spec.out_words, 0);
        Ok(words)
    }
}

#[cfg(feature = "pjrt")]
impl HwaCompute for PjrtCompute {
    fn compute_into(&mut self, spec: &HwaSpec, input: &[u32], out: &mut Vec<u32>) {
        if spec.artifact.is_some() {
            match self.run(spec, input) {
                Ok(words) => {
                    out.clear();
                    out.extend_from_slice(&words);
                    return;
                }
                Err(e) => {
                    // Surface once, then fall back (keeps sims running if
                    // an artifact is stale).
                    eprintln!("pjrt compute failed for {}: {e:#}", spec.name);
                }
            }
        }
        self.native.compute_into(spec, input, out);
    }
}

/// Pure-Rust golden compute (no artifacts needed).
#[derive(Debug, Default)]
pub struct NativeCompute {
    pub invocations: u64,
}

impl HwaCompute for NativeCompute {
    fn compute_into(&mut self, spec: &HwaSpec, input: &[u32], out: &mut Vec<u32>) {
        self.invocations += 1;
        let result: Vec<u32> = match spec.name {
            "izigzag" => {
                let mut block = [0i32; 64];
                for (i, w) in input.iter().take(64).enumerate() {
                    block[i] = *w as i32;
                }
                native::izigzag(&block).iter().map(|x| *x as u32).collect()
            }
            "iquantize" => {
                let mut block = [0i32; 64];
                for (i, w) in input.iter().take(64).enumerate() {
                    block[i] = *w as i32;
                }
                native::iquantize(&block, &DEFAULT_QTABLE)
                    .iter()
                    .map(|x| *x as u32)
                    .collect()
            }
            "idct" => {
                // Wire format: i32 dequantized coefficients in, f32 bits
                // out (shiftbound's input convention).
                let mut block = [0f32; 64];
                for (i, w) in input.iter().take(64).enumerate() {
                    block[i] = (*w as i32) as f32;
                }
                native::idct8x8(&block).iter().map(|x| x.to_bits()).collect()
            }
            "shiftbound" => {
                let mut block = [0f32; 64];
                for (i, w) in input.iter().take(64).enumerate() {
                    block[i] = f32::from_bits(*w);
                }
                native::shiftbound(&block)
                    .iter()
                    .map(|x| *x as u32)
                    .collect()
            }
            "dfadd" | "dfmul" | "dfdiv" => {
                let half = input.len() / 2;
                let op = match spec.name {
                    "dfadd" => native::dfadd as fn(f32, f32) -> f32,
                    "dfmul" => native::dfmul,
                    _ => native::dfdiv,
                };
                (0..half)
                    .map(|i| {
                        op(
                            f32::from_bits(input[i]),
                            f32::from_bits(input[half + i]),
                        )
                        .to_bits()
                    })
                    .collect()
            }
            "gsm" => {
                let frame: Vec<f32> =
                    input.iter().map(|w| f32::from_bits(*w)).collect();
                native::gsm_autocorr(&frame, spec.out_words.min(9))
                    .iter()
                    .map(|x| x.to_bits())
                    .collect()
            }
            // No functional model (aes/sha/prime/entropy): echo.
            _ => input.to_vec(),
        };
        let mut words = result;
        words.resize(spec.out_words, 0);
        out.clear();
        out.extend_from_slice(&words);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::hwa::spec_by_name;

    #[test]
    fn manifest_parses() {
        let m = parse_manifest(
            "izigzag | in int32:64x64 | out int32:64x64\n\
             dfadd | in float32:256,float32:256 | out float32:256\n",
        )
        .unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].name, "izigzag");
        assert_eq!(m[0].inputs[0].dims, vec![64, 64]);
        assert_eq!(m[1].inputs.len(), 2);
        assert_eq!(m[1].outputs[0].dtype, "float32");
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("nope").is_err());
        assert!(parse_manifest("a | b | c").is_err());
    }

    #[test]
    fn native_compute_izigzag_matches_golden() {
        let spec = spec_by_name("izigzag").unwrap();
        let mut nc = NativeCompute::default();
        let input: Vec<u32> = (0..64).collect();
        let out = nc.compute(&spec, &input);
        let mut block = [0i32; 64];
        for i in 0..64 {
            block[i] = i as i32;
        }
        let want: Vec<u32> =
            native::izigzag(&block).iter().map(|x| *x as u32).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn native_compute_resizes_to_out_words() {
        let spec = spec_by_name("dfadd").unwrap();
        let mut nc = NativeCompute::default();
        let out = nc.compute(&spec, &[1f32.to_bits(), 2f32.to_bits(),
                                      3f32.to_bits(), 4f32.to_bits()]);
        assert_eq!(out.len(), spec.out_words);
        assert_eq!(f32::from_bits(out[0]), 4.0); // 1 + 3
        assert_eq!(f32::from_bits(out[1]), 6.0); // 2 + 4
    }

    // PJRT tests that need built artifacts live in rust/tests/pjrt.rs so
    // they can be skipped gracefully when artifacts/ is absent.
}
