//! Native Rust golden implementations of the HWA computations — the
//! numerically independent check against the PJRT-executed AOT artifacts
//! (which are themselves validated against the jnp oracle by pytest).
//! Also the fallback compute when `artifacts/` has not been built.

/// ITU-T T.81 zigzag order: ZIGZAG[i] = raster index of scan position i.
pub const ZIGZAG: [usize; 64] = [
    0, 1, 8, 16, 9, 2, 3, 10, 17, 24, 32, 25, 18, 11, 4, 5, 12, 19, 26, 33,
    40, 48, 41, 34, 27, 20, 13, 6, 7, 14, 21, 28, 35, 42, 49, 56, 57, 50, 43,
    36, 29, 22, 15, 23, 30, 37, 44, 51, 58, 59, 52, 45, 38, 31, 39, 46, 53,
    60, 61, 54, 47, 55, 62, 63,
];

/// INV_ZIGZAG[r] = scan position holding raster index r.
pub fn inv_zigzag_table() -> [usize; 64] {
    let mut inv = [0usize; 64];
    for (i, &r) in ZIGZAG.iter().enumerate() {
        inv[r] = i;
    }
    inv
}

/// The default luminance quantization table (ITU-T T.81 Annex K.1) the
/// runtime bakes in — the analogue of the coefficient ROM in the paper's
/// Iquantize HWA.
pub const DEFAULT_QTABLE: [i32; 64] = [
    16, 11, 10, 16, 24, 40, 51, 61, 12, 12, 14, 19, 26, 58, 60, 55, 14, 13,
    16, 24, 40, 57, 69, 56, 14, 17, 22, 29, 51, 87, 80, 62, 18, 22, 37, 56,
    68, 109, 103, 77, 24, 35, 55, 64, 81, 104, 113, 92, 49, 64, 78, 87, 103,
    121, 120, 101, 72, 92, 95, 98, 112, 100, 103, 99,
];

/// Inverse zigzag over one 64-coefficient block (i32 lanes as u32 bits).
pub fn izigzag(scan: &[i32; 64]) -> [i32; 64] {
    let inv = inv_zigzag_table();
    let mut out = [0i32; 64];
    for r in 0..64 {
        out[r] = scan[inv[r]];
    }
    out
}

pub fn iquantize(coef: &[i32; 64], qtable: &[i32; 64]) -> [i32; 64] {
    let mut out = [0i32; 64];
    for i in 0..64 {
        out[i] = coef[i].wrapping_mul(qtable[i]);
    }
    out
}

/// 8x8 DCT-II basis matrix (same formula as ref.py's dct_basis_f32).
pub fn dct_basis() -> [[f32; 8]; 8] {
    let mut c = [[0f32; 8]; 8];
    for (k, row) in c.iter_mut().enumerate() {
        let scale = if k == 0 {
            (1.0f64 / 8.0).sqrt()
        } else {
            (2.0f64 / 8.0).sqrt()
        };
        for (n, v) in row.iter_mut().enumerate() {
            let ang =
                (2.0 * n as f64 + 1.0) * k as f64 * std::f64::consts::PI / 16.0;
            *v = (scale * ang.cos()) as f32;
        }
    }
    c
}

/// 2-D IDCT of one 8x8 block: C^T X C.
pub fn idct8x8(block: &[f32; 64]) -> [f32; 64] {
    let c = dct_basis();
    // y1 = X @ C  (x row-major 8x8)
    let mut y1 = [0f32; 64];
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = 0f32;
            for k in 0..8 {
                acc += block[i * 8 + k] * c[k][j];
            }
            y1[i * 8 + j] = acc;
        }
    }
    // y = C^T @ y1  => y[i][j] = sum_k C[k][i] * y1[k][j]
    let mut out = [0f32; 64];
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = 0f32;
            for k in 0..8 {
                acc += c[k][i] * y1[k * 8 + j];
            }
            out[i * 8 + j] = acc;
        }
    }
    out
}

/// Level shift + clamp to [0, 255].
pub fn shiftbound(pixels: &[f32; 64]) -> [i32; 64] {
    let mut out = [0i32; 64];
    for i in 0..64 {
        let v = pixels[i].round_ties_even() + 128.0;
        out[i] = v.clamp(0.0, 255.0) as i32;
    }
    out
}

/// Full JPEG decode chain on one block.
pub fn jpeg_chain(scan: &[i32; 64], qtable: &[i32; 64]) -> [i32; 64] {
    let deq = iquantize(&izigzag(scan), qtable);
    let mut f = [0f32; 64];
    for i in 0..64 {
        f[i] = deq[i] as f32;
    }
    shiftbound(&idct8x8(&f))
}

/// Forward path (for building realistic workloads): DCT + quantize +
/// zigzag of a pixel block.
pub fn jpeg_encode(pixels: &[f32; 64], qtable: &[i32; 64]) -> [i32; 64] {
    let c = dct_basis();
    let mut shifted = [0f32; 64];
    for i in 0..64 {
        shifted[i] = pixels[i] - 128.0;
    }
    // F = C X C^T
    let mut y1 = [0f32; 64];
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = 0f32;
            for k in 0..8 {
                acc += c[i][k] * shifted[k * 8 + j];
            }
            y1[i * 8 + j] = acc;
        }
    }
    let mut freq = [0f32; 64];
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = 0f32;
            for k in 0..8 {
                acc += y1[i * 8 + k] * c[j][k];
            }
            freq[i * 8 + j] = acc;
        }
    }
    let mut quant = [0i32; 64];
    for i in 0..64 {
        quant[i] = (freq[i] / qtable[i] as f32).round() as i32;
    }
    // natural -> scan order
    let mut scan = [0i32; 64];
    for (i, &r) in ZIGZAG.iter().enumerate() {
        scan[i] = quant[r];
    }
    scan
}

pub fn dfadd(a: f32, b: f32) -> f32 {
    a + b
}

pub fn dfmul(a: f32, b: f32) -> f32 {
    a * b
}

pub fn dfdiv(a: f32, b: f32) -> f32 {
    if b == 0.0 {
        a
    } else {
        a / b
    }
}

/// GSM autocorrelation, lags 0..=8 over a frame.
pub fn gsm_autocorr(frame: &[f32], lags: usize) -> Vec<f32> {
    (0..lags)
        .map(|k| {
            frame[..frame.len() - k]
                .iter()
                .zip(&frame[k..])
                .map(|(a, b)| a * b)
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_is_permutation() {
        let mut seen = [false; 64];
        for &z in &ZIGZAG {
            assert!(!seen[z]);
            seen[z] = true;
        }
    }

    #[test]
    fn izigzag_inverts_encode_order() {
        // natural -> scan (encode) -> natural (izigzag) is identity.
        let natural: [i32; 64] = std::array::from_fn(|i| i as i32);
        let mut scan = [0i32; 64];
        for (i, &r) in ZIGZAG.iter().enumerate() {
            scan[i] = natural[r];
        }
        assert_eq!(izigzag(&scan), natural);
    }

    #[test]
    fn basis_is_orthonormal() {
        let c = dct_basis();
        for i in 0..8 {
            for j in 0..8 {
                let dot: f32 = (0..8).map(|k| c[i][k] * c[j][k]).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-5, "({i},{j}) {dot}");
            }
        }
    }

    #[test]
    fn dc_only_block_decodes_flat() {
        let mut f = [0f32; 64];
        f[0] = 800.0;
        let out = idct8x8(&f);
        for v in out {
            assert!((v - 100.0).abs() < 1e-3);
        }
    }

    #[test]
    fn roundtrip_within_quantization_error() {
        let q = DEFAULT_QTABLE;
        let mut pixels = [0f32; 64];
        let mut x = 7u32;
        for p in pixels.iter_mut() {
            x = x.wrapping_mul(1664525).wrapping_add(1013904223);
            *p = (x >> 24) as f32;
        }
        let scan = jpeg_encode(&pixels, &q);
        let decoded = jpeg_chain(&scan, &q);
        let mean_err: f32 = pixels
            .iter()
            .zip(&decoded)
            .map(|(p, d)| (p - *d as f32).abs())
            .sum::<f32>()
            / 64.0;
        assert!(mean_err < 40.0, "mean_err={mean_err}");
    }

    #[test]
    fn shiftbound_saturates() {
        let mut px = [0f32; 64];
        px[0] = 1e6;
        px[1] = -1e6;
        px[2] = 0.0;
        let out = shiftbound(&px);
        assert_eq!(out[0], 255);
        assert_eq!(out[1], 0);
        assert_eq!(out[2], 128);
    }

    #[test]
    fn gsm_lag0_is_energy() {
        let frame: Vec<f32> = (0..160).map(|i| (i % 7) as f32).collect();
        let ac = gsm_autocorr(&frame, 9);
        let energy: f32 = frame.iter().map(|x| x * x).sum();
        assert!((ac[0] - energy).abs() < 1e-3);
        assert_eq!(ac.len(), 9);
    }

    #[test]
    fn dfdiv_guards_zero() {
        assert_eq!(dfdiv(4.0, 2.0), 2.0);
        assert_eq!(dfdiv(4.0, 0.0), 4.0);
    }
}
