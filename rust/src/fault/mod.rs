//! Seed-deterministic fault injection and the recovery-policy types
//! (ISSUE 9).
//!
//! The injection layer is a [`FaultSpec`] (what can go wrong, at what
//! rate) lowered by [`crate::sim::system::System::set_faults`] into
//! per-site state machines, each drawing from its **own** [`Pcg32`]
//! stream so runs stay bit-identical per seed and fault classes never
//! perturb each other's draws:
//!
//! * [`LinkFaults`] — NoC link faults at ejection links (a delivered
//!   flit is dropped or a data bit flips), hooked into
//!   `noc::mesh::Mesh::step_impl` phase B.
//! * [`ChannelFaults`] — HWA faults drawn per task (a task hangs until
//!   the channel watchdog kills it, or its result packet is corrupted),
//!   hooked into `fpga::channel::Channel::step_hwa`.
//! * [`UpsetFaults`] — SEU-style configuration upsets drawn per landed
//!   reconfiguration swap (the slot comes up dead until the scrubber
//!   re-programs it), hooked into `sim::system::System::finish_swaps`.
//!
//! `FaultSpec::None` installs nothing: no RNG stream is created and no
//! hook runs, so fault-free artifacts are byte-identical to pre-fault
//! builds (pinned by `rust/tests/sweep.rs`).
//!
//! Recovery (CRC/NACK at the packet receivers, source-side timeout →
//! retry → failover state machines, the slot scrubber) lives at the
//! respective sites; this module only defines the shared policy and
//! counter types. Nothing here panics: every fault path maps to a typed
//! counter or a typed [`crate::accel::AccelError`] (audited by the grep
//! test in `rust/tests/faults.rs`).

use crate::clock::Ps;
use crate::flit::{Flit, FlitKind};
use crate::util::rng::Pcg32;

/// Pcg32 stream ids. Disjoint from every workload stream in use
/// (open-loop sources use `id + 1`, serving pick streams `0x50_0000 +
/// id`, tenant streams `0x5e_0000 + id`).
const LINK_STREAM: u64 = 0xFA_1001;
const HWA_STREAM_BASE: u64 = 0xFA_2000;
const UPSET_STREAM: u64 = 0xFA_3001;

/// What to inject, and how often. Probabilities are per *opportunity*:
/// per delivered flit for link faults, per executed task for HWA
/// faults, per landed reconfiguration swap for upsets.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum FaultSpec {
    /// No injection at all (the default): byte-identical artifacts.
    #[default]
    None,
    /// NoC link faults only (drop and bit-flip, each at rate `p`).
    Link(f64),
    /// HWA faults only (hang and corrupt, each at rate `p`).
    Hwa(f64),
    /// Reconfiguration upsets only (dead slot at rate `p` per swap).
    Upset(f64),
    /// All three classes at rate `p`.
    Mixed(f64),
}

impl FaultSpec {
    /// Parse `"none" | "link:<p>" | "hwa:<p>" | "upset:<p>" |
    /// "mixed:<p>"` (the `fault.spec` sweep key).
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s == "none" {
            return Ok(FaultSpec::None);
        }
        let (kind, rate) = s
            .split_once(':')
            .ok_or_else(|| format!("bad fault spec {s:?} (want none | link:<p> | hwa:<p> | upset:<p> | mixed:<p>)"))?;
        let p: f64 = rate
            .parse()
            .map_err(|_| format!("bad fault probability {rate:?}"))?;
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("fault probability {p} outside [0, 1]"));
        }
        match kind {
            "link" => Ok(FaultSpec::Link(p)),
            "hwa" => Ok(FaultSpec::Hwa(p)),
            "upset" => Ok(FaultSpec::Upset(p)),
            "mixed" => Ok(FaultSpec::Mixed(p)),
            _ => Err(format!("unknown fault class {kind:?}")),
        }
    }

    /// Canonical name, the inverse of [`FaultSpec::parse`].
    pub fn name(&self) -> String {
        match self {
            FaultSpec::None => "none".to_string(),
            FaultSpec::Link(p) => format!("link:{p}"),
            FaultSpec::Hwa(p) => format!("hwa:{p}"),
            FaultSpec::Upset(p) => format!("upset:{p}"),
            FaultSpec::Mixed(p) => format!("mixed:{p}"),
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self, FaultSpec::None)
    }

    /// Per-delivered-flit drop probability.
    pub fn link_drop_p(&self) -> f64 {
        match self {
            FaultSpec::Link(p) | FaultSpec::Mixed(p) => *p,
            _ => 0.0,
        }
    }

    /// Per-delivered-flit data-bit-flip probability.
    pub fn link_flip_p(&self) -> f64 {
        self.link_drop_p()
    }

    /// Per-task hang probability.
    pub fn hwa_hang_p(&self) -> f64 {
        match self {
            FaultSpec::Hwa(p) | FaultSpec::Mixed(p) => *p,
            _ => 0.0,
        }
    }

    /// Per-task result-corruption probability.
    pub fn hwa_corrupt_p(&self) -> f64 {
        self.hwa_hang_p()
    }

    /// Per-landed-swap dead-slot probability.
    pub fn upset_p(&self) -> f64 {
        match self {
            FaultSpec::Upset(p) | FaultSpec::Mixed(p) => *p,
            _ => 0.0,
        }
    }
}

/// What the system does about detected faults (the `fault.recovery`
/// sweep key). Injection and recovery are orthogonal: `Recovery::None`
/// under faults shows the damage, `RetryFailover` bounds it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// Detect and count only: timed-out work becomes a typed permanent
    /// failure (nothing wedges, nothing is re-issued).
    #[default]
    None,
    /// Bounded re-submission to the same accelerator with exponential
    /// backoff, then permanent failure.
    Retry,
    /// [`RecoveryPolicy::Retry`], then failover to an equivalent
    /// accelerator (same spec) on another node before giving up.
    RetryFailover,
}

impl RecoveryPolicy {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim() {
            "none" => Ok(RecoveryPolicy::None),
            "retry" => Ok(RecoveryPolicy::Retry),
            "retry_failover" => Ok(RecoveryPolicy::RetryFailover),
            other => Err(format!(
                "unknown recovery policy {other:?} (want none | retry | retry_failover)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RecoveryPolicy::None => "none",
            RecoveryPolicy::Retry => "retry",
            RecoveryPolicy::RetryFailover => "retry_failover",
        }
    }

    /// Re-issue to the same target after a timeout?
    pub fn retries(&self) -> bool {
        !matches!(self, RecoveryPolicy::None)
    }

    /// Re-issue to an equivalent target after retries are exhausted?
    pub fn fails_over(&self) -> bool {
        matches!(self, RecoveryPolicy::RetryFailover)
    }
}

/// Aggregated fault counters (the `RunStats.fault_*` fields; additive
/// JSON only when nonzero so legacy BENCH bytes stay unchanged).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultStats {
    /// Faults the injection layer actually applied.
    pub injected: u64,
    /// Faults a receiver noticed (CRC mismatch, watchdog kill, timeout
    /// sweep, scrubber detection, stuck-TB reclaim).
    pub detected: u64,
    /// Re-submissions to the same target (including NACK retransmits).
    pub retried: u64,
    /// Re-submissions to an equivalent target on another node.
    pub failed_over: u64,
    /// Work given up on after the policy's budget was exhausted.
    pub permanently_failed: u64,
}

impl FaultStats {
    /// Window delta against an earlier snapshot.
    pub fn since(&self, earlier: &FaultStats) -> FaultStats {
        FaultStats {
            injected: self.injected - earlier.injected,
            detected: self.detected - earlier.detected,
            retried: self.retried - earlier.retried,
            failed_over: self.failed_over - earlier.failed_over,
            permanently_failed: self.permanently_failed
                - earlier.permanently_failed,
        }
    }

    pub fn any(&self) -> bool {
        self.injected != 0
            || self.detected != 0
            || self.retried != 0
            || self.failed_over != 0
            || self.permanently_failed != 0
    }

    pub fn absorb(&mut self, other: &FaultStats) {
        self.injected += other.injected;
        self.detected += other.detected;
        self.retried += other.retried;
        self.failed_over += other.failed_over;
        self.permanently_failed += other.permanently_failed;
    }
}

/// The lowered configuration the [`crate::sim::system::System`] holds
/// and distributes to sources/channels as they are created.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    pub spec: FaultSpec,
    pub recovery: RecoveryPolicy,
    /// Source/watchdog deadline: work invisible for this long is
    /// declared lost (first retry fires here; backoff doubles it).
    pub timeout_ps: u64,
    /// Scrubber period: how often dead slots are re-programmed.
    pub scrub_ps: u64,
    pub seed: u64,
}

/// NoC link-fault state, owned by the mesh (installed into
/// `noc::mesh::Mesh::fault`). Faults apply at a flit's final
/// delivery onto its ejection link — the congested interface links the
/// paper models — and only at nodes enabled in `mask` (MMU tiles are
/// exempt: memory-side payloads carry no end-to-end verifier yet).
///
/// Only `Single` command flits and `Body` data flits are droppable, and
/// only `Body` flits are flippable: wormhole `Head`/`Tail` framing
/// always survives, so a fault never wedges a packet receiver — it
/// surfaces as a CRC mismatch or a missing completion, both of which
/// the recovery layer converts into retries or typed failures.
#[derive(Debug)]
pub struct LinkFaults {
    drop_p: f64,
    flip_p: f64,
    rng: Pcg32,
    /// Per-node: do ejection-link faults apply here?
    mask: Vec<bool>,
    pub drops: u64,
    pub flips: u64,
}

/// Outcome of one delivery draw.
enum LinkFault {
    Pass,
    Drop,
    Flip,
}

impl LinkFaults {
    pub fn new(seed: u64, drop_p: f64, flip_p: f64, mask: Vec<bool>) -> Self {
        Self {
            drop_p,
            flip_p,
            rng: Pcg32::new(seed, LINK_STREAM),
            mask,
            drops: 0,
            flips: 0,
        }
    }

    /// Apply link faults to a flit about to be delivered at `node`.
    /// Returns `false` when the flit was dropped (the caller must not
    /// deliver it, but must still free its buffer credit). Draws are
    /// taken only for fault-eligible kinds at masked nodes, so the
    /// stream is a pure function of the delivered-flit sequence.
    pub fn on_deliver(&mut self, node: usize, flit: &mut Flit) -> bool {
        if !self.mask.get(node).copied().unwrap_or(false) {
            return true;
        }
        match self.draw(flit.kind()) {
            LinkFault::Pass => true,
            LinkFault::Drop => {
                self.drops += 1;
                false
            }
            LinkFault::Flip => {
                // Flip one of the 128 data-payload bits; the packet's
                // CRC16 (stamped at build time) no longer matches.
                let bit = self.rng.below(128);
                let word = (bit / 64) as usize;
                flit.raw.0[word] ^= 1u64 << (bit % 64);
                self.flips += 1;
                true
            }
        }
    }

    fn draw(&mut self, kind: FlitKind) -> LinkFault {
        match kind {
            FlitKind::Head | FlitKind::Tail => LinkFault::Pass,
            FlitKind::Single => {
                if self.rng.chance(self.drop_p) {
                    LinkFault::Drop
                } else {
                    LinkFault::Pass
                }
            }
            FlitKind::Body => {
                let r = self.rng.f64();
                if r < self.drop_p {
                    LinkFault::Drop
                } else if r < self.drop_p + self.flip_p {
                    LinkFault::Flip
                } else {
                    LinkFault::Pass
                }
            }
        }
    }
}

/// What an HWA fault draw decided for one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HwaFault {
    /// The task never finishes; the channel watchdog kills it at
    /// `exec_start + watchdog_ps`.
    Hang,
    /// The task finishes but a bit of its result packet flips after the
    /// CRC was stamped, so the requester's check fails.
    Corrupt,
}

/// Per-channel HWA fault state (each channel gets its own stream,
/// `HWA_STREAM_BASE + global channel index`, so slot swaps and
/// per-channel event order never perturb other channels' draws).
#[derive(Debug)]
pub struct ChannelFaults {
    hang_p: f64,
    corrupt_p: f64,
    rng: Pcg32,
    /// Watchdog deadline for hung tasks and stuck (granted-but-never-
    /// filled) task buffers, in ps.
    pub watchdog_ps: u64,
    /// Set while a configuration upset holds the slot dead (every task
    /// hangs, no RNG draw consumed); cleared when the scrubber's
    /// re-program lands. The upset itself was counted by
    /// [`UpsetFaults`], so dead-slot hangs don't inflate `hangs`.
    pub dead: bool,
    pub hangs: u64,
    pub corrupts: u64,
    /// Hung tasks the watchdog killed (each is also a detection).
    pub watchdog_kills: u64,
    /// Payload fills rejected on a CRC mismatch (NACKed to the sender).
    pub crc_rejects: u64,
    /// Granted/filling TBs reclaimed after their payload never arrived.
    pub tb_reclaims: u64,
}

impl ChannelFaults {
    pub fn new(
        seed: u64,
        global_channel: u64,
        hang_p: f64,
        corrupt_p: f64,
        watchdog_ps: u64,
    ) -> Self {
        Self {
            hang_p,
            corrupt_p,
            rng: Pcg32::new(seed, HWA_STREAM_BASE + global_channel),
            watchdog_ps,
            dead: false,
            hangs: 0,
            corrupts: 0,
            watchdog_kills: 0,
            crc_rejects: 0,
            tb_reclaims: 0,
        }
    }

    /// One draw per task entering execution. A dead (upset) slot hangs
    /// every task without consuming a draw, so scrubbing restores the
    /// exact fault sequence a never-upset run would have seen.
    pub fn draw_task(&mut self) -> Option<HwaFault> {
        if self.dead {
            return Some(HwaFault::Hang);
        }
        let r = self.rng.f64();
        if r < self.hang_p {
            self.hangs += 1;
            Some(HwaFault::Hang)
        } else if r < self.hang_p + self.corrupt_p {
            self.corrupts += 1;
            Some(HwaFault::Corrupt)
        } else {
            None
        }
    }

    /// Which data bit of a corrupted result packet flips.
    pub fn corrupt_bit(&mut self) -> u32 {
        self.rng.below(128)
    }

    /// Counters in [`FaultStats`] form (injected = hangs + corrupts).
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            injected: self.hangs + self.corrupts,
            detected: self.watchdog_kills + self.crc_rejects + self.tb_reclaims,
            ..FaultStats::default()
        }
    }
}

/// A reconfigured slot that came up dead and awaits scrubbing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeadSlot {
    pub fabric: usize,
    pub channel: usize,
}

/// Configuration-upset state, owned by the system: upsets are drawn per
/// landed reconfiguration swap; a periodic scrubber re-programs dead
/// slots through the ordinary reconfig controller FSM.
#[derive(Debug)]
pub struct UpsetFaults {
    p: f64,
    rng: Pcg32,
    pub scrub_ps: u64,
    /// Next scrub tick (folded into the idle-skip horizon like the
    /// reconfig engine's epoch clock).
    pub next_scrub: Ps,
    pub dead: Vec<DeadSlot>,
    pub upsets: u64,
    /// Dead slots the scrubber found and re-programmed.
    pub scrubs: u64,
}

impl UpsetFaults {
    pub fn new(seed: u64, p: f64, scrub_ps: u64) -> Self {
        Self {
            p,
            rng: Pcg32::new(seed, UPSET_STREAM),
            scrub_ps,
            next_scrub: scrub_ps,
            dead: Vec::new(),
            upsets: 0,
            scrubs: 0,
        }
    }

    /// Draw on a landed swap: does this slot come up dead?
    pub fn draw_on_land(&mut self, fabric: usize, channel: usize) -> bool {
        if self.rng.chance(self.p) {
            self.upsets += 1;
            self.dead.push(DeadSlot { fabric, channel });
            true
        } else {
            false
        }
    }

    pub fn is_dead(&self, fabric: usize, channel: usize) -> bool {
        self.dead.contains(&DeadSlot { fabric, channel })
    }

    /// The scrubber repaired (or at least re-queued) this slot.
    pub fn mark_repaired(&mut self, fabric: usize, channel: usize) {
        if let Some(i) = self
            .dead
            .iter()
            .position(|d| *d == DeadSlot { fabric, channel })
        {
            self.dead.swap_remove(i);
            self.scrubs += 1;
        }
    }

    pub fn stats(&self) -> FaultStats {
        FaultStats {
            injected: self.upsets,
            detected: self.scrubs,
            ..FaultStats::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flit::{HeadFields, PacketBuilder};

    #[test]
    fn fault_spec_parse_name_round_trips() {
        for s in ["none", "link:0.01", "hwa:0.005", "upset:0.1", "mixed:0.002"] {
            let spec = FaultSpec::parse(s).unwrap();
            assert_eq!(spec.name(), s);
            assert_eq!(FaultSpec::parse(&spec.name()).unwrap(), spec);
        }
        assert!(FaultSpec::parse("link").is_err());
        assert!(FaultSpec::parse("link:nan?").is_err());
        assert!(FaultSpec::parse("link:1.5").is_err());
        assert!(FaultSpec::parse("gamma:0.1").is_err());
    }

    #[test]
    fn none_spec_has_zero_rates_everywhere() {
        let none = FaultSpec::None;
        assert!(none.is_none());
        assert_eq!(none.link_drop_p(), 0.0);
        assert_eq!(none.link_flip_p(), 0.0);
        assert_eq!(none.hwa_hang_p(), 0.0);
        assert_eq!(none.hwa_corrupt_p(), 0.0);
        assert_eq!(none.upset_p(), 0.0);
    }

    #[test]
    fn mixed_spec_arms_every_class() {
        let m = FaultSpec::Mixed(0.25);
        assert_eq!(m.link_drop_p(), 0.25);
        assert_eq!(m.hwa_hang_p(), 0.25);
        assert_eq!(m.upset_p(), 0.25);
    }

    #[test]
    fn recovery_policy_parse_name_round_trips() {
        for s in ["none", "retry", "retry_failover"] {
            let p = RecoveryPolicy::parse(s).unwrap();
            assert_eq!(p.name(), s);
        }
        assert!(RecoveryPolicy::parse("panic").is_err());
        assert!(!RecoveryPolicy::None.retries());
        assert!(RecoveryPolicy::Retry.retries());
        assert!(!RecoveryPolicy::Retry.fails_over());
        assert!(RecoveryPolicy::RetryFailover.fails_over());
    }

    #[test]
    fn fault_stats_delta_and_absorb() {
        let mut a = FaultStats {
            injected: 10,
            detected: 7,
            retried: 5,
            failed_over: 2,
            permanently_failed: 1,
        };
        let b = FaultStats {
            injected: 4,
            detected: 3,
            retried: 2,
            failed_over: 1,
            permanently_failed: 0,
        };
        let d = a.since(&b);
        assert_eq!(d.injected, 6);
        assert_eq!(d.permanently_failed, 1);
        assert!(d.any());
        assert!(!FaultStats::default().any());
        a.absorb(&b);
        assert_eq!(a.injected, 14);
        assert_eq!(a.failed_over, 3);
    }

    fn body_and_head() -> (Flit, Flit) {
        let mut b = PacketBuilder::new(1);
        let p = b.payload(HeadFields::default(), &[1, 2, 3, 4, 5]);
        (p.flits[1], p.flits[0])
    }

    #[test]
    fn link_faults_are_deterministic_per_seed() {
        let mask = vec![true; 4];
        let mut a = LinkFaults::new(7, 0.3, 0.3, mask.clone());
        let mut b = LinkFaults::new(7, 0.3, 0.3, mask);
        let (body, _) = body_and_head();
        for node in (0..4).cycle().take(500) {
            let (mut fa, mut fb) = (body, body);
            assert_eq!(a.on_deliver(node, &mut fa), b.on_deliver(node, &mut fb));
            assert_eq!(fa, fb, "flips target the same bit");
        }
        assert_eq!(a.drops, b.drops);
        assert_eq!(a.flips, b.flips);
        assert!(a.drops > 0 && a.flips > 0, "both classes exercised");
    }

    #[test]
    fn link_faults_never_touch_head_or_tail_framing() {
        let mut lf = LinkFaults::new(3, 1.0, 1.0, vec![true]);
        let (_, head) = body_and_head();
        let mut h = head;
        // Even at p = 1, heads pass untouched (no draw consumed).
        assert!(lf.on_deliver(0, &mut h));
        assert_eq!(h, head);
        assert_eq!(lf.drops + lf.flips, 0);
        // Unmasked nodes are exempt.
        let (mut body, _) = body_and_head();
        assert!(lf.on_deliver(5, &mut body));
        assert_eq!(lf.drops + lf.flips, 0);
    }

    #[test]
    fn channel_faults_partition_hang_and_corrupt() {
        let mut cf = ChannelFaults::new(11, 0, 0.5, 0.5, 1_000);
        let mut hangs = 0;
        let mut corrupts = 0;
        for _ in 0..200 {
            match cf.draw_task() {
                Some(HwaFault::Hang) => hangs += 1,
                Some(HwaFault::Corrupt) => corrupts += 1,
                None => {}
            }
        }
        // p = 0.5 + 0.5 covers the unit interval: every task faults.
        assert_eq!(hangs + corrupts, 200);
        assert!(hangs > 0 && corrupts > 0);
        assert_eq!(cf.hangs, hangs);
        assert_eq!(cf.corrupts, corrupts);
        assert_eq!(cf.stats().injected, 200);
        assert!(cf.corrupt_bit() < 128);
    }

    #[test]
    fn dead_slot_hangs_every_task_without_consuming_draws() {
        let mut cf = ChannelFaults::new(1, 0, 0.0, 0.0, 1_000);
        assert_eq!(cf.draw_task(), None);
        cf.dead = true;
        assert_eq!(cf.draw_task(), Some(HwaFault::Hang));
        assert_eq!(cf.hangs, 0, "the upset was already counted");
        cf.dead = false;
        assert_eq!(cf.draw_task(), None);
    }

    #[test]
    fn upset_faults_track_dead_slots() {
        let mut uf = UpsetFaults::new(5, 1.0, 10_000);
        assert!(uf.draw_on_land(0, 2));
        assert!(uf.is_dead(0, 2));
        assert!(!uf.is_dead(0, 1));
        assert_eq!(uf.upsets, 1);
        uf.mark_repaired(0, 2);
        assert!(!uf.is_dead(0, 2));
        assert_eq!(uf.scrubs, 1);
        // Repairing a live slot is a no-op, not a panic.
        uf.mark_repaired(1, 1);
        assert_eq!(uf.scrubs, 1);
        let mut never = UpsetFaults::new(5, 0.0, 10_000);
        for c in 0..50 {
            assert!(!never.draw_on_land(0, c));
        }
        assert!(never.dead.is_empty());
    }
}
