//! Full-system assembly (paper Fig. 1): CMP cores + interconnect + FPGA
//! fabrics + MMU tiles, driven by a multi-domain clock and wired from a
//! declarative [`Floorplan`]. Three prototypes are expressible
//! (§6.7/§6.8): NoC + distributed buffers (the proposal), AXI4 bus +
//! distributed buffers, and NoC + shared FPGA cache — and the NoC
//! prototypes scale to **multiple FPGA interface tiles** (each its own
//! fabric, inventory and clock domains) and **multiple MMU tiles**
//! (nearest or hashed per-processor assignment), the scenarios the
//! paper's scalability argument is about.

use crate::baseline::axi::AxiBus;
use crate::baseline::shared_cache::CacheFpga;
use crate::clock::{Activity, ClockDomain, DomainId, MultiClock, Ps};
use crate::cmp::core::{Processor, Segment};
use crate::fault::{
    ChannelFaults, FaultConfig, FaultStats, LinkFaults, UpsetFaults,
};
use crate::flit::{ArenaStats, Flit, PacketArena};
use crate::fpga::fabric::{Fpga, FpgaConfig};
use crate::fpga::hwa::{HwaCompute, HwaSpec};
use crate::mem::mmu::Mmu;
use crate::noc::mesh::{Mesh, MeshConfig};
use crate::reconfig::{
    FabricView, LatencyModel, ProvisionPolicy, Provisioner, SlotState,
    SlotView,
};
use crate::workload::openloop::{OpenLoopSource, OpenLoopTarget};
use crate::workload::serving::{ServingSource, ServingTarget, TenantSpec};

use std::collections::BTreeMap;

use super::floorplan::{Floorplan, MmuAssign, TopologyError};

/// Interconnect selection (Fig. 13/14's three prototypes use Noc or Axi).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetKind {
    Noc,
    Axi,
}

/// FPGA-side architecture of one fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricKind {
    /// The paper's proposal: distributed TB/POB/CB buffers.
    Buffered,
    /// §6.8 baseline: shared system cache, given capacity in bytes.
    SharedCache { cache_bytes: u32 },
}

/// Everything that configures ONE FPGA interface tile: its architecture,
/// buffer/arbitration shape, clocking, HWA inventory and chain groups.
/// A [`SystemConfig`] carries one `FabricSpec` per `F<k>` floorplan tile.
#[derive(Debug, Clone)]
pub struct FabricSpec {
    pub kind: FabricKind,
    pub n_tbs: usize,
    pub pr_group: usize,
    pub ps_group: usize,
    pub iface_mhz: f64,
    pub specs: Vec<HwaSpec>,
    /// Chain groups over this fabric's channel indices (chains never
    /// cross fabrics — the driver rejects that with a typed error).
    pub chain_groups: Vec<Vec<usize>>,
    /// Channel indices sitting in partial-reconfiguration regions: only
    /// these slots may be swapped at runtime ([`crate::reconfig`]).
    /// Empty (the default) freezes the inventory, matching every
    /// pre-reconfig configuration bit-for-bit.
    pub reconfigurable: Vec<usize>,
}

impl FabricSpec {
    /// Paper defaults: buffered fabric, 2 TBs, PR4-PS4, 300 MHz.
    pub fn paper(specs: Vec<HwaSpec>) -> Self {
        Self {
            kind: FabricKind::Buffered,
            n_tbs: 2,
            pr_group: 4,
            ps_group: 4,
            iface_mhz: 300.0,
            specs,
            chain_groups: Vec::new(),
            reconfigurable: Vec::new(),
        }
    }
}

/// The system description: a floorplan plus one [`FabricSpec`] per
/// fabric tile. `SystemConfig::paper` is the compatibility constructor —
/// it lowers to the exact single-FPGA floorplan (FPGA last node, MMU
/// beside it) every pre-floorplan experiment assumed, so existing
/// configs produce bit-identical results.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub floorplan: Floorplan,
    pub net: NetKind,
    /// One spec per `F<k>` tile, indexed by fabric id.
    pub fabrics: Vec<FabricSpec>,
    /// Processor → MMU tile assignment policy (multi-MMU plans).
    pub mmu_assign: MmuAssign,
    /// The FPGA part every fabric's inventory is budgeted against
    /// (`system.device`; defaults to the paper's xc7vx690t).
    pub device: crate::synth::Device,
}

impl SystemConfig {
    /// Paper defaults: 3x3 mesh, NoC, one buffered fabric with the given
    /// inventory at the legacy placement.
    pub fn paper(specs: Vec<HwaSpec>) -> Self {
        Self::single(MeshConfig::default(), FabricSpec::paper(specs))
    }

    /// One fabric on the legacy single-FPGA floorplan over `mesh`.
    pub fn single(mesh: MeshConfig, fabric: FabricSpec) -> Self {
        Self {
            floorplan: Floorplan::single_fpga(mesh),
            net: NetKind::Noc,
            fabrics: vec![fabric],
            mmu_assign: MmuAssign::Nearest,
            device: crate::synth::Device::default(),
        }
    }

    /// A floorplanned system: `fabrics[k]` configures tile `F<k>`.
    pub fn floorplanned(plan: Floorplan, fabrics: Vec<FabricSpec>) -> Self {
        Self {
            floorplan: plan,
            net: NetKind::Noc,
            fabrics,
            mmu_assign: MmuAssign::Nearest,
            device: crate::synth::Device::default(),
        }
    }

    /// Re-lower onto the legacy single-FPGA layout over a `w`x`h` mesh
    /// (convenience for tests/benches that only vary mesh size).
    pub fn set_mesh(&mut self, width: u8, height: u8) {
        self.floorplan = Floorplan::single_fpga(MeshConfig {
            width,
            height,
            ..self.floorplan.mesh.clone()
        });
    }

    pub fn mesh(&self) -> &MeshConfig {
        &self.floorplan.mesh
    }

    pub fn n_nodes(&self) -> usize {
        self.floorplan.n_nodes()
    }

    /// The primary (fabric 0) spec — what single-fabric callers mutate.
    pub fn primary(&self) -> &FabricSpec {
        &self.fabrics[0]
    }

    pub fn primary_mut(&mut self) -> &mut FabricSpec {
        &mut self.fabrics[0]
    }

    /// Full construction-time validation: the floorplan itself, the
    /// fabric-spec count, chain-group ranges, and the AXI prototype's
    /// single-endpoint constraint.
    pub fn validate(&self) -> Result<(), TopologyError> {
        self.floorplan.validate()?;
        let plan_fabrics = self.floorplan.n_fabrics();
        if self.fabrics.len() != plan_fabrics {
            return Err(TopologyError::FabricCountMismatch {
                plan: plan_fabrics,
                specs: self.fabrics.len(),
            });
        }
        if self.net == NetKind::Axi && plan_fabrics != 1 {
            return Err(TopologyError::AxiMultiFabric {
                fabrics: plan_fabrics,
            });
        }
        for (f, spec) in self.fabrics.iter().enumerate() {
            for group in &spec.chain_groups {
                for member in group {
                    if *member >= spec.specs.len() {
                        return Err(TopologyError::ChainGroupOutOfRange {
                            fabric: f,
                            member: *member,
                        });
                    }
                }
            }
            for slot in &spec.reconfigurable {
                if *slot >= spec.specs.len() {
                    return Err(TopologyError::ReconfigSlotOutOfRange {
                        fabric: f,
                        slot: *slot,
                    });
                }
            }
            // Inventory + interface must fit the device (the synth
            // resource model was previously write-only; now it gates
            // construction and provisioner targets alike).
            if spec.kind == FabricKind::Buffered {
                let cost = crate::synth::resource::inventory_cost(
                    spec.pr_group,
                    spec.ps_group,
                    &spec.specs,
                    !spec.chain_groups.is_empty(),
                );
                if self.device.exceeds(&cost) {
                    return Err(TopologyError::ResourceBudget {
                        fabric: f,
                        luts: cost.lut,
                        brams: cost.bram,
                        device: self.device,
                    });
                }
            }
        }
        Ok(())
    }
}

pub enum Net {
    Noc(Mesh),
    Axi(AxiBus),
}

impl Net {
    fn can_inject(&self, node: usize) -> bool {
        match self {
            Net::Noc(m) => m.can_inject(node),
            Net::Axi(b) => b.can_inject(node),
        }
    }

    fn try_inject(&mut self, node: usize, flit: Flit) -> bool {
        match self {
            Net::Noc(m) => m.try_inject(node, flit),
            Net::Axi(b) => b.try_inject(node, flit),
        }
    }

    fn eject_pop(&mut self, node: usize) -> Option<Flit> {
        match self {
            Net::Noc(m) => m.eject_pop(node),
            Net::Axi(b) => b.eject_pop(node),
        }
    }

    fn eject_peek_some(&self, node: usize) -> bool {
        match self {
            Net::Noc(m) => m.eject_peek(node).is_some(),
            Net::Axi(b) => b.eject_len(node) > 0,
        }
    }

    fn step(&mut self) {
        match self {
            Net::Noc(m) => m.step(),
            Net::Axi(b) => b.step(),
        }
    }

    fn idle(&self) -> bool {
        match self {
            Net::Noc(m) => m.idle(),
            Net::Axi(b) => b.idle(),
        }
    }

    /// Fold `n` provably-idle cycles into the interconnect's statistics
    /// (the idle-skipping scheduler fast-forwarded past them).
    fn account_idle_cycles(&mut self, n: u64) {
        match self {
            Net::Noc(m) => m.account_idle_cycles(n),
            Net::Axi(b) => b.account_idle_cycles(n),
        }
    }
}

pub enum Fabric {
    Buffered(Fpga),
    Cached(CacheFpga),
}

impl Fabric {
    pub fn can_accept_from_noc(&self) -> bool {
        match self {
            Fabric::Buffered(f) => f.can_accept_from_noc(),
            Fabric::Cached(f) => f.can_accept_from_noc(),
        }
    }

    pub fn push_from_noc(&mut self, now: Ps, flit: Flit) {
        match self {
            Fabric::Buffered(f) => f.push_from_noc(now, flit),
            Fabric::Cached(f) => f.push_from_noc(now, flit),
        }
    }

    pub fn pop_to_noc(&mut self, now: Ps) -> Option<Flit> {
        match self {
            Fabric::Buffered(f) => f.pop_to_noc(now),
            Fabric::Cached(f) => f.pop_to_noc(now),
        }
    }

    pub fn step_iface(&mut self, now: Ps, arena: &mut PacketArena) {
        match self {
            Fabric::Buffered(f) => f.step_iface(now, arena),
            // The shared-cache baseline owns its task storage outright and
            // is not on the pooled hot path.
            Fabric::Cached(f) => f.step_iface(now),
        }
    }

    pub fn tasks_executed(&self) -> u64 {
        match self {
            Fabric::Buffered(f) => f.tasks_executed(),
            Fabric::Cached(f) => f.tasks_executed(),
        }
    }

    pub fn flits_in_out(&self) -> (u64, u64) {
        match self {
            Fabric::Buffered(f) => (f.stats.flits_from_noc, f.stats.flits_to_noc),
            Fabric::Cached(f) => (f.stats.flits_from_noc, f.stats.flits_to_noc),
        }
    }

    /// (busy interface cycles, total interface cycles) — the busy-fraction
    /// numerator/denominator. The shared-cache baseline keeps no per-HWA
    /// busy accounting, so it reports (0, 1).
    pub fn iface_busy(&self) -> (u64, u64) {
        match self {
            Fabric::Buffered(f) => {
                (f.stats.busy_iface_cycles, f.stats.iface_cycles)
            }
            Fabric::Cached(_) => (0, 1),
        }
    }

    pub fn buffered(&self) -> Option<&Fpga> {
        match self {
            Fabric::Buffered(f) => Some(f),
            _ => None,
        }
    }

    pub fn buffered_mut(&mut self) -> Option<&mut Fpga> {
        match self {
            Fabric::Buffered(f) => Some(f),
            _ => None,
        }
    }

    pub fn set_compute(&mut self, compute: Box<dyn HwaCompute>) {
        match self {
            Fabric::Buffered(f) => f.set_compute(compute),
            Fabric::Cached(f) => f.set_compute(compute),
        }
    }

    pub fn quiescent(&self, now: Ps) -> bool {
        match self {
            Fabric::Buffered(f) => f.quiescent(now),
            Fabric::Cached(f) => f.quiescent(),
        }
    }

    /// Fold `n` skipped interface-clock cycles into the fabric's counters
    /// so busy-fraction denominators match naive per-edge stepping.
    pub fn account_idle_iface_cycles(&mut self, n: u64) {
        match self {
            Fabric::Buffered(f) => f.account_idle_iface_cycles(n),
            Fabric::Cached(_) => {}
        }
    }

    /// Malformed/over-capacity flits dropped by the channels (summed
    /// across HWAs; the shared-cache baseline keeps no such counter).
    pub fn rejected_flits(&self) -> u64 {
        match self {
            Fabric::Buffered(f) => f
                .channels
                .iter()
                .map(|c| c.stats.rejected_flits)
                .sum(),
            Fabric::Cached(_) => 0,
        }
    }

    /// Flits queued toward the interconnect: NoC-domain scheduler probe.
    pub fn noc_tx_pending(&self) -> bool {
        match self {
            Fabric::Buffered(f) => f.noc_tx_pending(),
            Fabric::Cached(f) => f.noc_tx_pending(),
        }
    }

    /// Interface-domain scheduler probe. The shared-cache baseline drives
    /// everything from the interface clock, so it is busy whenever it is
    /// not fully quiescent.
    pub fn iface_activity(&self) -> Activity {
        match self {
            Fabric::Buffered(f) => f.iface_activity(),
            Fabric::Cached(f) => {
                if f.quiescent() {
                    Activity::Idle
                } else {
                    Activity::Busy
                }
            }
        }
    }

    /// Scheduler probe for one HWA clock domain (buffered fabric only —
    /// the shared-cache baseline registers no HWA domains).
    pub fn hwa_activity(&self, chans: &[usize]) -> Activity {
        match self {
            Fabric::Buffered(f) => f.hwa_domain_activity(chans),
            Fabric::Cached(_) => Activity::Idle,
        }
    }

    /// Fold skipped HWA-clock edges into the owning channels' counters.
    pub fn account_idle_hwa_cycles(&mut self, chans: &[usize], n: u64) {
        match self {
            Fabric::Buffered(f) => f.account_idle_hwa_cycles(chans, n),
            Fabric::Cached(_) => {}
        }
    }
}

/// The adaptive-provisioning engine installed by [`System::set_reconfig`]
/// with a non-`Static` policy: every `epoch_ps` it samples per-type
/// demand from the serving sources and asks the [`Provisioner`] for slot
/// swaps. `Static` installs no engine at all, so such runs are
/// bit-identical to pre-reconfig builds.
struct ReconfigEngine {
    epoch_ps: Ps,
    next_epoch: Ps,
    latency: LatencyModel,
    provisioner: Provisioner,
}

/// One fabric tile as wired into the running system: its NoC node, its
/// clock domains and the fabric model itself.
struct FabricSlot {
    node: usize,
    iface_dom: DomainId,
    hwa_doms: Vec<(DomainId, Vec<usize>)>,
    fabric: Fabric,
}

/// Per-fabric counter snapshot (surfaced as the `fabrics` array in
/// multi-fabric `BENCH_*.json` stats).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FabricTileStats {
    pub fabric: usize,
    pub node: usize,
    pub tasks_executed: u64,
    pub flits_from_noc: u64,
    pub flits_to_noc: u64,
    pub rejected_flits: u64,
    pub busy_iface_cycles: u64,
    pub iface_cycles: u64,
}

pub struct System {
    pub config: SystemConfig,
    pub clk: MultiClock,
    noc_dom: DomainId,
    slots: Vec<FabricSlot>,
    /// Pooled packet/word-buffer storage shared by every buffered fabric:
    /// flit vectors and task word buffers recycle through free-lists, so
    /// the steady-state hot path performs no heap allocation.
    arena: PacketArena,
    pub net: Net,
    pub procs: Vec<Processor>,
    /// Open-loop traffic sources replacing processors (per slot) for the
    /// §6.4 injection-rate experiments.
    pub open_sources: Vec<Option<OpenLoopSource>>,
    /// Multi-tenant serving front ends replacing processors (per slot)
    /// for the datacenter-serving workload tier.
    pub serving_sources: Vec<Option<ServingSource>>,
    mmus: Vec<Mmu>,
    /// src_id → assigned MMU node (the floorplan's per-processor
    /// nearest/hashed assignment, shared by every fabric's channels).
    mmu_route: Vec<u8>,
    ticking: Vec<DomainId>,
    /// Idle-skipping event-driven scheduling (on by default). Each clock
    /// domain reports an [`Activity`] horizon every step; the scheduler
    /// fast-forwards all domains to the earliest instant anything can
    /// happen — a busy domain's next edge, a reported `next_event_at`, or
    /// the caller's deadline — instead of ticking provably no-op edges.
    idle_skip: bool,
    skip_scratch: Vec<u64>,
    /// Clock edges actually dispatched (skipped edges excluded) — the
    /// scheduler's work metric, used by perf tests and hotpath_micro.
    pub edges_stepped: u64,
    /// Clock edges the idle-skipping scheduler proved no-ops and
    /// fast-forwarded past (summed over all domains) — reported per
    /// scenario by `sweep::RunStats`.
    pub edges_skipped: u64,
    /// Per-domain breakdown of `edges_skipped`, indexed by `DomainId`
    /// (surfaced through [`System::edges_skipped_breakdown`]).
    edges_skipped_by: Vec<u64>,
    /// Demand-driven provisioning engine (None = frozen inventory).
    reconfig: Option<ReconfigEngine>,
    /// Slot swaps begun but not yet landed — gates the per-edge
    /// completed-swap drain so the frozen-inventory hot path pays
    /// nothing.
    pending_swaps: usize,
    /// Fault injection + recovery configuration ([`System::set_faults`]).
    /// `None` — the default — installs no fault state anywhere, so
    /// fault-free runs stay byte-identical to pre-fault builds.
    fault_cfg: Option<FaultConfig>,
    /// Reconfiguration-upset state (dead slots awaiting the scrubber);
    /// present only when the fault spec arms the upset class.
    upsets: Option<Box<UpsetFaults>>,
}

impl System {
    /// Build a system, panicking on an invalid topology — the behavior
    /// every pre-floorplan caller relied on. Fallible construction (the
    /// sweep harness, anything user-facing) goes through
    /// [`System::try_new`].
    pub fn new(config: SystemConfig) -> Self {
        Self::try_new(config)
            .unwrap_or_else(|e| panic!("invalid system topology: {e}"))
    }

    /// Build a system from a validated configuration; every topology
    /// defect is a typed [`TopologyError`], not a panic.
    pub fn try_new(config: SystemConfig) -> Result<Self, TopologyError> {
        config.validate()?;
        let plan = &config.floorplan;
        let mut clk = MultiClock::new();
        let noc_clock = ClockDomain::from_mhz("noc+cmp", 1000.0);
        let noc_dom = clk.add(noc_clock.clone());
        let fabric_nodes = plan.fabric_nodes();
        let mmu_nodes = plan.mmu_nodes();
        let proc_nodes = plan.proc_nodes();
        // src_id (3 bits) -> node map for replies.
        let mut reply_route = vec![0u8; 8];
        for (i, n) in proc_nodes.iter().enumerate().take(8) {
            reply_route[i] = *n as u8;
        }
        // src_id -> assigned MMU node (per-processor nearest/hashed).
        let mut mmu_route = vec![mmu_nodes[0] as u8; 8];
        for (i, n) in proc_nodes.iter().enumerate().take(8) {
            mmu_route[i] = plan.mmu_for(*n, i, config.mmu_assign) as u8;
        }
        let mut slots = Vec::with_capacity(config.fabrics.len());
        for (fid, fspec) in config.fabrics.iter().enumerate() {
            let node = fabric_nodes[fid];
            let fabric = match fspec.kind {
                FabricKind::Buffered => {
                    let fcfg = FpgaConfig {
                        n_tbs: fspec.n_tbs,
                        pr: crate::fpga::PrStrategy::distributed(fspec.pr_group),
                        ps: crate::fpga::PsStrategy::hierarchical(
                            fspec.ps_group.min(fspec.specs.len().max(1)),
                        ),
                        iface_mhz: fspec.iface_mhz,
                        node: node as u8,
                        mmu_route: mmu_route.clone(),
                        reply_route: reply_route.clone(),
                    };
                    let mut f = Fpga::new(fcfg, fspec.specs.clone(), &noc_clock);
                    for g in &fspec.chain_groups {
                        f.add_chain_group(g.clone());
                    }
                    Fabric::Buffered(f)
                }
                FabricKind::SharedCache { cache_bytes } => {
                    Fabric::Cached(CacheFpga::new(
                        node as u8,
                        mmu_route.clone(),
                        reply_route.clone(),
                        fspec.specs.clone(),
                        cache_bytes,
                        &noc_clock,
                    ))
                }
            };
            let iface_dom = clk.add(match &fabric {
                Fabric::Buffered(f) => f.iface_clock.clone(),
                Fabric::Cached(f) => f.iface_clock.clone(),
            });
            let hwa_doms = match &fabric {
                Fabric::Buffered(f) => f
                    .hwa_domains()
                    .into_iter()
                    .enumerate()
                    .map(|(i, (p, chans))| {
                        let d = clk.add(ClockDomain {
                            name: format!("f{fid}hwa{i}"),
                            period_ps: p,
                            phase_ps: 0,
                        });
                        (d, chans)
                    })
                    .collect(),
                Fabric::Cached(_) => Vec::new(),
            };
            slots.push(FabricSlot {
                node,
                iface_dom,
                hwa_doms,
                fabric,
            });
        }
        let net = match config.net {
            NetKind::Noc => Net::Noc(Mesh::new(plan.mesh.clone())),
            NetKind::Axi => Net::Axi(
                AxiBus::new(plan.n_nodes(), &fabric_nodes).map_err(|e| {
                    TopologyError::AxiMultiFabric {
                        fabrics: e.endpoints(),
                    }
                })?,
            ),
        };
        // Processors default-route to fabric 0; per-job destinations come
        // from the driver's compiled `InvokeSpec::dest_node`.
        let primary_node = fabric_nodes[0] as u8;
        let procs: Vec<Processor> = proc_nodes
            .iter()
            .enumerate()
            .take(8)
            .map(|(i, n)| {
                Processor::new(i as u8, *n as u8, primary_node, Vec::new())
            })
            .collect();
        let mmus = mmu_nodes
            .iter()
            .map(|n| Mmu::new(*n as u8, primary_node, noc_clock.period_ps))
            .collect();
        let n_procs = proc_nodes.len().min(8);
        let n_domains = clk.n_domains();
        Ok(Self {
            config,
            clk,
            noc_dom,
            slots,
            arena: PacketArena::with_capacity(64, 256),
            net,
            procs,
            open_sources: (0..n_procs).map(|_| None).collect(),
            serving_sources: (0..n_procs).map(|_| None).collect(),
            mmus,
            mmu_route,
            ticking: Vec::new(),
            idle_skip: true,
            skip_scratch: Vec::new(),
            edges_stepped: 0,
            edges_skipped: 0,
            edges_skipped_by: vec![0; n_domains],
            reconfig: None,
            pending_swaps: 0,
            fault_cfg: None,
            upsets: None,
        })
    }

    // ------------------------------------------------------------------
    // Fabric / MMU access
    // ------------------------------------------------------------------

    pub fn n_fabrics(&self) -> usize {
        self.slots.len()
    }

    /// The primary fabric (fabric 0) — the single-fabric surface every
    /// legacy caller uses.
    pub fn fabric(&self) -> &Fabric {
        &self.slots[0].fabric
    }

    pub fn fabric_mut(&mut self) -> &mut Fabric {
        &mut self.slots[0].fabric
    }

    pub fn fabric_at(&self, fabric: usize) -> &Fabric {
        &self.slots[fabric].fabric
    }

    pub fn fabric_at_mut(&mut self, fabric: usize) -> &mut Fabric {
        &mut self.slots[fabric].fabric
    }

    /// NoC node of fabric `fabric`'s interface tile.
    pub fn fabric_node(&self, fabric: usize) -> usize {
        self.slots[fabric].node
    }

    pub fn n_mmus(&self) -> usize {
        self.mmus.len()
    }

    /// Allocation counters of the shared packet/word-buffer arena (the
    /// zero-copy hot path's observability surface: allocs say how often
    /// the pool grew, reuses how often a free-listed buffer was recycled,
    /// high-water the peak live population).
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.stats()
    }

    /// Live (packet, words) handle counts in the shared arena.
    pub fn arena_live(&self) -> (u64, u64) {
        self.arena.live()
    }

    /// The primary MMU (lowest node id).
    pub fn mmu(&self) -> &Mmu {
        &self.mmus[0]
    }

    pub fn mmu_mut(&mut self) -> &mut Mmu {
        &mut self.mmus[0]
    }

    pub fn mmus(&self) -> &[Mmu] {
        &self.mmus
    }

    pub fn mmu_at_mut(&mut self, i: usize) -> &mut Mmu {
        &mut self.mmus[i]
    }

    /// The MMU node assigned to processor `src` by the floorplan's
    /// nearest/hashed policy.
    pub fn mmu_node_for_src(&self, src: usize) -> usize {
        self.mmu_route
            .get(src)
            .copied()
            .unwrap_or(self.mmu_route[0]) as usize
    }

    // ------------------------------------------------------------------
    // Cross-fabric totals (the single-fabric values, summed)
    // ------------------------------------------------------------------

    /// Total tasks executed across every fabric.
    pub fn tasks_executed(&self) -> u64 {
        self.slots.iter().map(|s| s.fabric.tasks_executed()).sum()
    }

    /// (flits into, flits out of) all fabrics combined.
    pub fn flits_in_out(&self) -> (u64, u64) {
        self.slots.iter().fold((0, 0), |(i, o), s| {
            let (fi, fo) = s.fabric.flits_in_out();
            (i + fi, o + fo)
        })
    }

    /// Busy/total interface cycles summed across fabrics.
    pub fn iface_busy(&self) -> (u64, u64) {
        self.slots.iter().fold((0, 0), |(b, c), s| {
            let (fb, fc) = s.fabric.iface_busy();
            (b + fb, c + fc)
        })
    }

    /// Rejected flits summed across fabrics.
    pub fn rejected_flits(&self) -> u64 {
        self.slots.iter().map(|s| s.fabric.rejected_flits()).sum()
    }

    /// Per-fabric counter snapshot, indexed by fabric id.
    pub fn per_fabric_stats(&self) -> Vec<FabricTileStats> {
        self.slots
            .iter()
            .enumerate()
            .map(|(f, s)| {
                let (fin, fout) = s.fabric.flits_in_out();
                let (busy, cyc) = s.fabric.iface_busy();
                FabricTileStats {
                    fabric: f,
                    node: s.node,
                    tasks_executed: s.fabric.tasks_executed(),
                    flits_from_noc: fin,
                    flits_to_noc: fout,
                    rejected_flits: s.fabric.rejected_flits(),
                    busy_iface_cycles: busy,
                    iface_cycles: cyc,
                }
            })
            .collect()
    }

    // ------------------------------------------------------------------
    // Scheduling
    // ------------------------------------------------------------------

    /// Enable/disable the idle-skipping scheduler (enabled by default).
    /// Disabling forces naive per-edge stepping; per-task latency records
    /// are identical either way (rust/tests/event_driven.rs proves it).
    pub fn set_idle_skip(&mut self, on: bool) {
        self.idle_skip = on;
    }

    /// Replace every processor with an open-loop source at the given
    /// aggregate request rate (requests/µs across all sources). Sources
    /// spread their requests uniformly over every accelerator of every
    /// fabric (fabric-major target order).
    pub fn set_open_loop(&mut self, total_rate_per_us: f64, seed: u64) {
        let n = self.procs.len();
        let mut targets = Vec::new();
        for (fid, fspec) in self.config.fabrics.iter().enumerate() {
            let node = self.slots[fid].node as u8;
            for (i, s) in fspec.specs.iter().enumerate() {
                targets.push(OpenLoopTarget {
                    node,
                    hwa_id: i as u8,
                    spec: s.clone(),
                });
            }
        }
        for i in 0..n {
            let mut src = OpenLoopSource::new(
                i as u8,
                self.procs[i].node,
                targets.clone(),
                total_rate_per_us / n as f64,
                seed,
            );
            // The runner installs faults before sources: arm recovery.
            if let Some(cfg) = &self.fault_cfg {
                src.arm_fault_recovery(cfg.recovery, cfg.timeout_ps);
            }
            self.open_sources[i] = Some(src);
        }
    }

    /// Total completed invocations across open-loop sources.
    pub fn open_loop_completions(&self) -> u64 {
        self.open_sources
            .iter()
            .flatten()
            .map(|s| s.results_done)
            .sum()
    }

    /// Replace processors with multi-tenant serving front ends. Tenant
    /// `t` lands on processor `t % n_procs`; targets are fabric-major
    /// like [`System::set_open_loop`]. Chained jobs are only planned
    /// when the configuration declares chain groups; the serving source
    /// downgrades them to direct otherwise.
    pub fn set_serving(
        &mut self,
        tenants: &[TenantSpec],
        admission: bool,
        watermark: usize,
        seed: u64,
    ) {
        let n = self.procs.len();
        let mut targets = Vec::new();
        for (fid, fspec) in self.config.fabrics.iter().enumerate() {
            let node = self.slots[fid].node as u8;
            let fabric_len = fspec.specs.len();
            for (i, s) in fspec.specs.iter().enumerate() {
                targets.push(ServingTarget {
                    node,
                    hwa_id: i as u8,
                    spec: s.clone(),
                    fabric_len,
                });
            }
        }
        let chain_ok = self
            .config
            .fabrics
            .iter()
            .any(|f| !f.chain_groups.is_empty());
        for i in 0..n {
            let mine: Vec<TenantSpec> = tenants
                .iter()
                .enumerate()
                .filter(|(t, _)| t % n == i)
                .map(|(_, s)| *s)
                .collect();
            self.serving_sources[i] = if mine.is_empty() {
                None
            } else {
                let mut src = ServingSource::new(
                    i as u8,
                    self.procs[i].node,
                    targets.clone(),
                    mine,
                    admission,
                    watermark,
                    chain_ok,
                    seed,
                );
                // The runner installs faults before sources: arm
                // timeout/retry/failover recovery.
                if let Some(cfg) = &self.fault_cfg {
                    src.arm_fault_recovery(cfg.recovery, cfg.timeout_ps);
                }
                Some(src)
            };
        }
    }

    // ------------------------------------------------------------------
    // Fault injection & recovery ([`crate::fault`])
    // ------------------------------------------------------------------

    /// Install (or clear) seed-deterministic fault injection. A
    /// [`FaultSpec::None`](crate::fault::FaultSpec::None) spec installs
    /// nothing at all — no RNG stream, no per-site state, no extra
    /// activity horizons — so fault-free runs stay byte-identical to
    /// builds that never heard of faults (pinned by
    /// `rust/tests/sweep.rs`).
    ///
    /// Any armed spec installs per-channel fault state on every buffered
    /// fabric (the TB watchdog and dead-slot fencing serve the link and
    /// upset classes too, not just `hwa:`), link faults on the NoC when
    /// the link class is armed, and upset state when the upset class is.
    /// Sources built later pick the recovery policy up from the stored
    /// config; already-built sources are armed here.
    pub fn set_faults(&mut self, cfg: FaultConfig) {
        if cfg.spec.is_none() {
            self.fault_cfg = None;
            self.upsets = None;
            if let Net::Noc(m) = &mut self.net {
                m.fault = None;
            }
            for slot in &mut self.slots {
                if let Some(f) = slot.fabric.buffered_mut() {
                    for ch in f.channels.iter_mut() {
                        ch.fault = None;
                    }
                }
            }
            return;
        }
        let spec = cfg.spec;
        // Link faults hit ejection links at fabric and processor tiles.
        // MMU tiles are exempt (memory-side payloads carry no end-to-end
        // verifier yet) and the AXI baseline models no lossy links.
        if let Net::Noc(m) = &mut self.net {
            if spec.link_drop_p() > 0.0 {
                let mut mask = vec![true; self.config.floorplan.n_nodes()];
                for mn in self.config.floorplan.mmu_nodes() {
                    mask[mn] = false;
                }
                m.fault = Some(Box::new(LinkFaults::new(
                    cfg.seed,
                    spec.link_drop_p(),
                    spec.link_flip_p(),
                    mask,
                )));
            }
        }
        let mut global_channel = 0u64;
        for slot in &mut self.slots {
            if let Some(f) = slot.fabric.buffered_mut() {
                for ch in f.channels.iter_mut() {
                    ch.fault = Some(Box::new(ChannelFaults::new(
                        cfg.seed,
                        global_channel,
                        spec.hwa_hang_p(),
                        spec.hwa_corrupt_p(),
                        cfg.timeout_ps,
                    )));
                    global_channel += 1;
                }
            }
        }
        if spec.upset_p() > 0.0 {
            self.upsets = Some(Box::new(UpsetFaults::new(
                cfg.seed,
                spec.upset_p(),
                cfg.scrub_ps.max(1),
            )));
        }
        for src in self.serving_sources.iter_mut().flatten() {
            src.arm_fault_recovery(cfg.recovery, cfg.timeout_ps);
        }
        for src in self.open_sources.iter_mut().flatten() {
            src.arm_fault_recovery(cfg.recovery, cfg.timeout_ps);
        }
        self.fault_cfg = Some(cfg);
    }

    /// The installed fault configuration, if any.
    pub fn fault_config(&self) -> Option<&FaultConfig> {
        self.fault_cfg.as_ref()
    }

    /// Aggregate fault counters across every injection and recovery
    /// site: NoC link faults, per-channel HWA faults and their
    /// detectors, upsets/scrubs, and the sources' retry/failover/
    /// permanent-failure machines. All-zero when faults are off.
    pub fn fault_stats(&self) -> FaultStats {
        let mut st = FaultStats::default();
        if let Net::Noc(m) = &self.net {
            if let Some(lf) = m.fault.as_deref() {
                st.injected += lf.drops + lf.flips;
            }
        }
        for slot in &self.slots {
            if let Some(f) = slot.fabric.buffered() {
                for ch in &f.channels {
                    if let Some(cf) = ch.fault.as_deref() {
                        st.absorb(&cf.stats());
                    }
                }
            }
        }
        if let Some(up) = self.upsets.as_deref() {
            st.absorb(&up.stats());
        }
        for src in self.serving_sources.iter().flatten() {
            st.absorb(&src.fault_stats());
        }
        for src in self.open_sources.iter().flatten() {
            st.absorb(&src.fault_stats());
        }
        st
    }

    /// Fire scrubber epochs up to `now`: every `scrub_ps`, each dead
    /// slot is re-programmed with its **current** bitstream through the
    /// ordinary reconfiguration FSM (a scrub, not a swap — the inventory
    /// doesn't change). Slots already mid-swap are retried next epoch.
    /// Like [`System::fire_reconfig_epochs`], firing is a pure function
    /// of the dispatched-edge time, so naive and idle-skipping schedules
    /// scrub at identical instants.
    fn fire_scrub_epochs(&mut self, now: Ps) {
        let due = matches!(&self.upsets, Some(up) if now >= up.next_scrub);
        if !due {
            return;
        }
        let Some(mut up) = self.upsets.take() else { return };
        let latency_model = self
            .reconfig
            .as_ref()
            .map(|e| e.latency)
            .unwrap_or_default();
        while now >= up.next_scrub {
            if up.dead.is_empty() {
                // Nothing to scrub: jump to the first epoch past `now`
                // instead of looping through skipped-over ticks.
                let behind = now - up.next_scrub;
                up.next_scrub += (behind / up.scrub_ps + 1) * up.scrub_ps;
                break;
            }
            up.next_scrub += up.scrub_ps;
            let dead = up.dead.clone();
            for d in dead {
                let Some(spec) = self
                    .config
                    .fabrics
                    .get(d.fabric)
                    .and_then(|f| f.specs.get(d.channel))
                    .cloned()
                else {
                    continue;
                };
                let latency = latency_model.latency_ps(&spec);
                let _ =
                    self.request_reconfig(d.fabric, d.channel, spec, latency);
            }
        }
        self.upsets = Some(up);
    }

    // ------------------------------------------------------------------
    // Dynamic partial reconfiguration ([`crate::reconfig`])
    // ------------------------------------------------------------------

    /// Install the demand-driven provisioning engine. `Static` installs
    /// nothing — the run is bit-identical to one that never called this.
    /// `QueueDepth` samples per-type serving demand every `epoch_us` and
    /// swaps cold reconfigurable slots toward hot accelerator types, with
    /// per-swap latency from `latency` and the target core's size.
    pub fn set_reconfig(
        &mut self,
        policy: ProvisionPolicy,
        epoch_us: f64,
        latency: LatencyModel,
    ) {
        self.reconfig = match policy {
            ProvisionPolicy::Static => None,
            _ => {
                let epoch_ps =
                    ((epoch_us * crate::clock::PS_PER_US as f64) as Ps).max(1);
                Some(ReconfigEngine {
                    epoch_ps,
                    next_epoch: epoch_ps,
                    latency,
                    provisioner: Provisioner::new(policy),
                })
            }
        };
    }

    /// Manually begin a slot swap (the driver/demo surface — adaptive
    /// runs go through [`System::set_reconfig`] instead). The slot must
    /// be declared `reconfigurable` in its [`FabricSpec`] and the
    /// post-swap inventory must fit the device budget.
    pub fn request_reconfig(
        &mut self,
        fabric: usize,
        channel: usize,
        target: HwaSpec,
        latency_ps: Ps,
    ) -> Result<(), String> {
        let fspec = self
            .config
            .fabrics
            .get(fabric)
            .ok_or_else(|| format!("reconfig: no fabric {fabric}"))?;
        if !fspec.reconfigurable.contains(&channel) {
            return Err(format!(
                "reconfig: fabric {fabric} channel {channel} is not a \
                 reconfigurable slot"
            ));
        }
        let mut specs = fspec.specs.clone();
        specs[channel] = target.clone();
        let cost = crate::synth::resource::inventory_cost(
            fspec.pr_group,
            fspec.ps_group,
            &specs,
            !fspec.chain_groups.is_empty(),
        );
        if self.config.device.exceeds(&cost) {
            return Err(format!(
                "reconfig: swapping in {} exceeds the {} budget \
                 ({} LUTs / {} BRAMs)",
                target.name, self.config.device.name, cost.lut, cost.bram
            ));
        }
        let f = self.slots[fabric]
            .fabric
            .buffered_mut()
            .ok_or_else(|| {
                format!("reconfig: fabric {fabric} is not buffered")
            })?;
        f.begin_reconfig(channel, target, latency_ps)?;
        self.pending_swaps += 1;
        Ok(())
    }

    /// Is the slot serving `hwa_id` on `fabric` mid-swap right now?
    pub fn slot_reconfiguring(&self, fabric: usize, hwa_id: u8) -> bool {
        self.slots
            .get(fabric)
            .and_then(|s| s.fabric.buffered())
            .map(|f| f.reconfiguring(hwa_id as usize))
            .unwrap_or(false)
    }

    /// (swaps, drain cycles, blocked-while-reconfiguring cycles) summed
    /// across fabrics — the counters `sweep::RunStats` reports.
    pub fn reconfig_stats(&self) -> (u64, u64, u64) {
        self.slots.iter().fold((0, 0, 0), |(s, d, b), slot| {
            match slot.fabric.buffered() {
                Some(f) => (
                    s + f.stats.reconfig_swaps,
                    d + f.stats.reconfig_drain_cycles,
                    b + f.stats.reconfig_blocked_cycles,
                ),
                None => (s, d, b),
            }
        })
    }

    /// Inventory snapshot per buffered fabric, as the provisioner sees
    /// it: each slot's current type, whether it may be swapped, and any
    /// in-flight conversion.
    fn fabric_views(&self) -> Vec<FabricView> {
        let mut views = Vec::new();
        for (fid, slot) in self.slots.iter().enumerate() {
            let Some(f) = slot.fabric.buffered() else { continue };
            let reconfigurable = &self.config.fabrics[fid].reconfigurable;
            let slots = f
                .channels
                .iter()
                .enumerate()
                .map(|(c, ch)| {
                    let state = f
                        .active_reconfigs()
                        .iter()
                        .find(|r| r.channel == c)
                        .map(|r| SlotState::Converting(r.target.name))
                        .unwrap_or(SlotState::Live);
                    SlotView {
                        channel: c,
                        name: ch.spec.name,
                        reconfigurable: reconfigurable.contains(&c),
                        state,
                    }
                })
                .collect();
            views.push(FabricView { fabric: fid, slots });
        }
        views
    }

    /// Fire provisioning epochs up to `now`: sample demand from the
    /// serving sources, ask the provisioner for swaps, and begin every
    /// plan that clears the device budget.
    fn fire_reconfig_epochs(&mut self, now: Ps) {
        let due = match &self.reconfig {
            Some(eng) => now >= eng.next_epoch,
            None => false,
        };
        if !due {
            return;
        }
        let Some(mut eng) = self.reconfig.take() else { return };
        while now >= eng.next_epoch {
            eng.next_epoch += eng.epoch_ps;
            let mut demand: BTreeMap<&'static str, f64> = BTreeMap::new();
            for src in self.serving_sources.iter().flatten() {
                src.demand_by_name(&mut demand);
            }
            let views = self.fabric_views();
            let plans = eng.provisioner.plan(&demand, &views, &|name| {
                crate::fpga::hwa::spec_by_name(name)
            });
            for plan in plans {
                let latency = eng.latency.latency_ps(&plan.target);
                // Budget-infeasible or already-busy slots are skipped;
                // the provisioner retries at the next epoch.
                let _ = self.request_reconfig(
                    plan.fabric,
                    plan.channel,
                    plan.target,
                    latency,
                );
            }
        }
        self.reconfig = Some(eng);
    }

    /// Land completed swaps into the configuration's inventory view and
    /// retarget the serving sources (queued jobs for the old type keep
    /// their original plans; only future picks see the new inventory).
    fn finish_swaps(&mut self) {
        if self.pending_swaps == 0 {
            return;
        }
        for (fid, slot) in self.slots.iter_mut().enumerate() {
            let Fabric::Buffered(f) = &mut slot.fabric else { continue };
            for (c, spec) in f.take_completed_swaps() {
                self.pending_swaps -= 1;
                self.config.fabrics[fid].specs[c] = spec.clone();
                let node = slot.node as u8;
                for src in self.serving_sources.iter_mut().flatten() {
                    src.retarget(node, c as u8, &spec);
                }
                if let Some(up) = self.upsets.as_deref_mut() {
                    // A scrub re-land repairs the slot; then every
                    // landing — swap or scrub alike — rolls the upset
                    // die again (a scrub can itself be upset).
                    if up.is_dead(fid, c) {
                        up.mark_repaired(fid, c);
                    }
                    let dead_now = up.draw_on_land(fid, c);
                    if let Some(cf) = f.channels[c].fault.as_deref_mut() {
                        cf.dead = dead_now;
                    }
                }
            }
        }
    }

    /// Total completed requests across serving sources.
    pub fn serving_completions(&self) -> u64 {
        self.serving_sources
            .iter()
            .flatten()
            .map(|s| s.results_done)
            .sum()
    }

    /// Load a program onto processor `i`.
    pub fn load_program(&mut self, i: usize, program: Vec<Segment>) {
        for seg in program {
            self.procs[i].enqueue(seg);
        }
    }

    pub fn now(&self) -> Ps {
        self.clk.now()
    }

    /// Activity probe for the NoC+CMP clock domain: the interconnect,
    /// every fabric's NoC-facing FIFO, every MMU and every processor /
    /// open-loop source all act on NoC edges. `Busy` while any of them
    /// holds in-flight work; otherwise the earliest self-scheduled event
    /// (DMA completion, Poisson arrival) bounds the domain's horizon.
    fn noc_domain_activity(&self) -> Activity {
        if !self.net.idle()
            || self.slots.iter().any(|s| s.fabric.noc_tx_pending())
        {
            return Activity::Busy;
        }
        let mut act = Activity::Idle;
        for m in &self.mmus {
            act = act.join(m.activity());
            if act == Activity::Busy {
                return act;
            }
        }
        for (i, p) in self.procs.iter().enumerate() {
            let a = match (
                self.open_sources[i].as_ref(),
                self.serving_sources[i].as_ref(),
            ) {
                (Some(src), _) => src.activity(),
                (None, Some(src)) => src.activity(),
                (None, None) => p.activity(),
            };
            act = act.join(a);
            if act == Activity::Busy {
                return act;
            }
        }
        act
    }

    /// Per-domain event horizons (the ISSUE 4 tentpole). Each clock
    /// domain reports an [`Activity`]: the skip target is the earliest of
    /// every busy domain's next edge, every reported `next_event_at`, and
    /// the caller's deadline. Skipping all edges strictly before that
    /// target is sound because cross-domain work can only be injected at
    /// a dispatched edge, and no dispatched edge precedes the target; the
    /// skipped cycles are folded into each domain's cycle accounting so
    /// every statistic matches naive per-edge stepping (the
    /// `rust/tests/event_driven.rs` property and the ci_smoke neutrality
    /// test in `rust/tests/sweep.rs` enforce this).
    fn skip_idle(&mut self, deadline: Option<Ps>) {
        if !self.idle_skip {
            return;
        }
        let now = self.clk.now();
        if now == 0 {
            return;
        }
        fn fold(target: &mut Option<Ps>, t: Ps) {
            *target = Some(target.map_or(t, |x| x.min(t)));
        }
        let mut target: Option<Ps> = None;
        match self.noc_domain_activity() {
            Activity::Busy => fold(&mut target, self.clk.next_edge_of(self.noc_dom)),
            Activity::Idle => {}
            Activity::NextEventAt(t) => fold(&mut target, t),
        }
        for slot in &self.slots {
            match slot.fabric.iface_activity() {
                Activity::Busy => {
                    fold(&mut target, self.clk.next_edge_of(slot.iface_dom))
                }
                Activity::Idle => {}
                Activity::NextEventAt(t) => fold(&mut target, t),
            }
            for (d, chans) in &slot.hwa_doms {
                match slot.fabric.hwa_activity(chans) {
                    Activity::Busy => {
                        fold(&mut target, self.clk.next_edge_of(*d))
                    }
                    Activity::Idle => {}
                    Activity::NextEventAt(t) => fold(&mut target, t),
                }
            }
        }
        // A provisioning epoch is a scheduled event: never skip past it,
        // so adaptive runs observe demand at the same instants under
        // idle-skipping and naive stepping.
        if let Some(eng) = &self.reconfig {
            fold(&mut target, eng.next_epoch);
        }
        // A pending scrub is likewise a scheduled event, but only once a
        // slot is actually dead — with nothing to scrub the epoch is a
        // no-op and `fire_scrub_epochs` catches the clock up for free.
        if let Some(up) = self.upsets.as_deref() {
            if !up.dead.is_empty() {
                fold(&mut target, up.next_scrub);
            }
        }
        let target = match (target, deadline) {
            (Some(t), Some(d)) => t.min(d),
            (Some(t), None) => t,
            (None, Some(d)) => d,
            // Every domain idle, nothing scheduled, no deadline: there is
            // no provable horizon to skip to.
            (None, None) => return,
        };
        if target <= now {
            return;
        }
        let mut skipped = std::mem::take(&mut self.skip_scratch);
        self.clk.skip_until(target, &mut skipped);
        let n = skipped[self.noc_dom.0];
        if n > 0 {
            self.net.account_idle_cycles(n);
            // Processors (when not replaced by open-loop sources) count
            // every NoC edge in `total_cycles` even while awaiting; fold
            // the skipped ones in so the counter matches naive stepping.
            for (i, p) in self.procs.iter_mut().enumerate() {
                if self.open_sources[i].is_none()
                    && self.serving_sources[i].is_none()
                {
                    p.account_idle_cycles(n);
                }
            }
        }
        for slot in &mut self.slots {
            let n = skipped[slot.iface_dom.0];
            if n > 0 {
                slot.fabric.account_idle_iface_cycles(n);
            }
            for (d, chans) in &slot.hwa_doms {
                let n = skipped[d.0];
                if n > 0 {
                    slot.fabric.account_idle_hwa_cycles(chans, n);
                }
            }
        }
        for (i, n) in skipped.iter().enumerate() {
            self.edges_skipped += *n;
            self.edges_skipped_by[i] += *n;
        }
        self.skip_scratch = skipped;
    }

    /// Skipped-edge counts as (NoC+CMP, all fabric interfaces, all HWA
    /// domains) — the per-domain breakdown `sweep::RunStats` reports.
    pub fn edges_skipped_breakdown(&self) -> (u64, u64, u64) {
        let noc = self.edges_skipped_by[self.noc_dom.0];
        let mut iface = 0;
        let mut hwa = 0;
        for slot in &self.slots {
            iface += self.edges_skipped_by[slot.iface_dom.0];
            hwa += slot
                .hwa_doms
                .iter()
                .map(|(d, _)| self.edges_skipped_by[d.0])
                .sum::<u64>();
        }
        (noc, iface, hwa)
    }

    /// Advance the whole system by one clock event, first fast-forwarding
    /// past every edge the per-domain horizons prove to be a no-op.
    pub fn step(&mut self) -> Ps {
        self.skip_idle(None);
        self.step_edge()
    }

    /// Dispatch exactly one clock event (no idle skipping).
    fn step_edge(&mut self) -> Ps {
        self.edges_stepped += 1;
        let mut ticking = std::mem::take(&mut self.ticking);
        let t = self.clk.advance(&mut ticking);
        // Provisioning epochs fire at the first dispatched edge at or
        // after each epoch boundary — a pure function of `t`, so naive
        // and idle-skipping schedules make identical decisions.
        self.fire_reconfig_epochs(t);
        self.fire_scrub_epochs(t);
        for d in &ticking {
            if *d == self.noc_dom {
                self.step_noc_domain(t);
                continue;
            }
            let arena = &mut self.arena;
            for slot in self.slots.iter_mut() {
                if *d == slot.iface_dom {
                    slot.fabric.step_iface(t, arena);
                    break;
                }
                if let Some((_, chans)) =
                    slot.hwa_doms.iter().find(|(dd, _)| dd == d)
                {
                    if let Fabric::Buffered(f) = &mut slot.fabric {
                        for i in chans {
                            f.step_channel(*i, t, arena);
                        }
                        // Tasks retired on this edge hand their word
                        // buffers straight back to the pool.
                        f.recycle_completed_words(arena);
                    }
                    break;
                }
            }
        }
        self.ticking = ticking;
        self.finish_swaps();
        t
    }

    fn step_noc_domain(&mut self, t: Ps) {
        // Fabric <-> net exchange, per interface tile in fabric-id order.
        for k in 0..self.slots.len() {
            let node = self.slots[k].node;
            while self.slots[k].fabric.can_accept_from_noc()
                && self.net.eject_peek_some(node)
            {
                let f = self.net.eject_pop(node).expect("peeked");
                self.slots[k].fabric.push_from_noc(t, f);
            }
            if self.net.can_inject(node) {
                if let Some(mut f) = self.slots[k].fabric.pop_to_noc(t) {
                    // Stamp the interface tile of origin into every
                    // outbound head (grants, notifies AND result heads —
                    // all keep those payload bits spare): MMUs and
                    // open-loop sources attribute answers/completions to
                    // the right fabric without any global "the FPGA
                    // node" assumption.
                    if f.is_head() {
                        f.stamp_origin(node as u8);
                    }
                    let ok = self.net.try_inject(node, f);
                    debug_assert!(ok);
                }
            }
        }
        // MMU tiles.
        for i in 0..self.mmus.len() {
            let node = self.mmus[i].node as usize;
            while let Some(f) = self.net.eject_pop(node) {
                self.mmus[i].deliver(f, t);
            }
            let can = self.net.can_inject(node);
            if let Some(f) = self.mmus[i].step(t, can) {
                let ok = self.net.try_inject(node, f);
                debug_assert!(ok);
            }
        }
        // Processors (or their open-loop replacements).
        for (i, p) in self.procs.iter_mut().enumerate() {
            let node = p.node as usize;
            if let Some(src) = self.open_sources[i].as_mut() {
                while let Some(f) = self.net.eject_pop(node) {
                    src.deliver(f, t);
                }
                let can = self.net.can_inject(node);
                if let Some(f) = src.step(t, can) {
                    let ok = self.net.try_inject(node, f);
                    debug_assert!(ok);
                }
                continue;
            }
            if let Some(src) = self.serving_sources[i].as_mut() {
                while let Some(f) = self.net.eject_pop(node) {
                    src.deliver(f, t);
                }
                let can = self.net.can_inject(node);
                if let Some(f) = src.step(t, can) {
                    let ok = self.net.try_inject(node, f);
                    debug_assert!(ok);
                }
                continue;
            }
            while let Some(f) = self.net.eject_pop(node) {
                p.deliver(f, t);
            }
            let can = self.net.can_inject(node);
            if let Some(f) = p.step(t, can) {
                let ok = self.net.try_inject(node, f);
                debug_assert!(ok);
            }
        }
        // Advance the interconnect itself.
        self.net.step();
    }

    /// Run until every processor's program completes (or deadline).
    /// Returns true on completion. The completion check fires before any
    /// idle skip, so `now()` on success is the drain time, not the
    /// deadline; a deadlocked-idle system fast-forwards to the deadline.
    pub fn run_until_done(&mut self, deadline_ps: Ps) -> bool {
        while self.clk.now() < deadline_ps {
            self.skip_idle(Some(deadline_ps));
            self.step_edge();
            if self.procs.iter().all(|p| p.done())
                && self.net.idle()
                && self.mmus.iter().all(|m| m.idle())
                && {
                    let now = self.clk.now();
                    self.slots.iter().all(|s| s.fabric.quiescent(now))
                }
            {
                return true;
            }
        }
        false
    }

    /// Run for a fixed window.
    pub fn run_for(&mut self, window_ps: Ps) {
        let end = self.clk.now() + window_ps;
        while self.clk.now() < end {
            self.skip_idle(Some(end));
            self.step_edge();
        }
    }

    pub fn n_procs(&self) -> usize {
        self.procs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{AccelRuntime, Job};
    use crate::fpga::hwa::spec_by_name;

    fn one_hwa_runtime(net: NetKind, fabric: FabricKind) -> AccelRuntime {
        let mut cfg = SystemConfig::paper(vec![
            spec_by_name("dfadd").unwrap(),
            spec_by_name("izigzag").unwrap(),
        ]);
        cfg.net = net;
        cfg.fabrics[0].kind = fabric;
        AccelRuntime::new(cfg)
    }

    #[test]
    fn full_system_single_invocation_noc() {
        let mut rt = one_hwa_runtime(NetKind::Noc, FabricKind::Buffered);
        let dfadd = rt.accel(0).unwrap();
        let receipt = rt
            .submit(0, Job::on(dfadd).direct(vec![1, 2, 3, 4]))
            .unwrap();
        assert!(rt.run_until_done(50_000_000), "completed within 50 µs");
        let done = rt.poll(receipt).expect("recorded");
        let r = done.record();
        assert!(r.t_grant > r.t_request);
        assert!(r.t_result_last > r.t_grant);
        assert_eq!(rt.system().fabric().tasks_executed(), 1);
        // dfadd of (1,2)+(3,4) via native/echo compute: result delivered.
        assert_eq!(rt.last_result(0).len(), 2);
    }

    #[test]
    fn full_system_single_invocation_axi() {
        let mut rt = one_hwa_runtime(NetKind::Axi, FabricKind::Buffered);
        let dfadd = rt.accel(0).unwrap();
        rt.submit(0, Job::on(dfadd).direct(vec![1, 2, 3, 4])).unwrap();
        assert!(rt.run_until_done(50_000_000));
        assert_eq!(rt.system().fabric().tasks_executed(), 1);
    }

    #[test]
    fn full_system_single_invocation_shared_cache() {
        let mut rt = one_hwa_runtime(
            NetKind::Noc,
            FabricKind::SharedCache {
                cache_bytes: 64 * 1024,
            },
        );
        let dfadd = rt.accel(0).unwrap();
        rt.submit(0, Job::on(dfadd).direct(vec![1, 2, 3, 4])).unwrap();
        assert!(rt.run_until_done(50_000_000));
        assert_eq!(rt.system().fabric().tasks_executed(), 1);
    }

    #[test]
    fn seven_processors_share_one_hwa() {
        let mut rt = one_hwa_runtime(NetKind::Noc, FabricKind::Buffered);
        let izigzag = rt.accel(1).unwrap();
        let n = rt.n_cores();
        for core in 0..n {
            rt.submit(core, Job::on(izigzag).direct((0..64).collect()))
                .unwrap();
        }
        assert!(rt.run_until_done(100_000_000));
        assert_eq!(rt.system().fabric().tasks_executed(), n as u64);
        assert_eq!(rt.completions().len(), n);
    }

    #[test]
    fn noc_latency_beats_axi_under_load() {
        // The Fig. 14 direction: with several processors invoking
        // concurrently (each its own HWA so the fabric doesn't serialize),
        // the shared bus becomes the bottleneck and loses.
        let run = |net| {
            let mut cfg = SystemConfig::paper(
                crate::fpga::hwa::table3().into_iter().take(7).collect(),
            );
            cfg.net = net;
            let mut rt = AccelRuntime::new(cfg);
            let n = rt.n_cores();
            for core in 0..n {
                let hwa = rt.accel(core as u8).unwrap();
                let words: Vec<u32> = (0..hwa.in_words() as u32).collect();
                rt.submit(core, Job::on(hwa).direct(words)).unwrap();
            }
            assert!(rt.run_until_done(400_000_000));
            rt.completions()
                .iter()
                .map(|c| c.total_ps() as f64)
                .sum::<f64>()
                / n as f64
        };
        let noc = run(NetKind::Noc);
        let axi = run(NetKind::Axi);
        assert!(
            axi > noc,
            "axi mean latency {axi} should exceed noc {noc}"
        );
    }

    /// Idle skipping must be invisible to every task-level observable:
    /// same completions, same latencies, same flit/cycle statistics.
    #[test]
    fn idle_skip_matches_per_edge_stepping_open_loop() {
        let observe = |skip: bool, net: NetKind| {
            let mut cfg = SystemConfig::paper(vec![
                spec_by_name("izigzag").unwrap();
                4
            ]);
            cfg.net = net;
            let mut sys = System::new(cfg);
            sys.set_idle_skip(skip);
            sys.set_open_loop(0.5, 9);
            sys.run_for(40 * crate::clock::PS_PER_US);
            let lat: Vec<(u64, u64, Vec<u64>)> = sys
                .open_sources
                .iter()
                .flatten()
                .map(|s| {
                    (s.requests_issued, s.results_done, s.latencies_ps.clone())
                })
                .collect();
            let (fin, fout) = sys.fabric().flits_in_out();
            (lat, fin, fout, sys.fabric().tasks_executed())
        };
        for net in [NetKind::Noc, NetKind::Axi] {
            assert_eq!(observe(true, net), observe(false, net), "{net:?}");
        }
    }

    /// The scheduler's whole point: a low-injection open-loop run must
    /// dispatch far fewer edges with skipping than per-edge stepping.
    #[test]
    fn idle_skip_reduces_dispatched_edges() {
        let edges = |skip: bool| {
            let cfg = SystemConfig::paper(vec![
                spec_by_name("izigzag").unwrap();
                4
            ]);
            let mut sys = System::new(cfg);
            sys.set_idle_skip(skip);
            sys.set_open_loop(0.25, 7);
            sys.run_for(100 * crate::clock::PS_PER_US);
            (sys.edges_stepped, sys.open_loop_completions())
        };
        let (skipped, done_s) = edges(true);
        let (naive, done_n) = edges(false);
        assert_eq!(done_s, done_n, "same work either way");
        assert!(
            skipped * 2 < naive,
            "idle skipping should cut dispatched edges >=2x: {skipped} vs {naive}"
        );
    }

    /// Skipped cycles are folded into the stats that feed busy fractions.
    #[test]
    fn idle_skip_preserves_cycle_accounting() {
        let cycles = |skip: bool| {
            let cfg = SystemConfig::paper(vec![
                spec_by_name("izigzag").unwrap();
                2
            ]);
            let mut sys = System::new(cfg);
            sys.set_idle_skip(skip);
            sys.set_open_loop(1.0, 3);
            sys.run_for(20 * crate::clock::PS_PER_US);
            let mesh_cycles = match &sys.net {
                Net::Noc(m) => m.cycles,
                Net::Axi(b) => b.cycles,
            };
            let iface_cycles = sys
                .fabric()
                .buffered()
                .map(|f| f.stats.iface_cycles)
                .unwrap_or(0);
            (mesh_cycles, iface_cycles)
        };
        assert_eq!(cycles(true), cycles(false));
    }

    /// Per-domain event horizons: on a low-rate open loop every domain
    /// group skips edges, the breakdown sums to the total, and the 1 GHz
    /// NoC+CMP domain (the most frequent clock) dominates the savings.
    #[test]
    fn edges_skipped_breakdown_covers_all_domain_groups() {
        let cfg = SystemConfig::paper(vec![
            spec_by_name("izigzag").unwrap();
            4
        ]);
        let mut sys = System::new(cfg);
        sys.set_open_loop(0.5, 11);
        sys.run_for(50 * crate::clock::PS_PER_US);
        let (noc, iface, hwa) = sys.edges_skipped_breakdown();
        assert_eq!(noc + iface + hwa, sys.edges_skipped, "breakdown sums");
        assert!(noc > 0, "NoC domain skipped nothing");
        assert!(iface > 0, "interface domain skipped nothing");
        assert!(hwa > 0, "HWA domains skipped nothing");
        assert!(
            noc > iface && noc > hwa,
            "fastest clock should dominate: noc={noc} iface={iface} hwa={hwa}"
        );
    }

    /// The tentpole's new regime: while an invocation is mid-flight the
    /// system is never *fully* idle, yet per-domain horizons still skip
    /// edges (e.g. NoC edges while an HWA pipeline stage runs). The old
    /// all-or-nothing scheduler skipped zero edges on a closed-loop burst
    /// with back-to-back work; the per-domain one must not.
    #[test]
    fn horizons_skip_edges_during_mid_flight_work() {
        let mut rt = one_hwa_runtime(NetKind::Noc, FabricKind::Buffered);
        let izigzag = rt.accel(1).unwrap();
        for core in 0..rt.n_cores() {
            rt.submit(core, Job::on(izigzag).direct((0..64).collect()))
                .unwrap();
        }
        assert!(rt.run_until_done(100_000_000));
        let sys = rt.system();
        // The all-or-nothing scheduler skipped exactly zero edges here:
        // with requests queued in the RB the fabric is never quiescent
        // before the run completes. Any skipping at all is the horizons'.
        assert!(
            sys.edges_skipped > 0,
            "per-domain horizons found nothing to skip mid-flight"
        );
        let (noc, _, _) = sys.edges_skipped_breakdown();
        assert!(noc > 0, "the NoC domain should skip during HWA stages");
    }

    // ------------------------------------------------------------------
    // Floorplanned (multi-fabric / multi-MMU) systems
    // ------------------------------------------------------------------

    fn two_fabric_config() -> SystemConfig {
        let plan = Floorplan::parse("F0 P P / P M P / P P F1").unwrap();
        SystemConfig::floorplanned(
            plan,
            vec![
                FabricSpec::paper(vec![spec_by_name("izigzag").unwrap(); 2]),
                FabricSpec::paper(vec![spec_by_name("dfadd").unwrap()]),
            ],
        )
    }

    #[test]
    fn two_fabrics_execute_independently() {
        let mut rt = AccelRuntime::new(two_fabric_config());
        let iz = rt.accel_on(0, 0).unwrap();
        let df = rt.accel_on(1, 0).unwrap();
        rt.submit(0, Job::on(iz).direct((0..64).collect())).unwrap();
        rt.submit(1, Job::on(df).direct(vec![1, 2, 3, 4])).unwrap();
        assert!(rt.run_until_done(100_000_000));
        let sys = rt.system();
        assert_eq!(sys.n_fabrics(), 2);
        assert_eq!(sys.fabric_at(0).tasks_executed(), 1);
        assert_eq!(sys.fabric_at(1).tasks_executed(), 1);
        assert_eq!(sys.tasks_executed(), 2, "totals sum across fabrics");
        let rows = sys.per_fabric_stats();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].node, 0);
        assert_eq!(rows[1].node, 8);
        assert!(rows.iter().all(|r| r.rejected_flits == 0));
        assert!(rows.iter().all(|r| r.flits_to_noc > 0));
    }

    #[test]
    fn axi_with_two_fabrics_is_a_typed_error() {
        let mut cfg = two_fabric_config();
        cfg.net = NetKind::Axi;
        assert_eq!(
            System::try_new(cfg).err(),
            Some(TopologyError::AxiMultiFabric { fabrics: 2 })
        );
    }

    #[test]
    fn fabric_spec_count_must_match_the_plan() {
        let mut cfg = two_fabric_config();
        cfg.fabrics.pop();
        assert_eq!(
            System::try_new(cfg).err(),
            Some(TopologyError::FabricCountMismatch { plan: 2, specs: 1 })
        );
    }

    #[test]
    fn chain_group_members_are_range_checked() {
        let mut cfg = SystemConfig::paper(vec![
            spec_by_name("izigzag").unwrap();
            2
        ]);
        cfg.fabrics[0].chain_groups = vec![vec![0, 5]];
        assert_eq!(
            System::try_new(cfg).err(),
            Some(TopologyError::ChainGroupOutOfRange {
                fabric: 0,
                member: 5
            })
        );
    }

    #[test]
    fn reconfigurable_slot_indices_are_range_checked() {
        let mut cfg = SystemConfig::paper(vec![
            spec_by_name("izigzag").unwrap();
            2
        ]);
        cfg.fabrics[0].reconfigurable = vec![0, 7];
        assert_eq!(
            System::try_new(cfg).err(),
            Some(TopologyError::ReconfigSlotOutOfRange {
                fabric: 0,
                slot: 7
            })
        );
    }

    #[test]
    fn oversized_inventory_is_rejected_by_the_resource_budget() {
        // Four `prime` cores (161237 LUTs each) blow the xc7vx690t's
        // 433200-LUT budget long before the interface is counted.
        let cfg = SystemConfig::paper(vec![
            spec_by_name("prime").unwrap();
            4
        ]);
        match System::try_new(cfg).err() {
            Some(TopologyError::ResourceBudget { fabric: 0, luts, .. }) => {
                assert!(luts > crate::fpga::hwa::DEVICE_LUTS);
            }
            other => panic!("expected ResourceBudget, got {other:?}"),
        }
    }

    #[test]
    fn manual_reconfig_requires_a_declared_slot_and_budget() {
        let mut cfg = SystemConfig::paper(vec![
            spec_by_name("izigzag").unwrap();
            2
        ]);
        cfg.fabrics[0].reconfigurable = vec![1];
        let mut sys = System::new(cfg);
        let gsm = spec_by_name("gsm").unwrap();
        assert!(
            sys.request_reconfig(0, 0, gsm.clone(), 1000).is_err(),
            "slot 0 was not declared reconfigurable"
        );
        sys.request_reconfig(0, 1, gsm.clone(), 1000).unwrap();
        assert!(
            sys.request_reconfig(0, 1, gsm, 1000).is_err(),
            "second request on a slot already mid-swap must fail"
        );
        assert!(sys.slot_reconfiguring(0, 1));
        assert!(!sys.slot_reconfiguring(0, 0));
        sys.run_for(5 * crate::clock::PS_PER_US);
        assert!(!sys.slot_reconfiguring(0, 1), "swap landed");
        assert_eq!(sys.config.fabrics[0].specs[1].name, "gsm");
        let (swaps, drain, _blocked) = sys.reconfig_stats();
        assert_eq!(swaps, 1);
        assert!(drain > 0);
    }

    #[test]
    fn too_small_mesh_is_rejected_with_a_clear_error() {
        let cfg = SystemConfig::single(
            MeshConfig {
                width: 1,
                height: 2,
                ..MeshConfig::default()
            },
            FabricSpec::paper(vec![spec_by_name("dfadd").unwrap()]),
        );
        let err = System::try_new(cfg).unwrap_err();
        assert_eq!(err, TopologyError::NoProcessors);
        assert!(err.to_string().contains("no processor"));
    }

    #[test]
    fn multi_fabric_open_loop_drives_both_fabrics() {
        let mut sys = System::new(two_fabric_config());
        sys.set_open_loop(2.0, 13);
        sys.run_for(40 * crate::clock::PS_PER_US);
        let rows = sys.per_fabric_stats();
        assert!(
            rows[0].flits_from_noc > 0 && rows[1].flits_from_noc > 0,
            "both fabrics should see traffic: {rows:?}"
        );
        assert!(sys.open_loop_completions() > 0);
    }

    fn serving_tenants(n: u16, rate_each: f64) -> Vec<TenantSpec> {
        use crate::workload::serving::{ArrivalProcess, JobMix};
        (0..n)
            .map(|t| TenantSpec {
                id: t,
                rate_per_us: rate_each,
                arrival: if t % 2 == 0 {
                    ArrivalProcess::Poisson
                } else {
                    ArrivalProcess::Bursty {
                        burst_factor: 4.0,
                        mean_on_us: 2.0,
                    }
                },
                priority: 3 - (t % 4) as u8,
                mix: JobMix {
                    direct: 2,
                    via_memory: 1,
                    chained: 0,
                },
                slo_ps: 20 * crate::clock::PS_PER_US,
                phases: None,
            })
            .collect()
    }

    #[test]
    fn serving_sources_complete_mixed_jobs_end_to_end() {
        let cfg = SystemConfig::paper(vec![
            spec_by_name("izigzag").unwrap();
            2
        ]);
        let mut sys = System::new(cfg);
        sys.set_serving(&serving_tenants(4, 0.5), true, 32, 21);
        sys.run_for(60 * crate::clock::PS_PER_US);
        let done = sys.serving_completions();
        assert!(done > 20, "completions {done}");
        for src in sys.serving_sources.iter().flatten() {
            assert_eq!(src.unmatched, 0, "every completion tag matched");
            for t in &src.tenants {
                assert!(t.completed > 0, "tenant {} starved", t.spec.id);
            }
        }
    }

    /// Idle skipping must be invisible to every serving observable:
    /// arrivals, admission decisions, completions and latency samples.
    #[test]
    fn idle_skip_matches_per_edge_stepping_serving() {
        let observe = |skip: bool| {
            let cfg = SystemConfig::paper(vec![
                spec_by_name("izigzag").unwrap();
                2
            ]);
            let mut sys = System::new(cfg);
            sys.set_idle_skip(skip);
            sys.set_serving(&serving_tenants(3, 0.4), true, 32, 5);
            sys.run_for(40 * crate::clock::PS_PER_US);
            sys.serving_sources
                .iter()
                .flatten()
                .map(|s| {
                    let tenants: Vec<_> = s
                        .tenants
                        .iter()
                        .map(|t| {
                            (
                                t.arrivals,
                                t.admitted,
                                t.shed_bucket,
                                t.shed_watermark,
                                t.completed,
                                t.slo_violations,
                                t.latencies_ps.clone(),
                            )
                        })
                        .collect();
                    (s.requests_issued, s.results_done, tenants)
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(observe(true), observe(false));
    }

    #[test]
    fn hashed_and_nearest_mmu_assignment_both_complete_memory_jobs() {
        for assign in [MmuAssign::Nearest, MmuAssign::Hashed] {
            let plan = Floorplan::parse("P M P / P F0 P / P M P").unwrap();
            let mut cfg = SystemConfig::floorplanned(
                plan,
                vec![FabricSpec::paper(vec![
                    spec_by_name("izigzag").unwrap(),
                ])],
            );
            cfg.mmu_assign = assign;
            let mut rt = AccelRuntime::new(cfg);
            // Stage the input in the MMU assigned to core 0 (src 0).
            let sys = rt.system();
            assert_eq!(sys.n_mmus(), 2);
            let assigned = sys.mmu_node_for_src(0);
            let idx = sys
                .mmus()
                .iter()
                .position(|m| m.node as usize == assigned)
                .unwrap();
            let words: Vec<u32> = (0..64).collect();
            rt.system_mut().mmu_at_mut(idx).dram.write_words(0x100, &words);
            let h = rt.accel(0).unwrap();
            rt.submit(0, Job::on(h).via_memory(0x100, 256)).unwrap();
            assert!(rt.run_until_done(100_000_000), "{assign:?}");
            let sys = rt.system();
            assert_eq!(sys.mmus()[idx].stats.grants_decoded, 1, "{assign:?}");
            assert_eq!(sys.mmus()[idx].stats.results_written, 1, "{assign:?}");
            assert_eq!(sys.tasks_executed(), 1);
        }
    }
}
