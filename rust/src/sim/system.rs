//! Full-system assembly (paper Fig. 1): CMP cores + interconnect + FPGA
//! fabric + MMU, driven by a multi-domain clock. Three prototypes are
//! expressible (§6.7/§6.8): NoC + distributed buffers (the proposal),
//! AXI4 bus + distributed buffers, and NoC + shared FPGA cache.

use crate::baseline::axi::AxiBus;
use crate::baseline::shared_cache::CacheFpga;
use crate::clock::{Activity, ClockDomain, DomainId, MultiClock, Ps};
use crate::cmp::core::{Processor, Segment};
use crate::flit::Flit;
use crate::fpga::fabric::{Fpga, FpgaConfig};
use crate::fpga::hwa::{HwaCompute, HwaSpec};
use crate::mem::mmu::Mmu;
use crate::noc::mesh::{Mesh, MeshConfig};

/// Interconnect selection (Fig. 13/14's three prototypes use Noc or Axi).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetKind {
    Noc,
    Axi,
}

/// FPGA-side architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricKind {
    /// The paper's proposal: distributed TB/POB/CB buffers.
    Buffered,
    /// §6.8 baseline: shared system cache, given capacity in bytes.
    SharedCache { cache_bytes: u32 },
}

#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub mesh: MeshConfig,
    pub net: NetKind,
    pub fabric: FabricKind,
    pub n_tbs: usize,
    pub pr_group: usize,
    pub ps_group: usize,
    pub iface_mhz: f64,
    pub specs: Vec<HwaSpec>,
    pub chain_groups: Vec<Vec<usize>>,
}

impl SystemConfig {
    /// Paper defaults: 3x3 mesh, NoC, buffered fabric, 2 TBs, PR4-PS4.
    pub fn paper(specs: Vec<HwaSpec>) -> Self {
        Self {
            mesh: MeshConfig::default(),
            net: NetKind::Noc,
            fabric: FabricKind::Buffered,
            n_tbs: 2,
            pr_group: 4,
            ps_group: 4,
            iface_mhz: 300.0,
            specs,
            chain_groups: Vec::new(),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.mesh.width as usize * self.mesh.height as usize
    }

    /// FPGA sits at the last node, MMU beside it; processors elsewhere.
    pub fn fpga_node(&self) -> usize {
        self.n_nodes() - 1
    }

    pub fn mmu_node(&self) -> usize {
        self.n_nodes() - 2
    }

    pub fn proc_nodes(&self) -> Vec<usize> {
        (0..self.n_nodes())
            .filter(|n| *n != self.fpga_node() && *n != self.mmu_node())
            .collect()
    }
}

pub enum Net {
    Noc(Mesh),
    Axi(AxiBus),
}

impl Net {
    fn can_inject(&self, node: usize) -> bool {
        match self {
            Net::Noc(m) => m.can_inject(node),
            Net::Axi(b) => b.can_inject(node),
        }
    }

    fn try_inject(&mut self, node: usize, flit: Flit) -> bool {
        match self {
            Net::Noc(m) => m.try_inject(node, flit),
            Net::Axi(b) => b.try_inject(node, flit),
        }
    }

    fn eject_pop(&mut self, node: usize) -> Option<Flit> {
        match self {
            Net::Noc(m) => m.eject_pop(node),
            Net::Axi(b) => b.eject_pop(node),
        }
    }

    fn eject_peek_some(&self, node: usize) -> bool {
        match self {
            Net::Noc(m) => m.eject_peek(node).is_some(),
            Net::Axi(b) => b.eject_len(node) > 0,
        }
    }

    fn step(&mut self) {
        match self {
            Net::Noc(m) => m.step(),
            Net::Axi(b) => b.step(),
        }
    }

    fn idle(&self) -> bool {
        match self {
            Net::Noc(m) => m.idle(),
            Net::Axi(b) => b.idle(),
        }
    }

    /// Fold `n` provably-idle cycles into the interconnect's statistics
    /// (the idle-skipping scheduler fast-forwarded past them).
    fn account_idle_cycles(&mut self, n: u64) {
        match self {
            Net::Noc(m) => m.account_idle_cycles(n),
            Net::Axi(b) => b.account_idle_cycles(n),
        }
    }
}

pub enum Fabric {
    Buffered(Fpga),
    Cached(CacheFpga),
}

impl Fabric {
    pub fn can_accept_from_noc(&self) -> bool {
        match self {
            Fabric::Buffered(f) => f.can_accept_from_noc(),
            Fabric::Cached(f) => f.can_accept_from_noc(),
        }
    }

    pub fn push_from_noc(&mut self, now: Ps, flit: Flit) {
        match self {
            Fabric::Buffered(f) => f.push_from_noc(now, flit),
            Fabric::Cached(f) => f.push_from_noc(now, flit),
        }
    }

    pub fn pop_to_noc(&mut self, now: Ps) -> Option<Flit> {
        match self {
            Fabric::Buffered(f) => f.pop_to_noc(now),
            Fabric::Cached(f) => f.pop_to_noc(now),
        }
    }

    pub fn step_iface(&mut self, now: Ps) {
        match self {
            Fabric::Buffered(f) => f.step_iface(now),
            Fabric::Cached(f) => f.step_iface(now),
        }
    }

    pub fn tasks_executed(&self) -> u64 {
        match self {
            Fabric::Buffered(f) => f.tasks_executed(),
            Fabric::Cached(f) => f.tasks_executed(),
        }
    }

    pub fn flits_in_out(&self) -> (u64, u64) {
        match self {
            Fabric::Buffered(f) => (f.stats.flits_from_noc, f.stats.flits_to_noc),
            Fabric::Cached(f) => (f.stats.flits_from_noc, f.stats.flits_to_noc),
        }
    }

    /// (busy interface cycles, total interface cycles) — the busy-fraction
    /// numerator/denominator. The shared-cache baseline keeps no per-HWA
    /// busy accounting, so it reports (0, 1).
    pub fn iface_busy(&self) -> (u64, u64) {
        match self {
            Fabric::Buffered(f) => {
                (f.stats.busy_iface_cycles, f.stats.iface_cycles)
            }
            Fabric::Cached(_) => (0, 1),
        }
    }

    pub fn buffered(&self) -> Option<&Fpga> {
        match self {
            Fabric::Buffered(f) => Some(f),
            _ => None,
        }
    }

    pub fn buffered_mut(&mut self) -> Option<&mut Fpga> {
        match self {
            Fabric::Buffered(f) => Some(f),
            _ => None,
        }
    }

    pub fn set_compute(&mut self, compute: Box<dyn HwaCompute>) {
        match self {
            Fabric::Buffered(f) => f.set_compute(compute),
            Fabric::Cached(f) => f.set_compute(compute),
        }
    }

    pub fn quiescent(&self, now: Ps) -> bool {
        match self {
            Fabric::Buffered(f) => f.quiescent(now),
            Fabric::Cached(f) => f.quiescent(),
        }
    }

    /// Fold `n` skipped interface-clock cycles into the fabric's counters
    /// so busy-fraction denominators match naive per-edge stepping.
    pub fn account_idle_iface_cycles(&mut self, n: u64) {
        match self {
            Fabric::Buffered(f) => f.account_idle_iface_cycles(n),
            Fabric::Cached(_) => {}
        }
    }

    /// Malformed/over-capacity flits dropped by the channels (summed
    /// across HWAs; the shared-cache baseline keeps no such counter).
    pub fn rejected_flits(&self) -> u64 {
        match self {
            Fabric::Buffered(f) => f
                .channels
                .iter()
                .map(|c| c.stats.rejected_flits)
                .sum(),
            Fabric::Cached(_) => 0,
        }
    }

    /// Flits queued toward the interconnect: NoC-domain scheduler probe.
    pub fn noc_tx_pending(&self) -> bool {
        match self {
            Fabric::Buffered(f) => f.noc_tx_pending(),
            Fabric::Cached(f) => f.noc_tx_pending(),
        }
    }

    /// Interface-domain scheduler probe. The shared-cache baseline drives
    /// everything from the interface clock, so it is busy whenever it is
    /// not fully quiescent.
    pub fn iface_activity(&self) -> Activity {
        match self {
            Fabric::Buffered(f) => f.iface_activity(),
            Fabric::Cached(f) => {
                if f.quiescent() {
                    Activity::Idle
                } else {
                    Activity::Busy
                }
            }
        }
    }

    /// Scheduler probe for one HWA clock domain (buffered fabric only —
    /// the shared-cache baseline registers no HWA domains).
    pub fn hwa_activity(&self, chans: &[usize]) -> Activity {
        match self {
            Fabric::Buffered(f) => f.hwa_domain_activity(chans),
            Fabric::Cached(_) => Activity::Idle,
        }
    }

    /// Fold skipped HWA-clock edges into the owning channels' counters.
    pub fn account_idle_hwa_cycles(&mut self, chans: &[usize], n: u64) {
        match self {
            Fabric::Buffered(f) => f.account_idle_hwa_cycles(chans, n),
            Fabric::Cached(_) => {}
        }
    }
}

pub struct System {
    pub config: SystemConfig,
    pub clk: MultiClock,
    noc_dom: DomainId,
    iface_dom: DomainId,
    hwa_doms: Vec<(DomainId, Vec<usize>)>,
    pub net: Net,
    pub fabric: Fabric,
    pub procs: Vec<Processor>,
    /// Open-loop traffic sources replacing processors (per slot) for the
    /// §6.4 injection-rate experiments.
    pub open_sources: Vec<Option<crate::workload::openloop::OpenLoopSource>>,
    pub mmu: Mmu,
    ticking: Vec<DomainId>,
    /// Idle-skipping event-driven scheduling (on by default). Each clock
    /// domain reports an [`Activity`] horizon every step; the scheduler
    /// fast-forwards all domains to the earliest instant anything can
    /// happen — a busy domain's next edge, a reported `next_event_at`, or
    /// the caller's deadline — instead of ticking provably no-op edges.
    idle_skip: bool,
    skip_scratch: Vec<u64>,
    /// Clock edges actually dispatched (skipped edges excluded) — the
    /// scheduler's work metric, used by perf tests and hotpath_micro.
    pub edges_stepped: u64,
    /// Clock edges the idle-skipping scheduler proved no-ops and
    /// fast-forwarded past (summed over all domains) — reported per
    /// scenario by `sweep::RunStats`.
    pub edges_skipped: u64,
    /// Per-domain breakdown of `edges_skipped`, indexed by `DomainId`
    /// (surfaced through [`System::edges_skipped_breakdown`]).
    edges_skipped_by: Vec<u64>,
}

impl System {
    pub fn new(config: SystemConfig) -> Self {
        let mut clk = MultiClock::new();
        let noc_clock = ClockDomain::from_mhz("noc+cmp", 1000.0);
        let noc_dom = clk.add(noc_clock.clone());
        let fpga_node = config.fpga_node() as u8;
        let mmu_node = config.mmu_node() as u8;
        // src_id (3 bits) -> node map for replies.
        let proc_nodes = config.proc_nodes();
        let mut reply_route = vec![0u8; 8];
        for (i, n) in proc_nodes.iter().enumerate().take(8) {
            reply_route[i] = *n as u8;
        }
        let fabric = match config.fabric {
            FabricKind::Buffered => {
                let fcfg = FpgaConfig {
                    n_tbs: config.n_tbs,
                    pr: crate::fpga::PrStrategy::distributed(config.pr_group),
                    ps: crate::fpga::PsStrategy::hierarchical(
                        config.ps_group.min(config.specs.len().max(1)),
                    ),
                    iface_mhz: config.iface_mhz,
                    node: fpga_node,
                    mmu_node,
                    reply_route: reply_route.clone(),
                };
                let mut f = Fpga::new(fcfg, config.specs.clone(), &noc_clock);
                for g in &config.chain_groups {
                    f.add_chain_group(g.clone());
                }
                Fabric::Buffered(f)
            }
            FabricKind::SharedCache { cache_bytes } => Fabric::Cached(
                CacheFpga::new(
                    fpga_node,
                    mmu_node,
                    reply_route.clone(),
                    config.specs.clone(),
                    cache_bytes,
                    &noc_clock,
                ),
            ),
        };
        let iface_dom = clk.add(match &fabric {
            Fabric::Buffered(f) => f.iface_clock.clone(),
            Fabric::Cached(f) => f.iface_clock.clone(),
        });
        let hwa_doms = match &fabric {
            Fabric::Buffered(f) => f
                .hwa_domains()
                .into_iter()
                .enumerate()
                .map(|(i, (p, chans))| {
                    let d = clk.add(ClockDomain {
                        name: format!("hwa{i}"),
                        period_ps: p,
                        phase_ps: 0,
                    });
                    (d, chans)
                })
                .collect(),
            Fabric::Cached(_) => Vec::new(),
        };
        let net = match config.net {
            NetKind::Noc => Net::Noc(Mesh::new(config.mesh.clone())),
            NetKind::Axi => {
                Net::Axi(AxiBus::new(config.n_nodes(), config.fpga_node()))
            }
        };
        let procs = proc_nodes
            .iter()
            .enumerate()
            .take(8)
            .map(|(i, n)| {
                Processor::new(i as u8, *n as u8, fpga_node, Vec::new())
            })
            .collect();
        let mmu = Mmu::new(mmu_node, fpga_node, noc_clock.period_ps);
        let n_procs = proc_nodes.len().min(8);
        let n_domains = clk.n_domains();
        Self {
            config,
            clk,
            noc_dom,
            iface_dom,
            hwa_doms,
            net,
            fabric,
            procs,
            open_sources: (0..n_procs).map(|_| None).collect(),
            mmu,
            ticking: Vec::new(),
            idle_skip: true,
            skip_scratch: Vec::new(),
            edges_stepped: 0,
            edges_skipped: 0,
            edges_skipped_by: vec![0; n_domains],
        }
    }

    /// Enable/disable the idle-skipping scheduler (enabled by default).
    /// Disabling forces naive per-edge stepping; per-task latency records
    /// are identical either way (rust/tests/event_driven.rs proves it).
    pub fn set_idle_skip(&mut self, on: bool) {
        self.idle_skip = on;
    }

    /// Replace every processor with an open-loop source at the given
    /// aggregate request rate (requests/µs across all sources).
    pub fn set_open_loop(&mut self, total_rate_per_us: f64, seed: u64) {
        let n = self.procs.len();
        let fpga_node = self.config.fpga_node() as u8;
        for i in 0..n {
            self.open_sources[i] =
                Some(crate::workload::openloop::OpenLoopSource::new(
                    i as u8,
                    self.procs[i].node,
                    fpga_node,
                    self.config.specs.clone(),
                    total_rate_per_us / n as f64,
                    seed,
                ));
        }
    }

    /// Total completed invocations across open-loop sources.
    pub fn open_loop_completions(&self) -> u64 {
        self.open_sources
            .iter()
            .flatten()
            .map(|s| s.results_done)
            .sum()
    }

    /// Load a program onto processor `i`.
    pub fn load_program(&mut self, i: usize, program: Vec<Segment>) {
        for seg in program {
            self.procs[i].enqueue(seg);
        }
    }

    pub fn now(&self) -> Ps {
        self.clk.now()
    }

    /// Activity probe for the NoC+CMP clock domain: the interconnect, the
    /// fabric's NoC-facing FIFO, the MMU and every processor / open-loop
    /// source all act on NoC edges. `Busy` while any of them holds
    /// in-flight work; otherwise the earliest self-scheduled event (DMA
    /// completion, Poisson arrival) bounds the domain's horizon.
    fn noc_domain_activity(&self) -> Activity {
        if !self.net.idle() || self.fabric.noc_tx_pending() {
            return Activity::Busy;
        }
        let mut act = self.mmu.activity();
        if act == Activity::Busy {
            return act;
        }
        for (i, p) in self.procs.iter().enumerate() {
            let a = match self.open_sources[i].as_ref() {
                Some(src) => src.activity(),
                None => p.activity(),
            };
            act = act.join(a);
            if act == Activity::Busy {
                return act;
            }
        }
        act
    }

    /// Per-domain event horizons (the ISSUE 4 tentpole). Each clock
    /// domain reports an [`Activity`]: the skip target is the earliest of
    /// every busy domain's next edge, every reported `next_event_at`, and
    /// the caller's deadline. Skipping all edges strictly before that
    /// target is sound because cross-domain work can only be injected at
    /// a dispatched edge, and no dispatched edge precedes the target; the
    /// skipped cycles are folded into each domain's cycle accounting so
    /// every statistic matches naive per-edge stepping (the
    /// `rust/tests/event_driven.rs` property and the ci_smoke neutrality
    /// test in `rust/tests/sweep.rs` enforce this).
    fn skip_idle(&mut self, deadline: Option<Ps>) {
        if !self.idle_skip {
            return;
        }
        let now = self.clk.now();
        if now == 0 {
            return;
        }
        fn fold(target: &mut Option<Ps>, t: Ps) {
            *target = Some(target.map_or(t, |x| x.min(t)));
        }
        let mut target: Option<Ps> = None;
        match self.noc_domain_activity() {
            Activity::Busy => fold(&mut target, self.clk.next_edge_of(self.noc_dom)),
            Activity::Idle => {}
            Activity::NextEventAt(t) => fold(&mut target, t),
        }
        match self.fabric.iface_activity() {
            Activity::Busy => fold(&mut target, self.clk.next_edge_of(self.iface_dom)),
            Activity::Idle => {}
            Activity::NextEventAt(t) => fold(&mut target, t),
        }
        for (d, chans) in &self.hwa_doms {
            match self.fabric.hwa_activity(chans) {
                Activity::Busy => fold(&mut target, self.clk.next_edge_of(*d)),
                Activity::Idle => {}
                Activity::NextEventAt(t) => fold(&mut target, t),
            }
        }
        let target = match (target, deadline) {
            (Some(t), Some(d)) => t.min(d),
            (Some(t), None) => t,
            (None, Some(d)) => d,
            // Every domain idle, nothing scheduled, no deadline: there is
            // no provable horizon to skip to.
            (None, None) => return,
        };
        if target <= now {
            return;
        }
        let mut skipped = std::mem::take(&mut self.skip_scratch);
        self.clk.skip_until(target, &mut skipped);
        let n = skipped[self.noc_dom.0];
        if n > 0 {
            self.net.account_idle_cycles(n);
            // Processors (when not replaced by open-loop sources) count
            // every NoC edge in `total_cycles` even while awaiting; fold
            // the skipped ones in so the counter matches naive stepping.
            for (i, p) in self.procs.iter_mut().enumerate() {
                if self.open_sources[i].is_none() {
                    p.account_idle_cycles(n);
                }
            }
        }
        let n = skipped[self.iface_dom.0];
        if n > 0 {
            self.fabric.account_idle_iface_cycles(n);
        }
        for (d, chans) in &self.hwa_doms {
            let n = skipped[d.0];
            if n > 0 {
                self.fabric.account_idle_hwa_cycles(chans, n);
            }
        }
        for (i, n) in skipped.iter().enumerate() {
            self.edges_skipped += *n;
            self.edges_skipped_by[i] += *n;
        }
        self.skip_scratch = skipped;
    }

    /// Skipped-edge counts as (NoC+CMP, fabric interface, all HWA
    /// domains) — the per-domain breakdown `sweep::RunStats` reports.
    pub fn edges_skipped_breakdown(&self) -> (u64, u64, u64) {
        let noc = self.edges_skipped_by[self.noc_dom.0];
        let iface = self.edges_skipped_by[self.iface_dom.0];
        let hwa = self
            .hwa_doms
            .iter()
            .map(|(d, _)| self.edges_skipped_by[d.0])
            .sum();
        (noc, iface, hwa)
    }

    /// Advance the whole system by one clock event, first fast-forwarding
    /// past every edge the per-domain horizons prove to be a no-op.
    pub fn step(&mut self) -> Ps {
        self.skip_idle(None);
        self.step_edge()
    }

    /// Dispatch exactly one clock event (no idle skipping).
    fn step_edge(&mut self) -> Ps {
        self.edges_stepped += 1;
        let mut ticking = std::mem::take(&mut self.ticking);
        let t = self.clk.advance(&mut ticking);
        for d in &ticking {
            if *d == self.noc_dom {
                self.step_noc_domain(t);
            } else if *d == self.iface_dom {
                self.fabric.step_iface(t);
            } else if let Some((_, chans)) =
                self.hwa_doms.iter().find(|(dd, _)| dd == d)
            {
                if let Fabric::Buffered(f) = &mut self.fabric {
                    for i in chans {
                        f.step_channel(*i, t);
                    }
                }
            }
        }
        self.ticking = ticking;
        t
    }

    fn step_noc_domain(&mut self, t: Ps) {
        let fpga_node = self.config.fpga_node();
        let mmu_node = self.config.mmu_node();
        // FPGA <-> net exchange.
        while self.fabric.can_accept_from_noc()
            && self.net.eject_peek_some(fpga_node)
        {
            let f = self.net.eject_pop(fpga_node).expect("peeked");
            self.fabric.push_from_noc(t, f);
        }
        if self.net.can_inject(fpga_node) {
            if let Some(f) = self.fabric.pop_to_noc(t) {
                let ok = self.net.try_inject(fpga_node, f);
                debug_assert!(ok);
            }
        }
        // MMU.
        while let Some(f) = self.net.eject_pop(mmu_node) {
            self.mmu.deliver(f, t);
        }
        let can = self.net.can_inject(mmu_node);
        if let Some(f) = self.mmu.step(t, can) {
            let ok = self.net.try_inject(mmu_node, f);
            debug_assert!(ok);
        }
        // Processors (or their open-loop replacements).
        for (i, p) in self.procs.iter_mut().enumerate() {
            let node = p.node as usize;
            if let Some(src) = self.open_sources[i].as_mut() {
                while let Some(f) = self.net.eject_pop(node) {
                    src.deliver(f, t);
                }
                let can = self.net.can_inject(node);
                if let Some(f) = src.step(t, can) {
                    let ok = self.net.try_inject(node, f);
                    debug_assert!(ok);
                }
                continue;
            }
            while let Some(f) = self.net.eject_pop(node) {
                p.deliver(f, t);
            }
            let can = self.net.can_inject(node);
            if let Some(f) = p.step(t, can) {
                let ok = self.net.try_inject(node, f);
                debug_assert!(ok);
            }
        }
        // Advance the interconnect itself.
        self.net.step();
    }

    /// Run until every processor's program completes (or deadline).
    /// Returns true on completion. The completion check fires before any
    /// idle skip, so `now()` on success is the drain time, not the
    /// deadline; a deadlocked-idle system fast-forwards to the deadline.
    pub fn run_until_done(&mut self, deadline_ps: Ps) -> bool {
        while self.clk.now() < deadline_ps {
            self.skip_idle(Some(deadline_ps));
            self.step_edge();
            if self.procs.iter().all(|p| p.done())
                && self.net.idle()
                && self.mmu.idle()
                && self.fabric.quiescent(self.clk.now())
            {
                return true;
            }
        }
        false
    }

    /// Run for a fixed window.
    pub fn run_for(&mut self, window_ps: Ps) {
        let end = self.clk.now() + window_ps;
        while self.clk.now() < end {
            self.skip_idle(Some(end));
            self.step_edge();
        }
    }

    pub fn n_procs(&self) -> usize {
        self.procs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{AccelRuntime, Job};
    use crate::fpga::hwa::spec_by_name;

    fn one_hwa_runtime(net: NetKind, fabric: FabricKind) -> AccelRuntime {
        let mut cfg = SystemConfig::paper(vec![
            spec_by_name("dfadd").unwrap(),
            spec_by_name("izigzag").unwrap(),
        ]);
        cfg.net = net;
        cfg.fabric = fabric;
        AccelRuntime::new(cfg)
    }

    #[test]
    fn full_system_single_invocation_noc() {
        let mut rt = one_hwa_runtime(NetKind::Noc, FabricKind::Buffered);
        let dfadd = rt.accel(0).unwrap();
        let receipt = rt
            .submit(0, Job::on(dfadd).direct(vec![1, 2, 3, 4]))
            .unwrap();
        assert!(rt.run_until_done(50_000_000), "completed within 50 µs");
        let done = rt.poll(receipt).expect("recorded");
        let r = done.record();
        assert!(r.t_grant > r.t_request);
        assert!(r.t_result_last > r.t_grant);
        assert_eq!(rt.system().fabric.tasks_executed(), 1);
        // dfadd of (1,2)+(3,4) via native/echo compute: result delivered.
        assert_eq!(rt.last_result(0).len(), 2);
    }

    #[test]
    fn full_system_single_invocation_axi() {
        let mut rt = one_hwa_runtime(NetKind::Axi, FabricKind::Buffered);
        let dfadd = rt.accel(0).unwrap();
        rt.submit(0, Job::on(dfadd).direct(vec![1, 2, 3, 4])).unwrap();
        assert!(rt.run_until_done(50_000_000));
        assert_eq!(rt.system().fabric.tasks_executed(), 1);
    }

    #[test]
    fn full_system_single_invocation_shared_cache() {
        let mut rt = one_hwa_runtime(
            NetKind::Noc,
            FabricKind::SharedCache {
                cache_bytes: 64 * 1024,
            },
        );
        let dfadd = rt.accel(0).unwrap();
        rt.submit(0, Job::on(dfadd).direct(vec![1, 2, 3, 4])).unwrap();
        assert!(rt.run_until_done(50_000_000));
        assert_eq!(rt.system().fabric.tasks_executed(), 1);
    }

    #[test]
    fn seven_processors_share_one_hwa() {
        let mut rt = one_hwa_runtime(NetKind::Noc, FabricKind::Buffered);
        let izigzag = rt.accel(1).unwrap();
        let n = rt.n_cores();
        for core in 0..n {
            rt.submit(core, Job::on(izigzag).direct((0..64).collect()))
                .unwrap();
        }
        assert!(rt.run_until_done(100_000_000));
        assert_eq!(rt.system().fabric.tasks_executed(), n as u64);
        assert_eq!(rt.completions().len(), n);
    }

    #[test]
    fn noc_latency_beats_axi_under_load() {
        // The Fig. 14 direction: with several processors invoking
        // concurrently (each its own HWA so the fabric doesn't serialize),
        // the shared bus becomes the bottleneck and loses.
        let run = |net| {
            let mut cfg = SystemConfig::paper(
                crate::fpga::hwa::table3().into_iter().take(7).collect(),
            );
            cfg.net = net;
            let mut rt = AccelRuntime::new(cfg);
            let n = rt.n_cores();
            for core in 0..n {
                let hwa = rt.accel(core as u8).unwrap();
                let words: Vec<u32> = (0..hwa.in_words() as u32).collect();
                rt.submit(core, Job::on(hwa).direct(words)).unwrap();
            }
            assert!(rt.run_until_done(400_000_000));
            rt.completions()
                .iter()
                .map(|c| c.total_ps() as f64)
                .sum::<f64>()
                / n as f64
        };
        let noc = run(NetKind::Noc);
        let axi = run(NetKind::Axi);
        assert!(
            axi > noc,
            "axi mean latency {axi} should exceed noc {noc}"
        );
    }

    /// Idle skipping must be invisible to every task-level observable:
    /// same completions, same latencies, same flit/cycle statistics.
    #[test]
    fn idle_skip_matches_per_edge_stepping_open_loop() {
        let observe = |skip: bool, net: NetKind| {
            let mut cfg = SystemConfig::paper(vec![
                spec_by_name("izigzag").unwrap();
                4
            ]);
            cfg.net = net;
            let mut sys = System::new(cfg);
            sys.set_idle_skip(skip);
            sys.set_open_loop(0.5, 9);
            sys.run_for(40 * crate::clock::PS_PER_US);
            let lat: Vec<(u64, u64, Vec<u64>)> = sys
                .open_sources
                .iter()
                .flatten()
                .map(|s| {
                    (s.requests_issued, s.results_done, s.latencies_ps.clone())
                })
                .collect();
            let (fin, fout) = sys.fabric.flits_in_out();
            (lat, fin, fout, sys.fabric.tasks_executed())
        };
        for net in [NetKind::Noc, NetKind::Axi] {
            assert_eq!(observe(true, net), observe(false, net), "{net:?}");
        }
    }

    /// The scheduler's whole point: a low-injection open-loop run must
    /// dispatch far fewer edges with skipping than per-edge stepping.
    #[test]
    fn idle_skip_reduces_dispatched_edges() {
        let edges = |skip: bool| {
            let cfg = SystemConfig::paper(vec![
                spec_by_name("izigzag").unwrap();
                4
            ]);
            let mut sys = System::new(cfg);
            sys.set_idle_skip(skip);
            sys.set_open_loop(0.25, 7);
            sys.run_for(100 * crate::clock::PS_PER_US);
            (sys.edges_stepped, sys.open_loop_completions())
        };
        let (skipped, done_s) = edges(true);
        let (naive, done_n) = edges(false);
        assert_eq!(done_s, done_n, "same work either way");
        assert!(
            skipped * 2 < naive,
            "idle skipping should cut dispatched edges >=2x: {skipped} vs {naive}"
        );
    }

    /// Skipped cycles are folded into the stats that feed busy fractions.
    #[test]
    fn idle_skip_preserves_cycle_accounting() {
        let cycles = |skip: bool| {
            let cfg = SystemConfig::paper(vec![
                spec_by_name("izigzag").unwrap();
                2
            ]);
            let mut sys = System::new(cfg);
            sys.set_idle_skip(skip);
            sys.set_open_loop(1.0, 3);
            sys.run_for(20 * crate::clock::PS_PER_US);
            let mesh_cycles = match &sys.net {
                Net::Noc(m) => m.cycles,
                Net::Axi(b) => b.cycles,
            };
            let iface_cycles = sys
                .fabric
                .buffered()
                .map(|f| f.stats.iface_cycles)
                .unwrap_or(0);
            (mesh_cycles, iface_cycles)
        };
        assert_eq!(cycles(true), cycles(false));
    }

    /// Per-domain event horizons: on a low-rate open loop every domain
    /// group skips edges, the breakdown sums to the total, and the 1 GHz
    /// NoC+CMP domain (the most frequent clock) dominates the savings.
    #[test]
    fn edges_skipped_breakdown_covers_all_domain_groups() {
        let cfg = SystemConfig::paper(vec![
            spec_by_name("izigzag").unwrap();
            4
        ]);
        let mut sys = System::new(cfg);
        sys.set_open_loop(0.5, 11);
        sys.run_for(50 * crate::clock::PS_PER_US);
        let (noc, iface, hwa) = sys.edges_skipped_breakdown();
        assert_eq!(noc + iface + hwa, sys.edges_skipped, "breakdown sums");
        assert!(noc > 0, "NoC domain skipped nothing");
        assert!(iface > 0, "interface domain skipped nothing");
        assert!(hwa > 0, "HWA domains skipped nothing");
        assert!(
            noc > iface && noc > hwa,
            "fastest clock should dominate: noc={noc} iface={iface} hwa={hwa}"
        );
    }

    /// The tentpole's new regime: while an invocation is mid-flight the
    /// system is never *fully* idle, yet per-domain horizons still skip
    /// edges (e.g. NoC edges while an HWA pipeline stage runs). The old
    /// all-or-nothing scheduler skipped zero edges on a closed-loop burst
    /// with back-to-back work; the per-domain one must not.
    #[test]
    fn horizons_skip_edges_during_mid_flight_work() {
        let mut rt = one_hwa_runtime(NetKind::Noc, FabricKind::Buffered);
        let izigzag = rt.accel(1).unwrap();
        for core in 0..rt.n_cores() {
            rt.submit(core, Job::on(izigzag).direct((0..64).collect()))
                .unwrap();
        }
        assert!(rt.run_until_done(100_000_000));
        let sys = rt.system();
        // The all-or-nothing scheduler skipped exactly zero edges here:
        // with requests queued in the RB the fabric is never quiescent
        // before the run completes. Any skipping at all is the horizons'.
        assert!(
            sys.edges_skipped > 0,
            "per-domain horizons found nothing to skip mid-flight"
        );
        let (noc, _, _) = sys.edges_skipped_breakdown();
        assert!(noc > 0, "the NoC domain should skip during HWA stages");
    }
}
