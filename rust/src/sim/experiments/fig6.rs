//! Fig. 6: total execution time vs. number of task buffers, for the two
//! extreme communication patterns — Dfdiv (long execution, small data)
//! and Izigzag (one-cycle execution, large data).
//!
//! Paper result: Dfdiv flat across TB counts; Izigzag improves ~28.4%
//! going from 1 to 2 TBs and is flat beyond.

use crate::clock::PS_PER_US;
use crate::cmp::core::{InvokeSpec, Segment};
use crate::fpga::hwa::spec_by_name;
use crate::sim::system::{System, SystemConfig};
use crate::util::table::Table;

/// Requests per processor issued back-to-back at the same HWA (§6.2:
/// "multiple requests for the same HWA ... from different processors
/// simultaneously").
const REQUESTS_PER_PROC: usize = 8;

pub struct Fig6Point {
    pub hwa: &'static str,
    pub n_tbs: usize,
    pub total_us: f64,
}

pub fn run_point(hwa: &'static str, n_tbs: usize) -> Fig6Point {
    let spec = spec_by_name(hwa).expect("known benchmark");
    let mut cfg = SystemConfig::paper(vec![spec.clone()]);
    cfg.n_tbs = n_tbs;
    let mut sys = System::new(cfg);
    for i in 0..sys.n_procs() {
        let prog: Vec<Segment> = (0..REQUESTS_PER_PROC)
            .map(|_| {
                Segment::Invoke(InvokeSpec::direct(
                    0,
                    (0..spec.in_words as u32).collect(),
                    spec.out_words,
                ))
            })
            .collect();
        sys.load_program(i, prog);
    }
    let done = sys.run_until_done(2_000 * PS_PER_US);
    assert!(done, "fig6 run did not drain ({hwa}, {n_tbs} TBs)");
    let total_us = sys
        .procs
        .iter()
        .filter_map(|p| p.finished_at)
        .max()
        .unwrap_or(0) as f64
        / PS_PER_US as f64;
    Fig6Point {
        hwa,
        n_tbs,
        total_us,
    }
}

pub struct Fig6 {
    pub points: Vec<Fig6Point>,
}

pub fn run() -> Fig6 {
    let mut points = Vec::new();
    for hwa in ["dfdiv", "izigzag"] {
        for n_tbs in 1..=4 {
            points.push(run_point(hwa, n_tbs));
        }
    }
    Fig6 { points }
}

impl Fig6 {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 6 — execution time vs number of task buffers",
            &["hwa", "task buffers", "total time (us)", "vs 1 TB"],
        );
        for hwa in ["dfdiv", "izigzag"] {
            let base = self
                .points
                .iter()
                .find(|p| p.hwa == hwa && p.n_tbs == 1)
                .map(|p| p.total_us)
                .unwrap_or(f64::NAN);
            for p in self.points.iter().filter(|p| p.hwa == hwa) {
                t.row(&[
                    p.hwa.to_string(),
                    p.n_tbs.to_string(),
                    format!("{:.2}", p.total_us),
                    format!("{:+.1}%", 100.0 * (p.total_us - base) / base),
                ]);
            }
        }
        t
    }

    pub fn improvement_1_to_2(&self, hwa: &str) -> f64 {
        let t1 = self
            .points
            .iter()
            .find(|p| p.hwa == hwa && p.n_tbs == 1)
            .unwrap()
            .total_us;
        let t2 = self
            .points
            .iter()
            .find(|p| p.hwa == hwa && p.n_tbs == 2)
            .unwrap()
            .total_us;
        100.0 * (t1 - t2) / t1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn izigzag_improves_with_second_tb_dfdiv_does_not() {
        let fig = run();
        let izz = fig.improvement_1_to_2("izigzag");
        let dfd = fig.improvement_1_to_2("dfdiv");
        assert!(
            izz > 10.0,
            "izigzag should gain >10% from 2 TBs, got {izz:.1}%"
        );
        assert!(
            dfd < 5.0,
            "dfdiv should gain <5% from 2 TBs, got {dfd:.1}%"
        );
    }

    #[test]
    fn no_further_gain_beyond_two_tbs() {
        let fig = run();
        let t2 = fig
            .points
            .iter()
            .find(|p| p.hwa == "izigzag" && p.n_tbs == 2)
            .unwrap()
            .total_us;
        let t4 = fig
            .points
            .iter()
            .find(|p| p.hwa == "izigzag" && p.n_tbs == 4)
            .unwrap()
            .total_us;
        let gain = 100.0 * (t2 - t4) / t2;
        assert!(gain < 6.0, "beyond 2 TBs gain should be small: {gain:.1}%");
    }
}
