//! Fig. 6: total execution time vs. number of task buffers, for the two
//! extreme communication patterns — Dfdiv (long execution, small data)
//! and Izigzag (one-cycle execution, large data).
//!
//! Paper result: Dfdiv flat across TB counts; Izigzag improves ~28.4%
//! going from 1 to 2 TBs and is flat beyond.
//!
//! The experiment is a [`sweep`](crate::sweep) grid: 2 HWAs x 4 TB
//! depths, each scenario a burst of back-to-back requests from every
//! processor (§6.2: "multiple requests for the same HWA ... from
//! different processors simultaneously").

use crate::sweep::{ScenarioSpec, SweepReport, SweepRunner, WorkloadSpec};
use crate::util::table::Table;

/// Requests per processor issued back-to-back at the same HWA.
const REQUESTS_PER_PROC: usize = 8;

/// The swept TB depths.
pub const TB_DEPTHS: [usize; 4] = [1, 2, 3, 4];

/// The two extreme-pattern benchmarks.
pub const HWAS: [&str; 2] = ["dfdiv", "izigzag"];

/// The Fig. 6 scenario grid (8 points).
pub fn grid() -> Vec<ScenarioSpec> {
    let mut specs = Vec::new();
    for hwa in HWAS {
        for n_tbs in TB_DEPTHS {
            specs.push(
                ScenarioSpec::new(&format!("fig6[{hwa},tbs={n_tbs}]"))
                    .hwas(&format!("{hwa}*1"))
                    .task_buffers(n_tbs)
                    .workload(WorkloadSpec::Burst {
                        requests_per_proc: REQUESTS_PER_PROC,
                    })
                    .deadline_us(2_000),
            );
        }
    }
    specs
}

pub struct Fig6 {
    pub report: SweepReport,
}

pub fn run() -> Fig6 {
    Fig6 {
        report: SweepRunner::new()
            .run("fig6", grid())
            .expect("fig6 sweep drains"),
    }
}

impl Fig6 {
    /// Drain time (µs) for one (hwa, TB depth) grid point.
    pub fn total_us(&self, hwa: &str, n_tbs: usize) -> f64 {
        self.report
            .stats_where(|s| {
                s.hwas.to_string() == format!("{hwa}*1") && s.n_tbs == n_tbs
            })
            .total_us
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 6 — execution time vs number of task buffers",
            &["hwa", "task buffers", "total time (us)", "vs 1 TB"],
        );
        for hwa in HWAS {
            let base = self.total_us(hwa, 1);
            for n_tbs in TB_DEPTHS {
                let total = self.total_us(hwa, n_tbs);
                t.row(&[
                    hwa.to_string(),
                    n_tbs.to_string(),
                    format!("{total:.2}"),
                    format!("{:+.1}%", 100.0 * (total - base) / base),
                ]);
            }
        }
        t
    }

    /// Percentage improvement going from one to two task buffers.
    pub fn improvement_1_to_2(&self, hwa: &str) -> f64 {
        let t1 = self.total_us(hwa, 1);
        let t2 = self.total_us(hwa, 2);
        100.0 * (t1 - t2) / t1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn izigzag_improves_with_second_tb_dfdiv_does_not() {
        let fig = run();
        let izz = fig.improvement_1_to_2("izigzag");
        let dfd = fig.improvement_1_to_2("dfdiv");
        assert!(
            izz > 10.0,
            "izigzag should gain >10% from 2 TBs, got {izz:.1}%"
        );
        assert!(
            dfd < 5.0,
            "dfdiv should gain <5% from 2 TBs, got {dfd:.1}%"
        );
    }

    #[test]
    fn no_further_gain_beyond_two_tbs() {
        let fig = run();
        let t2 = fig.total_us("izigzag", 2);
        let t4 = fig.total_us("izigzag", 4);
        let gain = 100.0 * (t2 - t4) / t2;
        assert!(gain < 6.0, "beyond 2 TBs gain should be small: {gain:.1}%");
    }
}
