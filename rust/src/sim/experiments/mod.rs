//! One driver per paper figure/table (DESIGN.md §4 per-experiment index).

pub mod fig10;
pub mod fig13_14;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod tables;
