//! One driver per paper figure/table (DESIGN.md §4 per-experiment index).
//!
//! Since ISSUE 2, every simulation-backed figure (fig6, fig8, fig9,
//! fig10, fig13/14) is a declarative [`crate::sweep`] grid — the drivers
//! here only build `ScenarioSpec`s, run them through `SweepRunner`
//! (sharded across host cores) and render paper-style tables; benches
//! additionally persist each `SweepReport` as `BENCH_fig*.json`. Table
//! and fig7 outputs are closed-form (no simulation) and stay direct.
//! See `docs/EXPERIMENTS.md` for the figure -> command -> artifact map.

pub mod fig10;
pub mod fig13_14;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod tables;
