//! Fig. 7: maximum interface frequency for every PR x PS strategy pair
//! (32 HWA channels), from the analytical synthesis model.

use crate::synth::delay::{interface_fmax_mhz, pr_fmax_mhz, ps_fmax_mhz};
use crate::util::table::Table;

pub const N_CHANNELS: usize = 32;
pub const PR_SWEEP: [usize; 4] = [4, 8, 16, 32];
/// PS group sizes; `N_CHANNELS` encodes the global strategy.
pub const PS_SWEEP: [usize; 5] = [2, 4, 8, 16, N_CHANNELS];

pub struct Fig7 {
    /// (pr label, ps label, fmax MHz)
    pub grid: Vec<(String, String, f64)>,
}

pub fn run() -> Fig7 {
    let mut grid = Vec::new();
    for ps in PS_SWEEP {
        for pr in PR_SWEEP {
            let label_ps = if ps == N_CHANNELS {
                "PSglobal".to_string()
            } else {
                format!("PS{ps}")
            };
            grid.push((
                format!("PR{pr}"),
                label_ps,
                interface_fmax_mhz(pr, ps, N_CHANNELS),
            ));
        }
    }
    Fig7 { grid }
}

impl Fig7 {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 7 — max frequency (MHz), PR x PS strategies, 32 channels",
            &["PS strategy", "PR4", "PR8", "PR16", "PR32", "PR avg"],
        );
        for ps in PS_SWEEP {
            let label_ps = if ps == N_CHANNELS {
                "PSglobal".to_string()
            } else {
                format!("PS{ps}")
            };
            let row: Vec<f64> = PR_SWEEP
                .iter()
                .map(|pr| interface_fmax_mhz(*pr, ps, N_CHANNELS))
                .collect();
            let avg = row.iter().sum::<f64>() / row.len() as f64;
            t.row(&[
                label_ps,
                format!("{:.0}", row[0]),
                format!("{:.0}", row[1]),
                format!("{:.0}", row[2]),
                format!("{:.0}", row[3]),
                format!("{:.0}", avg),
            ]);
        }
        t
    }

    pub fn component_table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 7 components — standalone PR / PS fmax (MHz)",
            &["strategy", "fmax (MHz)"],
        );
        for pr in PR_SWEEP {
            t.row(&[
                format!("PR{pr}"),
                format!("{:.0}", pr_fmax_mhz(pr, N_CHANNELS)),
            ]);
        }
        for ps in PS_SWEEP {
            let label = if ps == N_CHANNELS {
                "PSglobal".to_string()
            } else {
                format!("PS{ps}")
            };
            t.row(&[label, format!("{:.0}", ps_fmax_mhz(ps, N_CHANNELS))]);
        }
        t
    }

    pub fn best(&self) -> &(String, String, f64) {
        self.grid
            .iter()
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_is_pr4_ps4() {
        let f = run();
        let (pr, ps, fmax) = f.best();
        assert_eq!(pr, "PR4");
        assert_eq!(ps, "PS4");
        assert!(*fmax >= 300.0);
    }
}
