//! Fig. 8: injection rate and throughput vs. request frequency for three
//! workloads on eight HWAs: (a) Izigzag-HWA (all izigzag), (b) Eight-HWA
//! (first eight Table 3 benchmarks), (c) Dfdiv-HWA (all dfdiv).
//!
//! Paper results: (a) throughput saturates at ~0.2 requests/µs per the
//! paper's normalization with max injection 27.95 flits/µs and max
//! throughput 24.81 flits/µs (~5.7% below injection), drooping slightly
//! past saturation; (b) saturates later, throughput well below injection;
//! (c) throughput flat — execution-bound.
//!
//! Each series is a [`sweep`](crate::sweep) grid over the request-rate
//! axis; all points of a series run concurrently.

use crate::fpga::hwa::HwaSpec;
use crate::sim::system::{FabricKind, NetKind};
use crate::sweep::{
    RunStats, ScenarioSpec, SweepReport, SweepRunner, WorkloadSpec,
};
use crate::util::table::Table;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    IzigzagHwa,
    EightHwa,
    DfdivHwa,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        match self {
            Workload::IzigzagHwa => "Izigzag-HWA",
            Workload::EightHwa => "Eight-HWA",
            Workload::DfdivHwa => "Dfdiv-HWA",
        }
    }

    /// The accelerator mix, in [`crate::sweep::HwaMix`] syntax.
    pub fn hwa_mix(&self) -> &'static str {
        match self {
            Workload::IzigzagHwa => "izigzag*8",
            Workload::EightHwa => "first8",
            Workload::DfdivHwa => "dfdiv*8",
        }
    }

    pub fn specs(&self) -> Vec<HwaSpec> {
        crate::sweep::HwaMix::parse(self.hwa_mix())
            .unwrap()
            .to_specs()
            .unwrap()
    }
}

/// Default request-rate sweep (total requests/µs across processors).
pub fn default_rates() -> Vec<f64> {
    vec![0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 24.0]
}

pub struct Fig8Series {
    pub workload: Workload,
    pub rates: Vec<f64>,
    pub report: SweepReport,
}

/// The scenario grid for one series (one point per rate).
#[allow(clippy::too_many_arguments)]
pub fn grid(
    workload: Workload,
    rates: &[f64],
    net: NetKind,
    fabric: FabricKind,
    warmup_us: u64,
    window_us: u64,
    seed: u64,
) -> Vec<ScenarioSpec> {
    rates
        .iter()
        .map(|rate| {
            ScenarioSpec::new(&format!(
                "fig8[{},rate={rate}]",
                workload.name()
            ))
            .net(net)
            .fabric(fabric)
            .hwas(workload.hwa_mix())
            .workload(WorkloadSpec::OpenLoop { rate_per_us: *rate })
            .warmup_us(warmup_us)
            .window_us(window_us)
            .seed(seed)
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
pub fn run_series(
    workload: Workload,
    rates: &[f64],
    net: NetKind,
    fabric: FabricKind,
    warmup_us: u64,
    window_us: u64,
    seed: u64,
) -> Fig8Series {
    let report = SweepRunner::new()
        .run(
            &format!("fig8-{}", workload.name()),
            grid(workload, rates, net, fabric, warmup_us, window_us, seed),
        )
        .expect("fig8 open-loop sweep cannot miss a deadline");
    Fig8Series {
        workload,
        rates: rates.to_vec(),
        report,
    }
}

/// All three paper series as ONE sweep grid (24 scenarios sharded across
/// every host core at once) — the bench/CLI path. Returns the per-series
/// views plus the combined report for `BENCH_fig8.json`.
pub fn run_all(
    warmup_us: u64,
    window_us: u64,
) -> (Vec<Fig8Series>, SweepReport) {
    let workloads =
        [Workload::IzigzagHwa, Workload::EightHwa, Workload::DfdivHwa];
    let rates = default_rates();
    let mut specs = Vec::new();
    for wl in workloads {
        specs.extend(grid(
            wl,
            &rates,
            NetKind::Noc,
            FabricKind::Buffered,
            warmup_us,
            window_us,
            0xF18,
        ));
    }
    let report = SweepRunner::new()
        .run("fig8", specs)
        .expect("fig8 open-loop sweep cannot miss a deadline");
    let series = workloads
        .iter()
        .enumerate()
        .map(|(i, wl)| Fig8Series {
            workload: *wl,
            rates: rates.clone(),
            report: SweepReport {
                name: format!("fig8-{}", wl.name()),
                scenarios: report.scenarios
                    [i * rates.len()..(i + 1) * rates.len()]
                    .to_vec(),
            },
        })
        .collect();
    (series, report)
}

/// The paper's configuration: NoC + buffered fabric.
pub fn run(workload: Workload, warmup_us: u64, window_us: u64) -> Fig8Series {
    run_series(
        workload,
        &default_rates(),
        NetKind::Noc,
        FabricKind::Buffered,
        warmup_us,
        window_us,
        0xF18,
    )
}

impl Fig8Series {
    /// Stats per rate point, in rate order.
    pub fn points(&self) -> Vec<&RunStats> {
        self.report.scenarios.iter().map(|s| &s.stats).collect()
    }

    pub fn point(&self, i: usize) -> &RunStats {
        &self.report.scenarios[i].stats
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!("Fig. 8 — {}", self.workload.name()),
            &[
                "req rate (/us)",
                "injection (flits/us)",
                "throughput (flits/us)",
                "busy",
                "done (/us)",
                "lat p99 (us)",
            ],
        );
        for (r, p) in self.rates.iter().zip(self.points()) {
            t.row(&[
                format!("{r:.2}"),
                format!("{:.2}", p.injection_flits_per_us),
                format!("{:.2}", p.throughput_flits_per_us),
                format!("{:.0}%", 100.0 * p.busy_fraction),
                format!("{:.2}", p.completions_per_us),
                format!("{:.3}", p.latency.p99_us),
            ]);
        }
        t
    }

    pub fn max_throughput(&self) -> f64 {
        self.points()
            .iter()
            .map(|p| p.throughput_flits_per_us)
            .fold(0.0, f64::max)
    }

    pub fn max_injection(&self) -> f64 {
        self.points()
            .iter()
            .map(|p| p.injection_flits_per_us)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(workload: Workload) -> Fig8Series {
        run_series(
            workload,
            &[0.5, 2.0, 8.0, 24.0],
            NetKind::Noc,
            FabricKind::Buffered,
            3,
            15,
            42,
        )
    }

    #[test]
    fn izigzag_throughput_tracks_injection() {
        let s = quick(Workload::IzigzagHwa);
        // At saturation throughput within ~15% of injection (paper: 5.7%).
        let inj = s.max_injection();
        let thr = s.max_throughput();
        assert!(thr > 0.75 * inj, "thr {thr} vs inj {inj}");
    }

    #[test]
    fn dfdiv_throughput_is_execution_bound() {
        let s = quick(Workload::DfdivHwa);
        // Throughput flat: the two highest-rate points differ little
        // while injection grows.
        let t_hi = s.point(3).throughput_flits_per_us;
        let t_mid = s.point(2).throughput_flits_per_us;
        assert!(
            (t_hi - t_mid).abs() / t_mid.max(1e-9) < 0.25,
            "dfdiv throughput should plateau: {t_mid} -> {t_hi}"
        );
    }

    #[test]
    fn eight_hwa_throughput_below_izigzag() {
        let izz = quick(Workload::IzigzagHwa);
        let eight = quick(Workload::EightHwa);
        assert!(eight.max_throughput() < izz.max_throughput());
    }
}
