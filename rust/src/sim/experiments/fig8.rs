//! Fig. 8: injection rate and throughput vs. request frequency for three
//! workloads on eight HWAs: (a) Izigzag-HWA (all izigzag), (b) Eight-HWA
//! (first eight Table 3 benchmarks), (c) Dfdiv-HWA (all dfdiv).
//!
//! Paper results: (a) throughput saturates at ~0.2 requests/µs per the
//! paper's normalization with max injection 27.95 flits/µs and max
//! throughput 24.81 flits/µs (~5.7% below injection), drooping slightly
//! past saturation; (b) saturates later, throughput well below injection;
//! (c) throughput flat — execution-bound.

use crate::fpga::hwa::{spec_by_name, table3, HwaSpec};
use crate::sim::system::{FabricKind, NetKind, System, SystemConfig};
use crate::util::table::Table;
use crate::workload::random::{measure_open_rate_point, RatePoint};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    IzigzagHwa,
    EightHwa,
    DfdivHwa,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        match self {
            Workload::IzigzagHwa => "Izigzag-HWA",
            Workload::EightHwa => "Eight-HWA",
            Workload::DfdivHwa => "Dfdiv-HWA",
        }
    }

    pub fn specs(&self) -> Vec<HwaSpec> {
        match self {
            Workload::IzigzagHwa => {
                vec![spec_by_name("izigzag").unwrap(); 8]
            }
            Workload::EightHwa => table3().into_iter().take(8).collect(),
            Workload::DfdivHwa => vec![spec_by_name("dfdiv").unwrap(); 8],
        }
    }
}

/// Default request-rate sweep (total requests/µs across processors).
pub fn default_rates() -> Vec<f64> {
    vec![0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 24.0]
}

pub struct Fig8Series {
    pub workload: Workload,
    pub rates: Vec<f64>,
    pub points: Vec<RatePoint>,
}

pub fn run_series(
    workload: Workload,
    rates: &[f64],
    net: NetKind,
    fabric: FabricKind,
    warmup_us: u64,
    window_us: u64,
    seed: u64,
) -> Fig8Series {
    let mut points = Vec::new();
    for rate in rates {
        let mut cfg = SystemConfig::paper(workload.specs());
        cfg.net = net;
        cfg.fabric = fabric;
        let mut sys = System::new(cfg);
        sys.set_open_loop(*rate, seed);
        points.push(measure_open_rate_point(&mut sys, warmup_us, window_us));
    }
    Fig8Series {
        workload,
        rates: rates.to_vec(),
        points,
    }
}

/// The paper's configuration: NoC + buffered fabric.
pub fn run(workload: Workload, warmup_us: u64, window_us: u64) -> Fig8Series {
    run_series(
        workload,
        &default_rates(),
        NetKind::Noc,
        FabricKind::Buffered,
        warmup_us,
        window_us,
        0xF18,
    )
}

impl Fig8Series {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!("Fig. 8 — {}", self.workload.name()),
            &[
                "req rate (/us)",
                "injection (flits/us)",
                "throughput (flits/us)",
                "busy",
                "done (/us)",
            ],
        );
        for (r, p) in self.rates.iter().zip(&self.points) {
            t.row(&[
                format!("{r:.2}"),
                format!("{:.2}", p.injection_flits_per_us),
                format!("{:.2}", p.throughput_flits_per_us),
                format!("{:.0}%", 100.0 * p.busy_fraction),
                format!("{:.2}", p.completions_per_us),
            ]);
        }
        t
    }

    pub fn max_throughput(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.throughput_flits_per_us)
            .fold(0.0, f64::max)
    }

    pub fn max_injection(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.injection_flits_per_us)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(workload: Workload) -> Fig8Series {
        run_series(
            workload,
            &[0.5, 2.0, 8.0, 24.0],
            NetKind::Noc,
            FabricKind::Buffered,
            3,
            15,
            42,
        )
    }

    #[test]
    fn izigzag_throughput_tracks_injection() {
        let s = quick(Workload::IzigzagHwa);
        // At saturation throughput within ~15% of injection (paper: 5.7%).
        let inj = s.max_injection();
        let thr = s.max_throughput();
        assert!(thr > 0.75 * inj, "thr {thr} vs inj {inj}");
    }

    #[test]
    fn dfdiv_throughput_is_execution_bound() {
        let s = quick(Workload::DfdivHwa);
        // Throughput flat: the two highest-rate points differ little
        // while injection grows.
        let t_hi = s.points[3].throughput_flits_per_us;
        let t_mid = s.points[2].throughput_flits_per_us;
        assert!(
            (t_hi - t_mid).abs() / t_mid.max(1e-9) < 0.25,
            "dfdiv throughput should plateau: {t_mid} -> {t_hi}"
        );
    }

    #[test]
    fn eight_hwa_throughput_below_izigzag() {
        let izz = quick(Workload::IzigzagHwa);
        let eight = quick(Workload::EightHwa);
        assert!(eight.max_throughput() < izz.max_throughput());
    }
}
