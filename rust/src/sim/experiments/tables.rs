//! Table 2 (component latencies), Table 3 (benchmark resources) and
//! Table 4 (interface resource breakdown) generators.

use crate::fpga::hwa::{table3, DEVICE_BRAMS, DEVICE_LUTS};
use crate::fpga::iface::pr::PrStrategy;
use crate::fpga::iface::ps::PsStrategy;
use crate::synth::resource::{
    channel_cost, interface_cost, pr_cost, ps_cost, CHAIN_COST, HWAC_PG_COST,
    LGB_COST, LGC_COST, POB_COST, RB_COST, TA_COST, TB_COST,
};
use crate::util::table::Table;

/// Table 2 — structural latencies the implementation enforces; the cycle
/// expressions are verified by unit/integration tests (see
/// `fpga::channel::tests::table2_hwac_pg_latency_structure`,
/// `fpga::iface::pr/ps` tests and `rust/tests/table2.rs`).
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2 — interface component latencies (cycles; N = payload flits)",
        &["scope", "component", "latency"],
    );
    for (scope, comp, lat) in [
        ("per HWA", "HWAC", "4 + N"),
        ("per HWA", "PG", "4 + N"),
        ("per HWA", "LGC", "1"),
        ("per HWA", "TA", "1"),
        ("per HWA", "CC", "1"),
        ("per HWA", "buffers (TB/POB/RB/LGB/CB)", "4 + N"),
        ("overall", "PR (command)", "1"),
        ("overall", "PR (payload)", "2 + N"),
        ("overall", "PS (command)", "1"),
        ("overall", "PS (payload)", "4 + N"),
    ] {
        t.row(&[scope.to_string(), comp.to_string(), lat.to_string()]);
    }
    t
}

/// Table 3 — benchmark resources (verbatim constants) plus our calibrated
/// execution model columns.
pub fn table3_table() -> Table {
    let mut t = Table::new(
        "Table 3 — benchmark complexity + calibrated execution model",
        &[
            "benchmark", "LUT", "BRAM", "DSP", "FF", "exec cycles",
            "in words", "fmax (MHz)",
        ],
    );
    for s in table3() {
        t.row(&[
            s.name.to_string(),
            s.resources.lut.to_string(),
            s.resources.bram.to_string(),
            s.resources.dsp.to_string(),
            s.resources.ff.to_string(),
            s.exec_cycles.to_string(),
            s.in_words.to_string(),
            format!("{:.0}", s.fmax_mhz),
        ]);
    }
    t
}

/// Table 4 — resource breakdown for the PR4-PS4 interface.
pub fn table4() -> Table {
    let mut t = Table::new(
        "Table 4 — interface resource breakdown (PR4-PS4, 32 channels)",
        &["scope", "component", "LUT", "LUT %", "BRAM", "BRAM %"],
    );
    let pct_l = |l: u32| format!("{:.2}", 100.0 * l as f64 / DEVICE_LUTS as f64);
    let pct_b =
        |b: u32| format!("{:.2}", 100.0 * b as f64 / DEVICE_BRAMS as f64);
    for (name, r) in [
        ("TB", TB_COST),
        ("TA", TA_COST),
        ("HWAC+PG", HWAC_PG_COST),
        ("POB", POB_COST),
        ("RB", RB_COST),
        ("LGC", LGC_COST),
        ("LGB", LGB_COST),
        ("CB+CC (chaining)", CHAIN_COST),
    ] {
        t.row(&[
            "per HWA".to_string(),
            name.to_string(),
            r.lut.to_string(),
            pct_l(r.lut),
            r.bram.to_string(),
            pct_b(r.bram),
        ]);
    }
    let pr = pr_cost(PrStrategy::distributed(4), 32);
    let ps = ps_cost(PsStrategy::hierarchical(4), 32);
    for (name, r) in [("PR", pr), ("PS", ps)] {
        t.row(&[
            "overall".to_string(),
            name.to_string(),
            r.lut.to_string(),
            pct_l(r.lut),
            r.bram.to_string(),
            pct_b(r.bram),
        ]);
    }
    let total = interface_cost(
        PrStrategy::distributed(4),
        PsStrategy::hierarchical(4),
        32,
        false,
    );
    t.row(&[
        "overall".to_string(),
        "total (32 channels, no chaining)".to_string(),
        total.lut.to_string(),
        pct_l(total.lut),
        total.bram.to_string(),
        pct_b(total.bram),
    ]);
    let per = channel_cost(false);
    t.row(&[
        "per HWA".to_string(),
        "channel total".to_string(),
        per.lut.to_string(),
        pct_l(per.lut),
        per.bram.to_string(),
        pct_b(per.bram),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        assert!(table2().render().contains("4 + N"));
        assert!(table3_table().render().contains("izigzag"));
        assert!(table4().render().contains("5039"));
    }
}
