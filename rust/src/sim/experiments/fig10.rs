//! Fig. 10: speedup of HWA chaining depths 1-3 over depth 0 for the JPEG
//! decompression chain (izigzag -> iquantize -> idct -> shiftbound).
//!
//! Paper result: speedup grows with chaining depth, because each chained
//! hop eliminates a result+request+payload round trip over the NoC whose
//! processor-side packet send/receive cost dominates.
//!
//! One `jpeg_chain` scenario per depth, all four running concurrently in
//! a [`sweep`](crate::sweep) grid.

use crate::sweep::{ScenarioSpec, SweepReport, SweepRunner, WorkloadSpec};
use crate::util::table::Table;

/// Blocks decoded per run.
pub const N_BLOCKS: usize = 12;

/// The synthetic-image seed (also the scenario seed).
const IMAGE_SEED: u64 = 0xF16;

/// The Fig. 10 grid: chaining depths 0..=3 over the chained JPEG system.
pub fn grid() -> Vec<ScenarioSpec> {
    (0..=3u8)
        .map(|depth| {
            ScenarioSpec::new(&format!("fig10[depth={depth}]"))
                .hwas("jpeg")
                .chain(true)
                .workload(WorkloadSpec::JpegChain {
                    depth,
                    blocks: N_BLOCKS,
                })
                .seed(IMAGE_SEED)
                .deadline_us(100_000)
        })
        .collect()
}

pub struct Fig10 {
    pub report: SweepReport,
}

pub fn run() -> Fig10 {
    Fig10 {
        report: SweepRunner::new()
            .run("fig10", grid())
            .expect("fig10 sweep drains"),
    }
}

impl Fig10 {
    pub fn total_us(&self, depth: u8) -> f64 {
        self.report
            .stats_where(|s| {
                matches!(
                    s.workload,
                    WorkloadSpec::JpegChain { depth: d, .. } if d == depth
                )
            })
            .total_us
    }

    pub fn speedup(&self, depth: u8) -> f64 {
        self.total_us(0) / self.total_us(depth)
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 10 — chaining speedup vs depth 0 (JPEG chain)",
            &["chaining depth", "total time (us)", "speedup"],
        );
        for depth in 0..=3u8 {
            t.row(&[
                depth.to_string(),
                format!("{:.2}", self.total_us(depth)),
                format!("{:.2}x", self.speedup(depth)),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grows_with_depth() {
        let f = run();
        let s1 = f.speedup(1);
        let s2 = f.speedup(2);
        let s3 = f.speedup(3);
        assert!(s1 > 1.0, "depth1 {s1}");
        assert!(s2 > s1, "depth2 {s2} vs {s1}");
        assert!(s3 > s2, "depth3 {s3} vs {s2}");
    }
}
