//! Fig. 10: speedup of HWA chaining depths 1-3 over depth 0 for the JPEG
//! decompression chain (izigzag -> iquantize -> idct -> shiftbound).
//!
//! Paper result: speedup grows with chaining depth, because each chained
//! hop eliminates a result+request+payload round trip over the NoC whose
//! processor-side packet send/receive cost dominates.

use crate::clock::PS_PER_US;
use crate::cmp::apps::jpeg_chain_depth_program;
use crate::fpga::hwa::spec_by_name;
use crate::sim::system::{System, SystemConfig};
use crate::util::table::Table;
use crate::workload::jpeg::BlockImage;

/// Blocks decoded per run.
pub const N_BLOCKS: usize = 12;

fn chain_system() -> System {
    let mut cfg = SystemConfig::paper(vec![
        spec_by_name("izigzag").unwrap(),
        spec_by_name("iquantize").unwrap(),
        spec_by_name("idct").unwrap(),
        spec_by_name("shiftbound").unwrap(),
    ]);
    cfg.chain_groups = vec![vec![0, 1, 2, 3]];
    System::new(cfg)
}

pub struct Fig10Point {
    pub depth: u8,
    pub total_us: f64,
}

pub fn run_depth(depth: u8) -> Fig10Point {
    let mut sys = chain_system();
    let img = BlockImage::synthetic(N_BLOCKS, 0xF16);
    let words = img.coefficient_words();
    // One processor decodes block after block (the §6.6 experiment).
    let mut prog = Vec::new();
    for block in words.iter() {
        for seg in jpeg_chain_depth_program(depth) {
            // Patch the real coefficients into the first invocation of
            // each block's program (the chain entry).
            prog.push(match seg {
                crate::cmp::core::Segment::Invoke(mut spec) => {
                    if spec.hwa_id == 0 {
                        spec.words = block.clone();
                    }
                    crate::cmp::core::Segment::Invoke(spec)
                }
                other => other,
            });
        }
    }
    sys.load_program(0, prog);
    let done = sys.run_until_done(100_000 * PS_PER_US);
    assert!(done, "fig10 depth {depth} did not finish");
    let total_us =
        sys.procs[0].finished_at.unwrap() as f64 / PS_PER_US as f64;
    Fig10Point { depth, total_us }
}

pub struct Fig10 {
    pub points: Vec<Fig10Point>,
}

pub fn run() -> Fig10 {
    Fig10 {
        points: (0..=3).map(run_depth).collect(),
    }
}

impl Fig10 {
    pub fn speedup(&self, depth: u8) -> f64 {
        let base = self.points[0].total_us;
        base / self.points[depth as usize].total_us
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 10 — chaining speedup vs depth 0 (JPEG chain)",
            &["chaining depth", "total time (us)", "speedup"],
        );
        for p in &self.points {
            t.row(&[
                p.depth.to_string(),
                format!("{:.2}", p.total_us),
                format!("{:.2}x", self.speedup(p.depth)),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grows_with_depth() {
        let f = run();
        let s1 = f.speedup(1);
        let s2 = f.speedup(2);
        let s3 = f.speedup(3);
        assert!(s1 > 1.0, "depth1 {s1}");
        assert!(s2 > s1, "depth2 {s2} vs {s1}");
        assert!(s3 > s2, "depth3 {s3} vs {s2}");
    }
}
