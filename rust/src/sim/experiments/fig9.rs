//! Fig. 9: latency breakdown (processor execution / FPGA acceleration /
//! data transmission) for every partition of GSM and the JPEG decoder.
//!
//! Paper result: the all-FPGA partitions (GSM.p3, JPEG.p5) have the
//! smallest overall latency; FPGA acceleration wins in every partition
//! even including communication overhead.
//!
//! Each (app, partition) pair is one `app_partition` scenario in a
//! [`sweep`](crate::sweep) grid; the breakdown lands in
//! `RunStats::{processor_us, fpga_us, transmission_us}`.

use crate::sweep::{
    AppKind, RunStats, ScenarioSpec, SweepReport, SweepRunner, WorkloadSpec,
};
use crate::util::table::Table;

// The spec mapping for app functions lives with the apps themselves.
pub use crate::cmp::apps::app_specs;

/// One partition's scenario (deadline per the §6.5 budget).
pub fn scenario(app: AppKind, partition: usize) -> ScenarioSpec {
    ScenarioSpec::new(&format!("fig9[{}.p{partition}]", app.name()))
        .workload(WorkloadSpec::AppPartition { app, partition })
        .deadline_us(50_000)
}

/// The full grid: every partition of both apps (4 + 6 scenarios).
pub fn grid() -> Vec<ScenarioSpec> {
    let mut specs = Vec::new();
    for app in [AppKind::Gsm, AppKind::Jpeg] {
        for k in 0..app.app().n_partitions() {
            specs.push(scenario(app, k));
        }
    }
    specs
}

/// Run one partition of an app on a single processor.
pub fn run_partition(app: AppKind, k: usize) -> RunStats {
    crate::sweep::run_scenario(&scenario(app, k))
        .expect("fig9 partition drains")
}

pub struct Fig9 {
    pub report: SweepReport,
}

pub fn run() -> Fig9 {
    Fig9 {
        report: SweepRunner::new()
            .run("fig9", grid())
            .expect("fig9 sweep drains"),
    }
}

impl Fig9 {
    pub fn breakdown(&self, app: AppKind, partition: usize) -> &RunStats {
        self.report.stats_where(|s| {
            s.workload
                == WorkloadSpec::AppPartition { app, partition }
        })
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 9 — latency breakdown per partition (µs)",
            &["partition", "processor", "FPGA", "transmission", "total"],
        );
        for s in &self.report.scenarios {
            let b = &s.stats;
            t.row(&[
                s.spec
                    .name
                    .trim_start_matches("fig9[")
                    .trim_end_matches(']')
                    .to_string(),
                format!("{:.2}", b.processor_us),
                format!("{:.2}", b.fpga_us),
                format!("{:.2}", b.transmission_us),
                format!("{:.2}", b.total_us),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_fpga_partition_is_fastest_gsm() {
        let p0 = run_partition(AppKind::Gsm, 0);
        let p3 = run_partition(AppKind::Gsm, 3);
        assert!(
            p3.total_us < p0.total_us,
            "GSM.p3 {:.2} should beat GSM.p0 {:.2}",
            p3.total_us,
            p0.total_us
        );
        assert!(p3.processor_us < p0.processor_us);
    }

    #[test]
    fn jpeg_p5_beats_all_software() {
        let p0 = run_partition(AppKind::Jpeg, 0);
        let p5 = run_partition(AppKind::Jpeg, 5);
        assert!(p5.total_us < p0.total_us);
    }

    #[test]
    fn offloading_monotonically_helps_jpeg() {
        // Each additional offloaded function reduces (or at worst nearly
        // preserves) total latency — the Fig. 9 staircase.
        let totals: Vec<f64> = (0..=5)
            .map(|k| run_partition(AppKind::Jpeg, k).total_us)
            .collect();
        for w in totals.windows(2) {
            assert!(
                w[1] < w[0] * 1.10,
                "partition step should not regress >10%: {totals:?}"
            );
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let b = run_partition(AppKind::Gsm, 2);
        let sum = b.processor_us + b.fpga_us + b.transmission_us;
        assert!(
            (sum - b.total_us).abs() < 1e-6,
            "breakdown {sum} vs total {}",
            b.total_us
        );
    }
}
