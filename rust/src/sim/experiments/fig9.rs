//! Fig. 9: latency breakdown (processor execution / FPGA acceleration /
//! data transmission) for every partition of GSM and the JPEG decoder.
//!
//! Paper result: the all-FPGA partitions (GSM.p3, JPEG.p5) have the
//! smallest overall latency; FPGA acceleration wins in every partition
//! even including communication overhead.

use crate::clock::{Ps, PS_PER_US};
use crate::cmp::apps::{gsm_app, jpeg_app, App};
use crate::fpga::hwa::{spec_by_name, HwaSpec, Resources};
use crate::sim::system::{System, SystemConfig};
use crate::util::table::Table;

/// HWA spec for an app function that has no Table 3 entry (JPEG entropy
/// decode and the GSM stages) — Huffman/LPC-class HLS kernels.
fn custom_spec(name: &'static str, exec: u64, words: usize, fmax: f64) -> HwaSpec {
    HwaSpec {
        name,
        exec_cycles: exec,
        in_words: words,
        out_words: words,
        fmax_mhz: fmax,
        resources: Resources::new(5000, 2, 8, 4000),
        artifact: None,
    }
}

/// Specs for the app's functions, hwa_id = function index.
pub fn app_specs(app: &App) -> Vec<HwaSpec> {
    app.functions
        .iter()
        .map(|f| match f.name {
            "izigzag" => spec_by_name("izigzag").unwrap(),
            "iquantize" => spec_by_name("iquantize").unwrap(),
            "idct" => spec_by_name("idct").unwrap(),
            "shiftbound" => spec_by_name("shiftbound").unwrap(),
            "autocorrelation" => custom_spec("autocorr", 180, 8, 260.0),
            "reflection_coeff" => custom_spec("reflect", 140, 8, 260.0),
            "lar_quantize" => custom_spec("larq", 60, 8, 300.0),
            "entropy_decode" => custom_spec("entropy", 500, 64, 250.0),
            other => panic!("no spec mapping for {other}"),
        })
        .collect()
}

#[derive(Debug, Clone, Copy)]
pub struct Breakdown {
    pub partition: usize,
    /// µs of pure software execution on the core.
    pub processor_us: f64,
    /// µs of HWA execution on the FPGA.
    pub fpga_us: f64,
    /// µs of everything else (request/grant/payload/result transmission).
    pub transmission_us: f64,
}

impl Breakdown {
    pub fn total_us(&self) -> f64 {
        self.processor_us + self.fpga_us + self.transmission_us
    }
}

/// Run one partition of an app on a single processor.
pub fn run_partition(app: &App, k: usize) -> Breakdown {
    let mut cfg = SystemConfig::paper(app_specs(app));
    cfg.chain_groups = vec![];
    let mut sys = System::new(cfg);
    sys.load_program(0, app.partition_program(k));
    let done = sys.run_until_done(50_000 * PS_PER_US);
    assert!(done, "{}.p{k} did not finish", app.name);
    let end: Ps = sys.procs[0].finished_at.expect("finished");
    let processor_ps = sys.procs[0].sw_cycles * 1000; // 1 GHz core
    // FPGA execution time: sum over completed tasks of exec intervals.
    let fpga_ps: u64 = sys
        .fabric
        .buffered()
        .map(|f| {
            f.channels
                .iter()
                .flat_map(|c| c.completed.iter())
                .map(|t| t.t_exec_end.saturating_sub(t.t_exec_start))
                .sum()
        })
        .unwrap_or(0);
    let transmission_ps = end.saturating_sub(processor_ps + fpga_ps);
    Breakdown {
        partition: k,
        processor_us: processor_ps as f64 / PS_PER_US as f64,
        fpga_us: fpga_ps as f64 / PS_PER_US as f64,
        transmission_us: transmission_ps as f64 / PS_PER_US as f64,
    }
}

pub struct Fig9 {
    pub gsm: Vec<Breakdown>,
    pub jpeg: Vec<Breakdown>,
}

pub fn run() -> Fig9 {
    let gsm = gsm_app(0);
    let jpeg = jpeg_app(0);
    Fig9 {
        gsm: (0..=gsm.functions.len())
            .map(|k| run_partition(&gsm, k))
            .collect(),
        jpeg: (0..=jpeg.functions.len())
            .map(|k| run_partition(&jpeg, k))
            .collect(),
    }
}

impl Fig9 {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 9 — latency breakdown per partition (µs)",
            &["partition", "processor", "FPGA", "transmission", "total"],
        );
        for (name, rows) in [("GSM", &self.gsm), ("JPEG", &self.jpeg)] {
            for b in rows.iter() {
                t.row(&[
                    format!("{name}.p{}", b.partition),
                    format!("{:.2}", b.processor_us),
                    format!("{:.2}", b.fpga_us),
                    format!("{:.2}", b.transmission_us),
                    format!("{:.2}", b.total_us()),
                ]);
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_fpga_partition_is_fastest_gsm() {
        let app = gsm_app(0);
        let p0 = run_partition(&app, 0);
        let p3 = run_partition(&app, 3);
        assert!(
            p3.total_us() < p0.total_us(),
            "GSM.p3 {:.2} should beat GSM.p0 {:.2}",
            p3.total_us(),
            p0.total_us()
        );
        assert!(p3.processor_us < p0.processor_us);
    }

    #[test]
    fn jpeg_p5_beats_all_software() {
        let app = jpeg_app(0);
        let p0 = run_partition(&app, 0);
        let p5 = run_partition(&app, 5);
        assert!(p5.total_us() < p0.total_us());
    }

    #[test]
    fn offloading_monotonically_helps_jpeg() {
        // Each additional offloaded function reduces (or at worst nearly
        // preserves) total latency — the Fig. 9 staircase.
        let app = jpeg_app(0);
        let totals: Vec<f64> = (0..=5)
            .map(|k| run_partition(&app, k).total_us())
            .collect();
        for w in totals.windows(2) {
            assert!(
                w[1] < w[0] * 1.10,
                "partition step should not regress >10%: {totals:?}"
            );
        }
    }
}
