//! Fig. 13 (maximum throughput) and Fig. 14 (single-invocation
//! communication latency) for the three prototypes: the proposed
//! NoC + distributed buffers, AXI bus integration (§6.7), and the shared
//! FPGA cache design (§6.8).
//!
//! Paper results: vs. the proposal, AXI loses 27% (Izigzag-HWA) / 53%
//! (Eight-HWA) max throughput and the cache design loses 22.5% / 28.2%;
//! Dfdiv-HWA is execution-bound and identical everywhere. Communication
//! latency: NoC 2.42x better than AXI, 1.63x better than the cache.

use crate::sim::system::{FabricKind, NetKind};
use crate::sweep::{ScenarioSpec, SweepRunner, WorkloadSpec};
use crate::util::table::Table;

use super::fig8::Workload;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Prototype {
    Proposed,
    Axi,
    SharedCache,
}

impl Prototype {
    pub fn name(&self) -> &'static str {
        match self {
            Prototype::Proposed => "NoC+buffers (proposed)",
            Prototype::Axi => "AXI bus",
            Prototype::SharedCache => "shared FPGA cache",
        }
    }

    pub fn net(&self) -> NetKind {
        match self {
            Prototype::Axi => NetKind::Axi,
            _ => NetKind::Noc,
        }
    }

    pub fn fabric(&self) -> FabricKind {
        match self {
            Prototype::SharedCache => FabricKind::SharedCache {
                cache_bytes: 128 * 1024,
            },
            _ => FabricKind::Buffered,
        }
    }
}

pub const PROTOTYPES: [Prototype; 3] =
    [Prototype::Proposed, Prototype::Axi, Prototype::SharedCache];

// ---------------------------------------------------------------------------
// Fig. 13 — max throughput
// ---------------------------------------------------------------------------

pub struct Fig13 {
    /// (prototype, workload, max throughput flits/µs)
    pub results: Vec<(Prototype, Workload, f64)>,
    /// All 36 underlying rate-point scenarios (3 prototypes x 3
    /// workloads x 4 rates) for `BENCH_fig13_14.json`.
    pub report: crate::sweep::SweepReport,
}

/// Rates probed per (prototype, workload) cell; the cell's result is the
/// max throughput across them.
pub const FIG13_RATES: [f64; 4] = [2.0, 8.0, 16.0, 24.0];

const FIG13_WORKLOADS: [Workload; 3] =
    [Workload::IzigzagHwa, Workload::EightHwa, Workload::DfdivHwa];

/// The full Fig. 13 grid, one sweep across every prototype and workload
/// (sharded over all host cores at once instead of nine serial series).
pub fn fig13_grid(warmup_us: u64, window_us: u64) -> Vec<ScenarioSpec> {
    let mut specs = Vec::new();
    for proto in PROTOTYPES {
        for wl in FIG13_WORKLOADS {
            for rate in FIG13_RATES {
                specs.push(
                    ScenarioSpec::new(&format!(
                        "fig13[{},{},rate={rate}]",
                        proto.name(),
                        wl.name()
                    ))
                    .net(proto.net())
                    .fabric(proto.fabric())
                    .hwas(wl.hwa_mix())
                    .workload(WorkloadSpec::OpenLoop { rate_per_us: rate })
                    .warmup_us(warmup_us)
                    .window_us(window_us)
                    .seed(0x1314),
                );
            }
        }
    }
    specs
}

pub fn run_fig13(warmup_us: u64, window_us: u64) -> Fig13 {
    let report = SweepRunner::new()
        .run("fig13", fig13_grid(warmup_us, window_us))
        .expect("fig13 open-loop sweep");
    let mut results = Vec::new();
    let mut cells = report.scenarios.chunks(FIG13_RATES.len());
    for proto in PROTOTYPES {
        for wl in FIG13_WORKLOADS {
            let cell = cells.next().expect("grid covers every cell");
            let max = cell
                .iter()
                .map(|s| s.stats.throughput_flits_per_us)
                .fold(0.0, f64::max);
            results.push((proto, wl, max));
        }
    }
    Fig13 { results, report }
}

impl Fig13 {
    pub fn get(&self, proto: Prototype, wl: Workload) -> f64 {
        self.results
            .iter()
            .find(|(p, w, _)| *p == proto && *w == wl)
            .map(|(_, _, t)| *t)
            .unwrap()
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 13 — maximum throughput (flits/µs)",
            &["prototype", "Izigzag-HWA", "Eight-HWA", "Dfdiv-HWA"],
        );
        for proto in PROTOTYPES {
            t.row(&[
                proto.name().to_string(),
                format!("{:.2}", self.get(proto, Workload::IzigzagHwa)),
                format!("{:.2}", self.get(proto, Workload::EightHwa)),
                format!("{:.2}", self.get(proto, Workload::DfdivHwa)),
            ]);
        }
        // Relative rows (the paper's reported percentages).
        for proto in [Prototype::Axi, Prototype::SharedCache] {
            let rel = |wl| {
                100.0
                    * (self.get(Prototype::Proposed, wl) - self.get(proto, wl))
                    / self.get(Prototype::Proposed, wl)
            };
            t.row(&[
                format!("{} loss vs proposed", proto.name()),
                format!("{:.1}%", rel(Workload::IzigzagHwa)),
                format!("{:.1}%", rel(Workload::EightHwa)),
                format!("{:.1}%", rel(Workload::DfdivHwa)),
            ]);
        }
        t
    }
}

// ---------------------------------------------------------------------------
// Fig. 14 — communication latency for a single invocation
// ---------------------------------------------------------------------------

pub struct Fig14 {
    /// (prototype, mean communication latency µs)
    pub results: Vec<(Prototype, f64)>,
    /// The three underlying scenarios (latency percentiles included).
    pub report: crate::sweep::SweepReport,
}

/// The Fig. 14 scenario grid: one loaded open-loop run per prototype.
pub fn fig14_grid() -> Vec<ScenarioSpec> {
    const RATE: f64 = 8.0;
    PROTOTYPES
        .iter()
        .map(|proto| {
            ScenarioSpec::new(&format!("fig14[{}]", proto.name()))
                .net(proto.net())
                .fabric(proto.fabric())
                .hwas(Workload::IzigzagHwa.hwa_mix())
                .workload(WorkloadSpec::OpenLoop { rate_per_us: RATE })
                .warmup_us(5)
                .window_us(25)
                .seed(0x1414)
        })
        .collect()
}

/// Mean request->result latency for invocations completing inside a
/// loaded steady state: open-loop Izigzag traffic near the proposed
/// design's saturation point. Izigzag executes in one cycle, so the
/// measured quantity is pure communication — the Fig. 14 metric. The
/// baselines are saturated at this rate, so their queueing delay is the
/// latency gap the paper reports.
pub fn run_fig14() -> Fig14 {
    let report = SweepRunner::new()
        .run("fig14", fig14_grid())
        .expect("fig14 open-loop sweep");
    let results = PROTOTYPES
        .iter()
        .zip(&report.scenarios)
        .map(|(proto, s)| {
            assert!(
                s.stats.latency.count > 0,
                "fig14 {}: no completions",
                proto.name()
            );
            (*proto, s.stats.latency.mean_us)
        })
        .collect();
    Fig14 { results, report }
}

impl Fig14 {
    pub fn get(&self, proto: Prototype) -> f64 {
        self.results
            .iter()
            .find(|(p, _)| *p == proto)
            .map(|(_, l)| *l)
            .unwrap()
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 14 — communication latency, single invocation (µs)",
            &["prototype", "latency (µs)", "vs proposed"],
        );
        let base = self.get(Prototype::Proposed);
        for proto in PROTOTYPES {
            let l = self.get(proto);
            t.row(&[
                proto.name().to_string(),
                format!("{l:.3}"),
                format!("{:.2}x", l / base),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_both_baselines_lose_to_noc() {
        // Paper: NoC 2.42x better than AXI, 1.63x better than the cache.
        // Our calibrated models preserve the headline (NoC clearly best,
        // both baselines substantially worse); the AXI-vs-cache relative
        // order depends on cache-port vs bus-width constants and is a
        // documented deviation (EXPERIMENTS.md).
        let f = run_fig14();
        let noc = f.get(Prototype::Proposed);
        let axi = f.get(Prototype::Axi);
        let cache = f.get(Prototype::SharedCache);
        assert!(axi > 1.2 * noc, "axi {axi} vs noc {noc}");
        assert!(cache > 1.2 * noc, "cache {cache} vs noc {noc}");
    }

    #[test]
    fn fig13_proposed_wins_izigzag_clearly_eight_mildly() {
        let f = run_fig13(2, 10);
        // Izigzag-HWA: communication-bound; both baselines lose by a
        // clear margin (paper: AXI -27%, cache -22.5%).
        let wl = Workload::IzigzagHwa;
        let prop = f.get(Prototype::Proposed, wl);
        assert!(prop > 1.15 * f.get(Prototype::Axi, wl), "axi margin");
        assert!(prop > 1.15 * f.get(Prototype::SharedCache, wl), "cache margin");
        // Eight-HWA: mixed exec times damp the gap in our calibration
        // (paper reports larger losses; see docs/EXPERIMENTS.md §Deviations) —
        // assert the proposal is never materially beaten.
        let wl = Workload::EightHwa;
        let prop = f.get(Prototype::Proposed, wl);
        assert!(prop > 0.9 * f.get(Prototype::Axi, wl));
        assert!(prop > 0.9 * f.get(Prototype::SharedCache, wl));
    }

    #[test]
    fn fig13_dfdiv_is_execution_bound_everywhere() {
        let f = run_fig13(2, 10);
        let vals: Vec<f64> = PROTOTYPES
            .iter()
            .map(|p| f.get(*p, Workload::DfdivHwa))
            .collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            (max - min) / max < 0.35,
            "dfdiv throughput should be close across prototypes: {vals:?}"
        );
    }
}
