//! Fig. 13 (maximum throughput) and Fig. 14 (single-invocation
//! communication latency) for the three prototypes: the proposed
//! NoC + distributed buffers, AXI bus integration (§6.7), and the shared
//! FPGA cache design (§6.8).
//!
//! Paper results: vs. the proposal, AXI loses 27% (Izigzag-HWA) / 53%
//! (Eight-HWA) max throughput and the cache design loses 22.5% / 28.2%;
//! Dfdiv-HWA is execution-bound and identical everywhere. Communication
//! latency: NoC 2.42x better than AXI, 1.63x better than the cache.

use crate::clock::PS_PER_US;
use crate::sim::system::{FabricKind, NetKind, System, SystemConfig};
use crate::util::table::Table;

use super::fig8::{run_series, Workload};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Prototype {
    Proposed,
    Axi,
    SharedCache,
}

impl Prototype {
    pub fn name(&self) -> &'static str {
        match self {
            Prototype::Proposed => "NoC+buffers (proposed)",
            Prototype::Axi => "AXI bus",
            Prototype::SharedCache => "shared FPGA cache",
        }
    }

    pub fn net(&self) -> NetKind {
        match self {
            Prototype::Axi => NetKind::Axi,
            _ => NetKind::Noc,
        }
    }

    pub fn fabric(&self) -> FabricKind {
        match self {
            Prototype::SharedCache => FabricKind::SharedCache {
                cache_bytes: 128 * 1024,
            },
            _ => FabricKind::Buffered,
        }
    }
}

pub const PROTOTYPES: [Prototype; 3] =
    [Prototype::Proposed, Prototype::Axi, Prototype::SharedCache];

// ---------------------------------------------------------------------------
// Fig. 13 — max throughput
// ---------------------------------------------------------------------------

pub struct Fig13 {
    /// (prototype, workload, max throughput flits/µs)
    pub results: Vec<(Prototype, Workload, f64)>,
}

pub fn run_fig13(warmup_us: u64, window_us: u64) -> Fig13 {
    let rates = [2.0, 8.0, 16.0, 24.0];
    let mut results = Vec::new();
    for proto in PROTOTYPES {
        for wl in [Workload::IzigzagHwa, Workload::EightHwa, Workload::DfdivHwa]
        {
            let series = run_series(
                wl,
                &rates,
                proto.net(),
                proto.fabric(),
                warmup_us,
                window_us,
                0x1314,
            );
            results.push((proto, wl, series.max_throughput()));
        }
    }
    Fig13 { results }
}

impl Fig13 {
    pub fn get(&self, proto: Prototype, wl: Workload) -> f64 {
        self.results
            .iter()
            .find(|(p, w, _)| *p == proto && *w == wl)
            .map(|(_, _, t)| *t)
            .unwrap()
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 13 — maximum throughput (flits/µs)",
            &["prototype", "Izigzag-HWA", "Eight-HWA", "Dfdiv-HWA"],
        );
        for proto in PROTOTYPES {
            t.row(&[
                proto.name().to_string(),
                format!("{:.2}", self.get(proto, Workload::IzigzagHwa)),
                format!("{:.2}", self.get(proto, Workload::EightHwa)),
                format!("{:.2}", self.get(proto, Workload::DfdivHwa)),
            ]);
        }
        // Relative rows (the paper's reported percentages).
        for proto in [Prototype::Axi, Prototype::SharedCache] {
            let rel = |wl| {
                100.0
                    * (self.get(Prototype::Proposed, wl) - self.get(proto, wl))
                    / self.get(Prototype::Proposed, wl)
            };
            t.row(&[
                format!("{} loss vs proposed", proto.name()),
                format!("{:.1}%", rel(Workload::IzigzagHwa)),
                format!("{:.1}%", rel(Workload::EightHwa)),
                format!("{:.1}%", rel(Workload::DfdivHwa)),
            ]);
        }
        t
    }
}

// ---------------------------------------------------------------------------
// Fig. 14 — communication latency for a single invocation
// ---------------------------------------------------------------------------

pub struct Fig14 {
    /// (prototype, mean communication latency µs)
    pub results: Vec<(Prototype, f64)>,
}

/// Mean request->result latency for invocations completing inside a
/// loaded steady state: open-loop Izigzag traffic near the proposed
/// design's saturation point. Izigzag executes in one cycle, so the
/// measured quantity is pure communication — the Fig. 14 metric. The
/// baselines are saturated at this rate, so their queueing delay is the
/// latency gap the paper reports.
pub fn run_fig14() -> Fig14 {
    const RATE: f64 = 8.0;
    let mut results = Vec::new();
    for proto in PROTOTYPES {
        let mut cfg = SystemConfig::paper(Workload::IzigzagHwa.specs());
        cfg.net = proto.net();
        cfg.fabric = proto.fabric();
        let mut sys = System::new(cfg);
        sys.set_open_loop(RATE, 0x1414);
        // Warmup, then measure latencies of completions in the window.
        let warm_end = sys.now() + 5 * PS_PER_US;
        while sys.now() < warm_end {
            sys.step();
        }
        let skip: Vec<usize> = sys
            .open_sources
            .iter()
            .flatten()
            .map(|s| s.latencies_ps.len())
            .collect();
        let end = sys.now() + 25 * PS_PER_US;
        while sys.now() < end {
            sys.step();
        }
        let mut total = 0f64;
        let mut count = 0f64;
        for (s, skip_n) in sys.open_sources.iter().flatten().zip(&skip) {
            for l in s.latencies_ps.iter().skip(*skip_n) {
                total += *l as f64;
                count += 1.0;
            }
        }
        assert!(count > 0.0, "fig14 {}: no completions", proto.name());
        results.push((proto, total / count / PS_PER_US as f64));
    }
    Fig14 { results }
}

impl Fig14 {
    pub fn get(&self, proto: Prototype) -> f64 {
        self.results
            .iter()
            .find(|(p, _)| *p == proto)
            .map(|(_, l)| *l)
            .unwrap()
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 14 — communication latency, single invocation (µs)",
            &["prototype", "latency (µs)", "vs proposed"],
        );
        let base = self.get(Prototype::Proposed);
        for proto in PROTOTYPES {
            let l = self.get(proto);
            t.row(&[
                proto.name().to_string(),
                format!("{l:.3}"),
                format!("{:.2}x", l / base),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_both_baselines_lose_to_noc() {
        // Paper: NoC 2.42x better than AXI, 1.63x better than the cache.
        // Our calibrated models preserve the headline (NoC clearly best,
        // both baselines substantially worse); the AXI-vs-cache relative
        // order depends on cache-port vs bus-width constants and is a
        // documented deviation (EXPERIMENTS.md).
        let f = run_fig14();
        let noc = f.get(Prototype::Proposed);
        let axi = f.get(Prototype::Axi);
        let cache = f.get(Prototype::SharedCache);
        assert!(axi > 1.2 * noc, "axi {axi} vs noc {noc}");
        assert!(cache > 1.2 * noc, "cache {cache} vs noc {noc}");
    }

    #[test]
    fn fig13_proposed_wins_izigzag_clearly_eight_mildly() {
        let f = run_fig13(2, 10);
        // Izigzag-HWA: communication-bound; both baselines lose by a
        // clear margin (paper: AXI -27%, cache -22.5%).
        let wl = Workload::IzigzagHwa;
        let prop = f.get(Prototype::Proposed, wl);
        assert!(prop > 1.15 * f.get(Prototype::Axi, wl), "axi margin");
        assert!(prop > 1.15 * f.get(Prototype::SharedCache, wl), "cache margin");
        // Eight-HWA: mixed exec times damp the gap in our calibration
        // (paper reports larger losses; see EXPERIMENTS.md §Deviations) —
        // assert the proposal is never materially beaten.
        let wl = Workload::EightHwa;
        let prop = f.get(Prototype::Proposed, wl);
        assert!(prop > 0.9 * f.get(Prototype::Axi, wl));
        assert!(prop > 0.9 * f.get(Prototype::SharedCache, wl));
    }

    #[test]
    fn fig13_dfdiv_is_execution_bound_everywhere() {
        let f = run_fig13(2, 10);
        let vals: Vec<f64> = PROTOTYPES
            .iter()
            .map(|p| f.get(*p, Workload::DfdivHwa))
            .collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            (max - min) / max < 0.35,
            "dfdiv throughput should be close across prototypes: {vals:?}"
        );
    }
}
