//! Declarative floorplans: an explicit node → tile assignment over an
//! arbitrary mesh, replacing the old hardcoded "FPGA at the last node,
//! MMU beside it" layout.
//!
//! The paper's central claim is *scalability* of the FPGA–CMP
//! integration over the NoC; a [`Floorplan`] makes the scenarios that
//! claim is about representable: multiple FPGA interface tiles (each its
//! own fabric with its own inventory and clock domains), multiple
//! MMU/memory-controller tiles, and arbitrary placement on any mesh.
//!
//! The textual grammar is ESP-style rows of tile tokens, rows separated
//! by `/`:
//!
//! ```
//! use accnoc::sim::Floorplan;
//!
//! let plan = Floorplan::parse("P P F0 / P M P / P P F1").unwrap();
//! assert_eq!((plan.mesh.width, plan.mesh.height), (3, 3));
//! assert_eq!(plan.n_fabrics(), 2);
//! assert_eq!(plan.fabric_nodes(), vec![2, 8]);
//! assert_eq!(plan.mmu_nodes(), vec![4]);
//! assert_eq!(plan.proc_nodes().len(), 6);
//! ```
//!
//! Tokens: `P` = processor, `M` = MMU/memory controller, `F<k>` = FPGA
//! interface block of fabric `k`, `.` (or `E`) = empty tile. Node ids
//! are row-major (`id = y * width + x`), matching the mesh's router
//! numbering.

use crate::noc::mesh::MeshConfig;

/// What occupies one mesh node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tile {
    /// A CMP processor core (only the first 8 get cores — `src_id` is a
    /// 3-bit wire field; further processor tiles are inert).
    Proc,
    /// An FPGA interface block: the NoC endpoint of fabric `fabric_id`.
    FpgaIface { fabric_id: u8 },
    /// An MMU / memory-controller tile (§5 Fig. 5b DMA endpoint).
    Mmu,
    /// Nothing — the router exists, no endpoint is attached.
    Empty,
}

/// How processors are assigned to an MMU tile when the plan has more
/// than one (single-MMU plans are unaffected — every choice degenerates
/// to the one tile).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MmuAssign {
    /// Each processor uses the MMU tile with the smallest Manhattan
    /// distance from its own node (ties break toward the lower node id).
    #[default]
    Nearest,
    /// Processor `src_id` uses MMU tile `src_id % n_mmus` — a hashed
    /// spread that balances DMA load regardless of placement.
    Hashed,
}

impl MmuAssign {
    pub fn name(&self) -> &'static str {
        match self {
            MmuAssign::Nearest => "nearest",
            MmuAssign::Hashed => "hashed",
        }
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        match text.trim() {
            "nearest" => Ok(MmuAssign::Nearest),
            "hashed" => Ok(MmuAssign::Hashed),
            other => Err(format!("mmu_assign: {other:?} (nearest|hashed)")),
        }
    }
}

/// Why a floorplan (or the system configuration built on it) is
/// unbuildable. Every variant is a construction-time rejection: nothing
/// here can panic a running simulation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TopologyError {
    /// The plan text had no rows/tokens.
    EmptyPlan,
    /// Row `row` is empty (a doubled or trailing `/` — rejected rather
    /// than silently changing the mesh height).
    EmptyRow { row: usize },
    /// Row `row` has `got` tiles where the first row had `want`.
    RaggedRows { row: usize, want: usize, got: usize },
    /// A token that is not `P`, `M`, `F<k>`, `.` or `E`.
    BadToken { token: String },
    /// A programmatically-built plan whose tile vector does not cover
    /// the mesh (the only way tiles can "overlap" or fall out of range).
    TileCountMismatch { tiles: usize, nodes: usize },
    /// More nodes than the 7-bit flit routing field can address.
    TooManyNodes { nodes: usize },
    /// Two tiles claim the same fabric id.
    DuplicateFabricId { fabric_id: u8 },
    /// Fabric ids must be contiguous from 0 (`F0..F<n-1>`).
    NonContiguousFabricIds { n_fabrics: usize, missing: u8 },
    /// No processor tile: nothing could ever submit work.
    NoProcessors,
    /// No MMU tile: memory-access invocations would be unroutable.
    NoMmu,
    /// No FPGA interface tile: nothing could ever execute work.
    NoFabric,
    /// `SystemConfig.fabrics` must provide exactly one `FabricSpec` per
    /// `F<k>` tile in the plan.
    FabricCountMismatch { plan: usize, specs: usize },
    /// The AXI bus prototype models a single FPGA slave/master pair
    /// (§6.7); plans with more than one fabric need the NoC.
    AxiMultiFabric { fabrics: usize },
    /// A chain group in a `FabricSpec` names a channel index beyond the
    /// fabric's HWA inventory.
    ChainGroupOutOfRange { fabric: usize, member: usize },
    /// A fabric's declared inventory (accelerator cores plus the
    /// interface itself) does not fit the device's LUT/BRAM budget.
    ResourceBudget {
        fabric: usize,
        luts: u32,
        brams: u32,
        device: crate::synth::Device,
    },
    /// A `FabricSpec.reconfigurable` entry names a channel index beyond
    /// the fabric's HWA inventory.
    ReconfigSlotOutOfRange { fabric: usize, slot: usize },
}

impl std::fmt::Display for TopologyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologyError::EmptyPlan => write!(f, "empty floorplan"),
            TopologyError::EmptyRow { row } => write!(
                f,
                "floorplan row {row} is empty (doubled or trailing '/')"
            ),
            TopologyError::RaggedRows { row, want, got } => write!(
                f,
                "floorplan row {row} has {got} tiles, expected {want}"
            ),
            TopologyError::BadToken { token } => write!(
                f,
                "bad floorplan token {token:?} (want P, M, F<k>, or .)"
            ),
            TopologyError::TileCountMismatch { tiles, nodes } => write!(
                f,
                "{tiles} tiles for a {nodes}-node mesh"
            ),
            TopologyError::TooManyNodes { nodes } => write!(
                f,
                "{nodes} nodes exceed the 7-bit flit routing field (128)"
            ),
            TopologyError::DuplicateFabricId { fabric_id } => {
                write!(f, "fabric id F{fabric_id} appears on two tiles")
            }
            TopologyError::NonContiguousFabricIds { n_fabrics, missing } => {
                write!(
                    f,
                    "{n_fabrics} fabric tiles but F{missing} is missing \
                     (ids must be F0..F{})",
                    n_fabrics.saturating_sub(1)
                )
            }
            TopologyError::NoProcessors => {
                write!(f, "floorplan has no processor tiles")
            }
            TopologyError::NoMmu => write!(f, "floorplan has no MMU tile"),
            TopologyError::NoFabric => {
                write!(f, "floorplan has no FPGA interface tile")
            }
            TopologyError::FabricCountMismatch { plan, specs } => write!(
                f,
                "floorplan has {plan} fabric tiles but {specs} FabricSpecs \
                 were provided"
            ),
            TopologyError::AxiMultiFabric { fabrics } => write!(
                f,
                "the AXI prototype supports exactly one fabric endpoint, \
                 got {fabrics} (use net = noc for multi-FPGA plans)"
            ),
            TopologyError::ChainGroupOutOfRange { fabric, member } => write!(
                f,
                "fabric {fabric}: chain group member {member} names no \
                 configured channel"
            ),
            TopologyError::ResourceBudget {
                fabric,
                luts,
                brams,
                device,
            } => write!(
                f,
                "fabric {fabric}: inventory needs {luts} LUTs / {brams} \
                 BRAMs, exceeding the {} budget ({} / {})",
                device.name, device.luts, device.brams
            ),
            TopologyError::ReconfigSlotOutOfRange { fabric, slot } => write!(
                f,
                "fabric {fabric}: reconfigurable slot {slot} names no \
                 configured channel"
            ),
        }
    }
}

impl std::error::Error for TopologyError {}

/// An explicit node → tile assignment over a mesh.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Floorplan {
    pub mesh: MeshConfig,
    /// One tile per node, row-major (`tiles[y * width + x]`).
    pub tiles: Vec<Tile>,
}

impl Floorplan {
    /// The legacy single-FPGA lowering the entire pre-floorplan test and
    /// experiment corpus assumed: FPGA interface at the last node, MMU
    /// beside it, processors everywhere else. `SystemConfig::paper`
    /// builds exactly this plan.
    pub fn single_fpga(mesh: MeshConfig) -> Self {
        let n = mesh.width as usize * mesh.height as usize;
        let mut tiles = vec![Tile::Proc; n];
        if n >= 1 {
            tiles[n - 1] = Tile::FpgaIface { fabric_id: 0 };
        }
        if n >= 2 {
            tiles[n - 2] = Tile::Mmu;
        }
        Self { mesh, tiles }
    }

    /// Parse the row grammar (`"P P F0 / P M P / P P F1"`). Mesh
    /// dimensions come from the text (buffer depths stay at the mesh
    /// defaults); the result is validated.
    pub fn parse(text: &str) -> Result<Self, TopologyError> {
        if text.trim().is_empty() {
            return Err(TopologyError::EmptyPlan);
        }
        let rows: Vec<&str> = text.split('/').map(str::trim).collect();
        let mut tiles = Vec::new();
        let mut width = 0usize;
        for (y, row) in rows.iter().enumerate() {
            // An empty row is a typo (doubled/trailing '/'), not a
            // request for a shorter mesh.
            if row.is_empty() {
                return Err(TopologyError::EmptyRow { row: y });
            }
            let toks: Vec<&str> = row.split_whitespace().collect();
            if y == 0 {
                width = toks.len();
                if width == 0 {
                    return Err(TopologyError::EmptyPlan);
                }
            } else if toks.len() != width {
                return Err(TopologyError::RaggedRows {
                    row: y,
                    want: width,
                    got: toks.len(),
                });
            }
            for tok in toks {
                tiles.push(Self::parse_token(tok)?);
            }
        }
        if width > u8::MAX as usize || rows.len() > u8::MAX as usize {
            return Err(TopologyError::TooManyNodes { nodes: tiles.len() });
        }
        let plan = Self {
            mesh: MeshConfig {
                width: width as u8,
                height: rows.len() as u8,
                ..MeshConfig::default()
            },
            tiles,
        };
        plan.validate()?;
        Ok(plan)
    }

    fn parse_token(tok: &str) -> Result<Tile, TopologyError> {
        match tok {
            "P" | "p" => Ok(Tile::Proc),
            "M" | "m" => Ok(Tile::Mmu),
            "." | "E" | "e" => Ok(Tile::Empty),
            _ => {
                let bad = || TopologyError::BadToken {
                    token: tok.to_string(),
                };
                let id = tok
                    .strip_prefix('F')
                    .or_else(|| tok.strip_prefix('f'))
                    .ok_or_else(bad)?;
                let id: u8 = id.parse().map_err(|_| bad())?;
                Ok(Tile::FpgaIface { fabric_id: id })
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.mesh.width as usize * self.mesh.height as usize
    }

    /// Reject every unbuildable plan with a specific [`TopologyError`]:
    /// tile/node mismatches (the dense form of "overlapping or
    /// out-of-range tiles"), duplicate or gappy fabric ids, and plans
    /// with no processors, no MMU, or no fabric.
    pub fn validate(&self) -> Result<(), TopologyError> {
        let nodes = self.n_nodes();
        if self.tiles.len() != nodes {
            return Err(TopologyError::TileCountMismatch {
                tiles: self.tiles.len(),
                nodes,
            });
        }
        if nodes > 128 {
            return Err(TopologyError::TooManyNodes { nodes });
        }
        let mut fabric_ids: Vec<u8> = Vec::new();
        let mut procs = 0usize;
        let mut mmus = 0usize;
        for tile in &self.tiles {
            match tile {
                Tile::Proc => procs += 1,
                Tile::Mmu => mmus += 1,
                Tile::Empty => {}
                Tile::FpgaIface { fabric_id } => {
                    if fabric_ids.contains(fabric_id) {
                        return Err(TopologyError::DuplicateFabricId {
                            fabric_id: *fabric_id,
                        });
                    }
                    fabric_ids.push(*fabric_id);
                }
            }
        }
        if fabric_ids.is_empty() {
            return Err(TopologyError::NoFabric);
        }
        for want in 0..fabric_ids.len() as u8 {
            if !fabric_ids.contains(&want) {
                return Err(TopologyError::NonContiguousFabricIds {
                    n_fabrics: fabric_ids.len(),
                    missing: want,
                });
            }
        }
        if mmus == 0 {
            return Err(TopologyError::NoMmu);
        }
        if procs == 0 {
            return Err(TopologyError::NoProcessors);
        }
        Ok(())
    }

    /// Number of FPGA interface tiles (== number of fabrics after
    /// validation).
    pub fn n_fabrics(&self) -> usize {
        self.tiles
            .iter()
            .filter(|t| matches!(t, Tile::FpgaIface { .. }))
            .count()
    }

    /// Node of each fabric's interface tile, indexed by fabric id
    /// (`fabric_nodes()[k]` is where `F<k>` sits).
    pub fn fabric_nodes(&self) -> Vec<usize> {
        let mut nodes = vec![usize::MAX; self.n_fabrics()];
        for (node, tile) in self.tiles.iter().enumerate() {
            if let Tile::FpgaIface { fabric_id } = tile {
                if let Some(slot) = nodes.get_mut(*fabric_id as usize) {
                    *slot = node;
                }
            }
        }
        nodes
    }

    /// Nodes of every MMU tile, ascending.
    pub fn mmu_nodes(&self) -> Vec<usize> {
        self.tiles
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, Tile::Mmu))
            .map(|(n, _)| n)
            .collect()
    }

    /// Nodes of every processor tile, ascending. Only the first 8 host
    /// cores (3-bit `src_id`); the rest are inert.
    pub fn proc_nodes(&self) -> Vec<usize> {
        self.tiles
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, Tile::Proc))
            .map(|(n, _)| n)
            .collect()
    }

    /// Manhattan distance between two nodes on this mesh.
    pub fn distance(&self, a: usize, b: usize) -> usize {
        let w = self.mesh.width as usize;
        let (ax, ay) = (a % w, a / w);
        let (bx, by) = (b % w, b / w);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// The MMU node assigned to a processor at `node` under `assign`
    /// (where `src_id` is the processor's 3-bit wire id).
    pub fn mmu_for(&self, node: usize, src_id: usize, assign: MmuAssign) -> usize {
        let mmus = self.mmu_nodes();
        debug_assert!(!mmus.is_empty(), "validated plans have an MMU");
        match assign {
            MmuAssign::Hashed => mmus[src_id % mmus.len()],
            MmuAssign::Nearest => *mmus
                .iter()
                .min_by_key(|m| (self.distance(node, **m), **m))
                .expect("non-empty"),
        }
    }

    /// Canonical single-line form (`"P P F0 / P M P / P P F1"`), the
    /// inverse of [`Floorplan::parse`].
    pub fn to_spec_string(&self) -> String {
        let w = self.mesh.width as usize;
        let mut rows = Vec::new();
        for chunk in self.tiles.chunks(w) {
            let row: Vec<String> = chunk
                .iter()
                .map(|t| match t {
                    Tile::Proc => "P".to_string(),
                    Tile::Mmu => "M".to_string(),
                    Tile::Empty => ".".to_string(),
                    Tile::FpgaIface { fabric_id } => format!("F{fabric_id}"),
                })
                .collect();
            rows.push(row.join(" "));
        }
        rows.join(" / ")
    }

    /// Multi-line tile map for human output (`accnoc topology`): one row
    /// per mesh row, processor tiles numbered by core id.
    pub fn render(&self) -> String {
        let w = self.mesh.width as usize;
        let mut out = String::new();
        let mut core = 0usize;
        let cells: Vec<String> = self
            .tiles
            .iter()
            .map(|t| match t {
                Tile::Proc => {
                    let label = if core < 8 {
                        format!("P{core}")
                    } else {
                        "P-".to_string()
                    };
                    core += 1;
                    label
                }
                Tile::Mmu => "M".to_string(),
                Tile::Empty => ".".to_string(),
                Tile::FpgaIface { fabric_id } => format!("F{fabric_id}"),
            })
            .collect();
        for row in cells.chunks(w) {
            out.push_str("  ");
            for cell in row {
                out.push_str(&format!("{cell:>4}"));
            }
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Floorplan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_spec_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_lowering_matches_the_old_hardcoded_layout() {
        let plan = Floorplan::single_fpga(MeshConfig::default());
        assert_eq!(plan.n_nodes(), 9);
        assert_eq!(plan.fabric_nodes(), vec![8], "FPGA at the last node");
        assert_eq!(plan.mmu_nodes(), vec![7], "MMU beside it");
        assert_eq!(plan.proc_nodes(), vec![0, 1, 2, 3, 4, 5, 6]);
        assert!(plan.validate().is_ok());
    }

    #[test]
    fn parse_round_trips_through_display() {
        for text in [
            "P P F0 / P M P / P P F1",
            "F0 P F1 / P M P / F2 P F3",
            "P M / F0 .",
        ] {
            let plan = Floorplan::parse(text).unwrap();
            assert_eq!(plan.to_spec_string(), text);
            let again = Floorplan::parse(&plan.to_spec_string()).unwrap();
            assert_eq!(again.tiles, plan.tiles);
        }
    }

    #[test]
    fn parse_derives_mesh_dimensions() {
        let plan = Floorplan::parse("P P P P / M F0 P P").unwrap();
        assert_eq!((plan.mesh.width, plan.mesh.height), (4, 2));
        assert_eq!(plan.fabric_nodes(), vec![5]);
    }

    #[test]
    fn rejects_ragged_rows_and_bad_tokens() {
        assert_eq!(
            Floorplan::parse("P P F0 / P M"),
            Err(TopologyError::RaggedRows {
                row: 1,
                want: 3,
                got: 2
            })
        );
        assert_eq!(
            Floorplan::parse("P Q / M F0"),
            Err(TopologyError::BadToken {
                token: "Q".to_string()
            })
        );
        assert_eq!(Floorplan::parse("  "), Err(TopologyError::EmptyPlan));
        // A doubled '/' must not silently shrink the mesh.
        assert_eq!(
            Floorplan::parse("P P F0 / / P M P"),
            Err(TopologyError::EmptyRow { row: 1 })
        );
        assert_eq!(
            Floorplan::parse("P M F0 /"),
            Err(TopologyError::EmptyRow { row: 1 })
        );
    }

    #[test]
    fn rejects_plans_missing_a_role() {
        assert_eq!(
            Floorplan::parse("M F0 / F1 ."),
            Err(TopologyError::NoProcessors)
        );
        assert_eq!(
            Floorplan::parse("P F0 / P P"),
            Err(TopologyError::NoMmu)
        );
        assert_eq!(
            Floorplan::parse("P M / P P"),
            Err(TopologyError::NoFabric)
        );
    }

    #[test]
    fn rejects_duplicate_and_gappy_fabric_ids() {
        assert_eq!(
            Floorplan::parse("P F0 / M F0"),
            Err(TopologyError::DuplicateFabricId { fabric_id: 0 })
        );
        assert_eq!(
            Floorplan::parse("P F0 / M F2"),
            Err(TopologyError::NonContiguousFabricIds {
                n_fabrics: 2,
                missing: 1
            })
        );
    }

    #[test]
    fn rejects_tile_count_mismatch() {
        // A programmatically-built plan whose tiles do not cover the
        // mesh — the dense-representation analog of an out-of-range or
        // overlapping tile assignment.
        let mut plan = Floorplan::single_fpga(MeshConfig::default());
        plan.tiles.pop();
        assert_eq!(
            plan.validate(),
            Err(TopologyError::TileCountMismatch { tiles: 8, nodes: 9 })
        );
    }

    #[test]
    fn too_small_legacy_mesh_is_rejected_not_silently_empty() {
        // The old SystemConfig accepted a 1x2 mesh and built a system
        // with zero processors; the plan now rejects it.
        let plan = Floorplan::single_fpga(MeshConfig {
            width: 1,
            height: 2,
            ..MeshConfig::default()
        });
        assert_eq!(plan.validate(), Err(TopologyError::NoProcessors));
    }

    #[test]
    fn nearest_mmu_assignment_uses_manhattan_distance() {
        // M at nodes 1 and 7 on a 3x3: node 0 is nearer 1; node 6 nearer 7.
        let plan = Floorplan::parse("P M P / P F0 P / P M P").unwrap();
        assert_eq!(plan.mmu_nodes(), vec![1, 7]);
        assert_eq!(plan.mmu_for(0, 0, MmuAssign::Nearest), 1);
        assert_eq!(plan.mmu_for(6, 4, MmuAssign::Nearest), 7);
        // Equidistant (node 3): ties break toward the lower node id.
        assert_eq!(plan.mmu_for(3, 1, MmuAssign::Nearest), 1);
        // Hashed spreads by src_id.
        assert_eq!(plan.mmu_for(0, 0, MmuAssign::Hashed), 1);
        assert_eq!(plan.mmu_for(0, 1, MmuAssign::Hashed), 7);
        assert_eq!(plan.mmu_for(0, 2, MmuAssign::Hashed), 1);
    }

    #[test]
    fn render_labels_cores_in_node_order() {
        let plan = Floorplan::parse("P P F0 / P M P").unwrap();
        let grid = plan.render();
        assert!(grid.contains("P0"));
        assert!(grid.contains("P3"), "{grid}");
        assert!(grid.contains("F0"));
        assert_eq!(grid.lines().count(), 2);
    }
}
