//! Full-system simulation: system assembly ([`System`]) and the paper's
//! experiment drivers (`experiments`, each a thin grid over
//! [`crate::sweep`]).
//!
//! A [`System`] wires CMP cores, the interconnect (mesh NoC or AXI
//! baseline), the FPGA fabric (distributed buffers or shared-cache
//! baseline) and the MMU onto a multi-domain picosecond clock, with
//! idle-skipping event-driven scheduling on top. Minimal closed loop:
//!
//! ```
//! use accnoc::cmp::core::{InvokeSpec, Segment};
//! use accnoc::fpga::hwa::spec_by_name;
//! use accnoc::sim::{System, SystemConfig};
//!
//! let cfg = SystemConfig::paper(vec![spec_by_name("dfadd").unwrap()]);
//! let mut sys = System::new(cfg);
//! sys.load_program(
//!     0,
//!     vec![Segment::Invoke(InvokeSpec::direct(0, vec![1, 2, 3, 4], 2))],
//! );
//! assert!(sys.run_until_done(50_000_000)); // 50 simulated µs
//! assert_eq!(sys.fabric.tasks_executed(), 1);
//! ```

pub mod experiments;
pub mod system;

pub use system::{Fabric, FabricKind, Net, NetKind, System, SystemConfig};
