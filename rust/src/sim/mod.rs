//! Full-system simulation: system assembly ([`System`]) and the paper's
//! experiment drivers (`experiments`, each a thin grid over
//! [`crate::sweep`]).
//!
//! A [`System`] wires CMP cores, the interconnect (mesh NoC or AXI
//! baseline), the FPGA fabric (distributed buffers or shared-cache
//! baseline) and the MMU onto a multi-domain picosecond clock, with
//! idle-skipping event-driven scheduling on top. Work is submitted
//! through the [`crate::accel`] driver layer; a minimal closed loop:
//!
//! ```
//! use accnoc::accel::{AccelRuntime, Job};
//! use accnoc::fpga::hwa::spec_by_name;
//! use accnoc::sim::SystemConfig;
//!
//! let cfg = SystemConfig::paper(vec![spec_by_name("dfadd").unwrap()]);
//! let mut rt = AccelRuntime::new(cfg);
//! let dfadd = rt.accel(0).unwrap();
//! let receipt = rt.submit(0, Job::on(dfadd).direct(vec![1, 2, 3, 4])).unwrap();
//! assert!(rt.run_until_done(50_000_000)); // 50 simulated µs
//! assert_eq!(rt.system().fabric().tasks_executed(), 1);
//! assert!(rt.poll(receipt).is_some());
//! ```

pub mod experiments;
pub mod floorplan;
pub mod system;

pub use floorplan::{Floorplan, MmuAssign, Tile, TopologyError};
pub use system::{
    Fabric, FabricKind, FabricSpec, FabricTileStats, Net, NetKind, System,
    SystemConfig,
};
