//! Full-system simulation: system assembly and experiment drivers.

pub mod experiments;
pub mod system;

pub use system::{Fabric, FabricKind, Net, NetKind, System, SystemConfig};
