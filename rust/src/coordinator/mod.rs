//! Coordinator: configuration loading, system construction, experiment
//! dispatch and reporting — the surface behind the `accnoc` CLI.

use crate::sim::experiments::{fig10, fig13_14, fig6, fig7, fig8, fig9, tables};
use crate::util::cli::Args;
use crate::util::config_text::ConfigText;

pub const USAGE: &str = "\
accnoc — FPGA multi-accelerator / NoC-CMP integration simulator
(reproduction of Lin et al., IEEE TMSCS 2017; see DESIGN.md)

USAGE:
    accnoc <subcommand> [options]

SUBCOMMANDS:
    experiment <id>   regenerate a paper result:
                      fig6 | fig7 | fig8 | fig9 | fig10 | fig13 | fig14 |
                      table2 | table3 | table4 | all
    run               run a custom simulation from a config file
                      (--config path, see configs/ samples)
    synth             print the synthesis model sweep (fmax + resources)
    list              list HWA benchmarks and artifacts
    selftest          quick end-to-end smoke of all three prototypes
    help              this text

OPTIONS:
    --warmup-us N     measurement warmup (default 5)
    --window-us N     measurement window (default 40)
    --csv             CSV output instead of tables
";

fn emit(t: crate::util::table::Table, csv: bool) {
    if csv {
        print!("{}", t.render_csv());
    } else {
        t.print();
    }
}

pub fn main_with(args: Args) -> Result<(), String> {
    let csv = args.has_flag("csv");
    let warmup: u64 = args.get_parse_or("warmup-us", 5)?;
    let window: u64 = args.get_parse_or("window-us", 40)?;
    match args.subcommand.as_deref() {
        Some("experiment") => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .ok_or("experiment: missing id (fig6..fig14, table2..4, all)")?;
            run_experiment(id, warmup, window, csv)
        }
        Some("run") => run_custom(&args, csv),
        Some("synth") => {
            emit(fig7::run().table(), csv);
            emit(fig7::run().component_table(), csv);
            emit(tables::table4(), csv);
            Ok(())
        }
        Some("list") => {
            emit(tables::table3_table(), csv);
            #[cfg(feature = "pjrt")]
            match crate::runtime::Runtime::load_default() {
                Ok(rt) => println!("artifacts: {:?}", rt.names()),
                Err(e) => println!("artifacts not loaded: {e:#}"),
            }
            #[cfg(not(feature = "pjrt"))]
            println!(
                "artifacts: built without the `pjrt` feature \
                 (rebuild with --features pjrt after `make artifacts`)"
            );
            Ok(())
        }
        Some("selftest") => selftest(),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand {other:?}\n{USAGE}")),
    }
}

pub fn run_experiment(
    id: &str,
    warmup: u64,
    window: u64,
    csv: bool,
) -> Result<(), String> {
    match id {
        "fig6" => emit(fig6::run().table(), csv),
        "fig7" => {
            let f = fig7::run();
            emit(f.table(), csv);
            emit(f.component_table(), csv);
        }
        "fig8" => {
            for wl in [
                fig8::Workload::IzigzagHwa,
                fig8::Workload::EightHwa,
                fig8::Workload::DfdivHwa,
            ] {
                emit(fig8::run(wl, warmup, window).table(), csv);
            }
        }
        "fig9" => emit(fig9::run().table(), csv),
        "fig10" => emit(fig10::run().table(), csv),
        "fig13" => emit(fig13_14::run_fig13(warmup, window).table(), csv),
        "fig14" => emit(fig13_14::run_fig14().table(), csv),
        "table2" => emit(tables::table2(), csv),
        "table3" => emit(tables::table3_table(), csv),
        "table4" => emit(tables::table4(), csv),
        "all" => {
            for id in [
                "table2", "table3", "table4", "fig6", "fig7", "fig8", "fig9",
                "fig10", "fig13", "fig14",
            ] {
                run_experiment(id, warmup, window, csv)?;
            }
        }
        other => return Err(format!("unknown experiment {other:?}")),
    }
    Ok(())
}

/// Custom run: config-file-driven single simulation.
fn run_custom(args: &Args, csv: bool) -> Result<(), String> {
    use crate::fpga::hwa::{spec_by_name, table3};
    use crate::sim::system::{FabricKind, NetKind, System, SystemConfig};
    use crate::workload::random::measure_open_rate_point;

    let cfg_text = match args.get("config") {
        Some(path) => ConfigText::load(std::path::Path::new(path))?,
        None => ConfigText::parse("")?,
    };
    let hwas = cfg_text
        .get("system.hwas")
        .map(|s| s.to_string())
        .unwrap_or_else(|| "first8".to_string());
    let specs = match hwas.as_str() {
        "first8" => table3().into_iter().take(8).collect(),
        "jpeg" => vec![
            spec_by_name("izigzag").unwrap(),
            spec_by_name("iquantize").unwrap(),
            spec_by_name("idct").unwrap(),
            spec_by_name("shiftbound").unwrap(),
        ],
        list => list
            .split(|c| c == '+' || c == ',')
            .map(|n| {
                spec_by_name(n.trim())
                    .ok_or_else(|| format!("unknown HWA {n:?}"))
            })
            .collect::<Result<Vec<_>, _>>()?,
    };
    let mut sys_cfg = SystemConfig::paper(specs);
    sys_cfg.n_tbs = cfg_text.get_or("system.task_buffers", 2usize)?;
    sys_cfg.pr_group = cfg_text.get_or("system.pr_group", 4usize)?;
    sys_cfg.ps_group = cfg_text.get_or("system.ps_group", 4usize)?;
    sys_cfg.net = match cfg_text.get("system.net").unwrap_or("noc") {
        "axi" => NetKind::Axi,
        _ => NetKind::Noc,
    };
    if cfg_text.get("system.fabric") == Some("shared_cache") {
        sys_cfg.fabric = FabricKind::SharedCache {
            cache_bytes: cfg_text.get_or("system.cache_kib", 128u32)? * 1024,
        };
    }
    let rate: f64 = cfg_text.get_or("workload.rate_per_us", 4.0)?;
    let seed: u64 = cfg_text.get_or("workload.seed", 7u64)?;
    let warmup: u64 = cfg_text.get_or("workload.warmup_us", 5u64)?;
    let window: u64 = cfg_text.get_or("workload.window_us", 40u64)?;
    let mut sys = System::new(sys_cfg);
    sys.set_open_loop(rate, seed);
    let p = measure_open_rate_point(&mut sys, warmup, window);
    let mut t = crate::util::table::Table::new(
        "custom run",
        &["metric", "value"],
    );
    t.row(&["injection (flits/us)".into(), format!("{:.2}", p.injection_flits_per_us)]);
    t.row(&["throughput (flits/us)".into(), format!("{:.2}", p.throughput_flits_per_us)]);
    t.row(&["busy fraction".into(), format!("{:.3}", p.busy_fraction)]);
    t.row(&["completions (/us)".into(), format!("{:.2}", p.completions_per_us)]);
    t.row(&["tasks executed".into(), sys.fabric.tasks_executed().to_string()]);
    emit(t, csv);
    Ok(())
}

fn selftest() -> Result<(), String> {
    use crate::cmp::core::{InvokeSpec, Segment};
    use crate::fpga::hwa::table3;
    use crate::sim::system::{FabricKind, NetKind, System, SystemConfig};

    for (name, net, fabric) in [
        ("noc+buffers", NetKind::Noc, FabricKind::Buffered),
        ("axi+buffers", NetKind::Axi, FabricKind::Buffered),
        (
            "noc+cache",
            NetKind::Noc,
            FabricKind::SharedCache {
                cache_bytes: 128 * 1024,
            },
        ),
    ] {
        let mut cfg = SystemConfig::paper(table3().into_iter().take(8).collect());
        cfg.net = net;
        cfg.fabric = fabric;
        let mut sys = System::new(cfg);
        for i in 0..sys.n_procs() {
            let spec = sys.config.specs[i % 8].clone();
            sys.load_program(
                i,
                vec![Segment::Invoke(InvokeSpec::direct(
                    (i % 8) as u8,
                    (0..spec.in_words as u32).collect(),
                    spec.out_words,
                ))],
            );
        }
        let ok = sys.run_until_done(100_000 * crate::clock::PS_PER_US);
        if !ok {
            return Err(format!("selftest {name}: did not complete"));
        }
        println!(
            "selftest {name}: OK ({} tasks executed)",
            sys.fabric.tasks_executed()
        );
    }
    Ok(())
}
