//! Coordinator: configuration loading, system construction, experiment
//! dispatch and reporting — the surface behind the `accnoc` CLI.

use crate::sim::experiments::{fig10, fig13_14, fig6, fig7, fig8, fig9, tables};
use crate::sweep::{SweepRunner, SweepSpec};
use crate::util::cli::Args;

pub const USAGE: &str = "\
accnoc — FPGA multi-accelerator / NoC-CMP integration simulator
(reproduction of Lin et al., IEEE TMSCS 2017; see docs/ARCHITECTURE.md)

USAGE:
    accnoc <subcommand> [options]

SUBCOMMANDS:
    experiment <id>   regenerate a paper result:
                      fig6 | fig7 | fig8 | fig9 | fig10 | fig13 | fig14 |
                      table2 | table3 | table4 | all
    sweep <spec>      cartesian-expand a TOML/JSON sweep spec, run the
                      scenario grid on all host cores, and write the
                      machine-readable BENCH_*.json report
                      (see configs/ and docs/EXPERIMENTS.md)
    run               run one scenario from a config file
                      (--config path; same [system]/[workload] keys as a
                      sweep spec, without list values)
    synth             print the synthesis model sweep (fmax + resources)
    list              list HWA benchmarks and artifacts
    selftest          quick end-to-end smoke of all three prototypes
    help              this text

OPTIONS:
    --warmup-us N     measurement warmup (default 5)
    --window-us N     measurement window (default 40)
    --csv             CSV output instead of tables
    --threads N       sweep worker threads (default: all host cores)
    --out PATH        sweep JSON report path (default: the spec's
                      `output`, else BENCH_<name>.json)
    --csv-out PATH    also write the sweep report as CSV
    --dry-run         expand and list the sweep grid without running
";

fn emit(t: crate::util::table::Table, csv: bool) {
    if csv {
        print!("{}", t.render_csv());
    } else {
        t.print();
    }
}

pub fn main_with(args: Args) -> Result<(), String> {
    let csv = args.has_flag("csv");
    let warmup: u64 = args.get_parse_or("warmup-us", 5)?;
    let window: u64 = args.get_parse_or("window-us", 40)?;
    match args.subcommand.as_deref() {
        Some("experiment") => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .ok_or("experiment: missing id (fig6..fig14, table2..4, all)")?;
            run_experiment(id, warmup, window, csv)
        }
        Some("run") => run_custom(&args, csv),
        Some("sweep") => run_sweep(&args, csv),
        Some("synth") => {
            emit(fig7::run().table(), csv);
            emit(fig7::run().component_table(), csv);
            emit(tables::table4(), csv);
            Ok(())
        }
        Some("list") => {
            emit(tables::table3_table(), csv);
            #[cfg(feature = "pjrt")]
            match crate::runtime::Runtime::load_default() {
                Ok(rt) => println!("artifacts: {:?}", rt.names()),
                Err(e) => println!("artifacts not loaded: {e:#}"),
            }
            #[cfg(not(feature = "pjrt"))]
            println!(
                "artifacts: built without the `pjrt` feature \
                 (rebuild with --features pjrt after `make artifacts`)"
            );
            Ok(())
        }
        Some("selftest") => selftest(),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand {other:?}\n{USAGE}")),
    }
}

pub fn run_experiment(
    id: &str,
    warmup: u64,
    window: u64,
    csv: bool,
) -> Result<(), String> {
    match id {
        "fig6" => emit(fig6::run().table(), csv),
        "fig7" => {
            let f = fig7::run();
            emit(f.table(), csv);
            emit(f.component_table(), csv);
        }
        "fig8" => {
            for wl in [
                fig8::Workload::IzigzagHwa,
                fig8::Workload::EightHwa,
                fig8::Workload::DfdivHwa,
            ] {
                emit(fig8::run(wl, warmup, window).table(), csv);
            }
        }
        "fig9" => emit(fig9::run().table(), csv),
        "fig10" => emit(fig10::run().table(), csv),
        "fig13" => emit(fig13_14::run_fig13(warmup, window).table(), csv),
        "fig14" => emit(fig13_14::run_fig14().table(), csv),
        "table2" => emit(tables::table2(), csv),
        "table3" => emit(tables::table3_table(), csv),
        "table4" => emit(tables::table4(), csv),
        "all" => {
            for id in [
                "table2", "table3", "table4", "fig6", "fig7", "fig8", "fig9",
                "fig10", "fig13", "fig14",
            ] {
                run_experiment(id, warmup, window, csv)?;
            }
        }
        other => return Err(format!("unknown experiment {other:?}")),
    }
    Ok(())
}

/// Custom run: one scenario from a config file (a sweep spec whose
/// values are all single — the same parser, minus the grid).
fn run_custom(args: &Args, csv: bool) -> Result<(), String> {
    let sweep = match args.get("config") {
        Some(path) => SweepSpec::load(std::path::Path::new(path))?,
        None => SweepSpec::parse_toml("name = custom")?,
    };
    let scenarios = sweep.expand()?;
    if scenarios.len() != 1 {
        return Err(format!(
            "run: config expands to {} scenarios; use `accnoc sweep` for \
             grids",
            scenarios.len()
        ));
    }
    let report = SweepRunner::with_threads(1).run(&sweep.name, scenarios)?;
    emit(report.table(), csv);
    Ok(())
}

/// The `sweep` verb: expand a TOML/JSON spec, run the grid on all host
/// cores, write the machine-readable report.
fn run_sweep(args: &Args, csv: bool) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .ok_or("sweep: missing spec path (see configs/)")?;
    let sweep = SweepSpec::load(std::path::Path::new(path))?;
    let scenarios = sweep.expand()?;
    if args.has_flag("dry-run") {
        println!(
            "sweep {}: {} scenarios from {} axes",
            sweep.name,
            scenarios.len(),
            sweep.axes().len()
        );
        for s in &scenarios {
            println!("  {}", s.name);
        }
        return Ok(());
    }
    let runner = match args.get_parse::<usize>("threads")? {
        Some(n) => SweepRunner::with_threads(n),
        None => SweepRunner::new(),
    };
    eprintln!(
        "sweep {}: {} scenarios on {} threads",
        sweep.name,
        scenarios.len(),
        runner.threads()
    );
    let t0 = std::time::Instant::now();
    let report = runner.run(&sweep.name, scenarios)?;
    let wall = t0.elapsed();
    emit(report.table(), csv);
    let out = args
        .get("out")
        .map(|s| s.to_string())
        .unwrap_or_else(|| sweep.output_path());
    report.write_json(std::path::Path::new(&out))?;
    eprintln!(
        "sweep {}: {} scenarios in {:.2} s -> {out}",
        sweep.name,
        report.scenarios.len(),
        wall.as_secs_f64()
    );
    if let Some(csv_out) = args.get("csv-out") {
        report.write_csv(std::path::Path::new(csv_out))?;
        eprintln!("sweep {}: CSV -> {csv_out}", sweep.name);
    }
    Ok(())
}

fn selftest() -> Result<(), String> {
    use crate::accel::{AccelRuntime, Job};
    use crate::fpga::hwa::table3;
    use crate::sim::system::{FabricKind, NetKind, SystemConfig};

    for (name, net, fabric) in [
        ("noc+buffers", NetKind::Noc, FabricKind::Buffered),
        ("axi+buffers", NetKind::Axi, FabricKind::Buffered),
        (
            "noc+cache",
            NetKind::Noc,
            FabricKind::SharedCache {
                cache_bytes: 128 * 1024,
            },
        ),
    ] {
        let mut cfg = SystemConfig::paper(table3().into_iter().take(8).collect());
        cfg.net = net;
        cfg.fabric = fabric;
        let mut rt = AccelRuntime::new(cfg);
        let mut receipts = Vec::new();
        for core in 0..rt.n_cores() {
            let hwa = rt.accel((core % 8) as u8).expect("eight accelerators");
            let words: Vec<u32> = (0..hwa.in_words() as u32).collect();
            let receipt = rt
                .submit(core, Job::on(hwa).direct(words))
                .map_err(|e| e.to_string())?;
            receipts.push(receipt);
        }
        if !rt.run_until_done(100_000 * crate::clock::PS_PER_US) {
            return Err(format!("selftest {name}: did not complete"));
        }
        for receipt in receipts {
            if rt.poll(receipt).is_none() {
                return Err(format!(
                    "selftest {name}: unresolved receipt {receipt:?}"
                ));
            }
        }
        println!(
            "selftest {name}: OK ({} tasks executed)",
            rt.system().fabric.tasks_executed()
        );
    }
    // The driver-API demo (same scenario as examples/driver_api.rs):
    // chained + direct jobs through AccelRuntime with receipt breakdowns.
    let report = crate::accel::driver_api_demo().map_err(|e| e.to_string())?;
    print!("{report}");
    println!("selftest driver-api: OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The `accnoc help` text must point at living documentation (the CI
    /// workflow greps the same string from the built binary).
    #[test]
    fn usage_points_at_architecture_doc() {
        assert!(USAGE.contains("docs/ARCHITECTURE.md"), "{USAGE}");
        assert!(!USAGE.contains("DESIGN.md"), "stale doc reference");
    }

    #[test]
    fn usage_lists_every_subcommand() {
        for verb in ["experiment", "sweep", "run", "synth", "list", "selftest"]
        {
            assert!(USAGE.contains(verb), "usage missing {verb}");
        }
    }
}
