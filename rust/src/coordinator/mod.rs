//! Coordinator: configuration loading, system construction, experiment
//! dispatch and reporting — the surface behind the `accnoc` CLI.

use crate::sim::experiments::{fig10, fig13_14, fig6, fig7, fig8, fig9, tables};
use crate::sweep::{SweepRunner, SweepSpec};
use crate::util::cli::Args;

pub const USAGE: &str = "\
accnoc — FPGA multi-accelerator / NoC-CMP integration simulator
(reproduction of Lin et al., IEEE TMSCS 2017; see docs/ARCHITECTURE.md)

USAGE:
    accnoc <subcommand> [options]

SUBCOMMANDS:
    experiment <id>   regenerate a paper result:
                      fig6 | fig7 | fig8 | fig9 | fig10 | fig13 | fig14 |
                      table2 | table3 | table4 | all
    sweep <spec>      cartesian-expand a TOML/JSON sweep spec, run the
                      scenario grid on all host cores, and write the
                      machine-readable BENCH_*.json report
                      (see configs/ and docs/EXPERIMENTS.md)
    topology <spec>   resolve a sweep spec's floorplans without
                      simulating: print each distinct tile map with its
                      per-fabric inventories, modeled interface fmax and
                      MMU assignment (autotune specs resolve their whole
                      candidate space, with pruned-candidate accounting)
    autotune <spec>   closed-loop design-space search: prune infeasible
                      floorplan candidates with the synthesis models
                      (device LUT/BRAM budget, modeled interface fmax),
                      simulate the survivors, and report the best plan
                      plus a ready-to-run config fragment
                      (see configs/autotune_smoke.toml)
    run               run one scenario from a config file
                      (--config path; same [system]/[workload] keys as a
                      sweep spec, without list values)
    synth             print the synthesis model sweep (fmax + resources)
    list              list HWA benchmarks and artifacts
    selftest          quick end-to-end smoke of all three prototypes
    help              this text

OPTIONS:
    --warmup-us N     measurement warmup (default 5)
    --window-us N     measurement window (default 40)
    --csv             CSV output instead of tables
    --threads N       sweep worker threads (default: all host cores)
    --out PATH        sweep JSON report path (default: the spec's
                      `output`, else BENCH_<name>.json)
    --csv-out PATH    also write the sweep report as CSV
    --dry-run         expand and list the sweep grid without running
    --objective O     autotune objective override:
                      p99 | throughput | throughput_per_lut | slo_violations
    --budget N        autotune evaluation-budget override
    --seed N          autotune search-seed override
";

fn emit(t: crate::util::table::Table, csv: bool) {
    if csv {
        print!("{}", t.render_csv());
    } else {
        t.print();
    }
}

pub fn main_with(args: Args) -> Result<(), String> {
    let csv = args.has_flag("csv");
    let warmup: u64 = args.get_parse_or("warmup-us", 5)?;
    let window: u64 = args.get_parse_or("window-us", 40)?;
    match args.subcommand.as_deref() {
        Some("experiment") => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .ok_or("experiment: missing id (fig6..fig14, table2..4, all)")?;
            run_experiment(id, warmup, window, csv)
        }
        Some("run") => run_custom(&args, csv),
        Some("sweep") => run_sweep(&args, csv),
        Some("topology") => run_topology(&args),
        Some("autotune") => run_autotune(&args),
        Some("synth") => {
            emit(fig7::run().table(), csv);
            emit(fig7::run().component_table(), csv);
            emit(tables::table4(), csv);
            Ok(())
        }
        Some("list") => {
            emit(tables::table3_table(), csv);
            #[cfg(feature = "pjrt")]
            match crate::runtime::Runtime::load_default() {
                Ok(rt) => println!("artifacts: {:?}", rt.names()),
                Err(e) => println!("artifacts not loaded: {e:#}"),
            }
            #[cfg(not(feature = "pjrt"))]
            println!(
                "artifacts: built without the `pjrt` feature \
                 (rebuild with --features pjrt after `make artifacts`)"
            );
            Ok(())
        }
        Some("selftest") => selftest(),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand {other:?}\n{USAGE}")),
    }
}

pub fn run_experiment(
    id: &str,
    warmup: u64,
    window: u64,
    csv: bool,
) -> Result<(), String> {
    match id {
        "fig6" => emit(fig6::run().table(), csv),
        "fig7" => {
            let f = fig7::run();
            emit(f.table(), csv);
            emit(f.component_table(), csv);
        }
        "fig8" => {
            for wl in [
                fig8::Workload::IzigzagHwa,
                fig8::Workload::EightHwa,
                fig8::Workload::DfdivHwa,
            ] {
                emit(fig8::run(wl, warmup, window).table(), csv);
            }
        }
        "fig9" => emit(fig9::run().table(), csv),
        "fig10" => emit(fig10::run().table(), csv),
        "fig13" => emit(fig13_14::run_fig13(warmup, window).table(), csv),
        "fig14" => emit(fig13_14::run_fig14().table(), csv),
        "table2" => emit(tables::table2(), csv),
        "table3" => emit(tables::table3_table(), csv),
        "table4" => emit(tables::table4(), csv),
        "all" => {
            for id in [
                "table2", "table3", "table4", "fig6", "fig7", "fig8", "fig9",
                "fig10", "fig13", "fig14",
            ] {
                run_experiment(id, warmup, window, csv)?;
            }
        }
        other => return Err(format!("unknown experiment {other:?}")),
    }
    Ok(())
}

/// Custom run: one scenario from a config file (a sweep spec whose
/// values are all single — the same parser, minus the grid).
fn run_custom(args: &Args, csv: bool) -> Result<(), String> {
    let sweep = match args.get("config") {
        Some(path) => SweepSpec::load(std::path::Path::new(path))?,
        None => SweepSpec::parse_toml("name = custom")?,
    };
    let scenarios = sweep.expand()?;
    if scenarios.len() != 1 {
        return Err(format!(
            "run: config expands to {} scenarios; use `accnoc sweep` for \
             grids",
            scenarios.len()
        ));
    }
    let report = SweepRunner::with_threads(1).run(&sweep.name, scenarios)?;
    emit(report.table(), csv);
    Ok(())
}

/// The `sweep` verb: expand a TOML/JSON spec, run the grid on all host
/// cores, write the machine-readable report.
fn run_sweep(args: &Args, csv: bool) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .ok_or("sweep: missing spec path (see configs/)")?;
    let sweep = SweepSpec::load(std::path::Path::new(path))?;
    let scenarios = sweep.expand()?;
    if args.has_flag("dry-run") {
        println!(
            "sweep {}: {} scenarios from {} axes",
            sweep.name,
            scenarios.len(),
            sweep.axes().len()
        );
        for s in &scenarios {
            println!("  {}", s.name);
        }
        return Ok(());
    }
    let runner = match args.get_parse::<usize>("threads")? {
        Some(n) => SweepRunner::with_threads(n),
        None => SweepRunner::new(),
    };
    eprintln!(
        "sweep {}: {} scenarios on {} threads",
        sweep.name,
        scenarios.len(),
        runner.threads()
    );
    let t0 = std::time::Instant::now();
    let report = runner.run(&sweep.name, scenarios)?;
    let wall = t0.elapsed();
    emit(report.table(), csv);
    let out = args
        .get("out")
        .map(|s| s.to_string())
        .unwrap_or_else(|| sweep.output_path());
    report.write_json(std::path::Path::new(&out))?;
    eprintln!(
        "sweep {}: {} scenarios in {:.2} s -> {out}",
        sweep.name,
        report.scenarios.len(),
        wall.as_secs_f64()
    );
    if let Some(csv_out) = args.get("csv-out") {
        report.write_csv(std::path::Path::new(csv_out))?;
        eprintln!("sweep {}: CSV -> {csv_out}", sweep.name);
    }
    Ok(())
}

/// The `topology` verb: resolve every scenario's floorplan and fabric
/// inventories without running a single simulated cycle (`--dry-run` for
/// the machine shape instead of the grid). Distinct topologies are
/// printed once; CI runs this over every `configs/*.toml`.
fn run_topology(args: &Args) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .ok_or("topology: missing spec path (see configs/)")?;
    // Autotune specs carry an `[autotune]` section a sweep parser would
    // reject; resolve their candidate space instead of a scenario grid.
    if let Ok(text) = std::fs::read_to_string(std::path::Path::new(path)) {
        if crate::autotune::AutotuneSpec::is_autotune_text(&text) {
            return autotune_topology(&text);
        }
    }
    let sweep = SweepSpec::load(std::path::Path::new(path))?;
    let scenarios = sweep.expand()?;
    let mut seen: Vec<String> = Vec::new();
    for s in &scenarios {
        let cfg = s.system_config()?;
        let key = render_topology(&cfg);
        if seen.contains(&key) {
            continue;
        }
        println!(
            "topology {} of sweep {} ({}x{} mesh, {} fabric{}, {} MMU{}, \
             {} processor core{})",
            seen.len(),
            sweep.name,
            cfg.floorplan.mesh.width,
            cfg.floorplan.mesh.height,
            cfg.fabrics.len(),
            if cfg.fabrics.len() == 1 { "" } else { "s" },
            cfg.floorplan.mmu_nodes().len(),
            if cfg.floorplan.mmu_nodes().len() == 1 { "" } else { "s" },
            cfg.floorplan.proc_nodes().len().min(8),
            if cfg.floorplan.proc_nodes().len().min(8) == 1 { "" } else { "s" },
        );
        print!("{key}");
        seen.push(key);
    }
    println!(
        "topology {}: {} scenarios resolve to {} distinct topolog{}",
        sweep.name,
        scenarios.len(),
        seen.len(),
        if seen.len() == 1 { "y" } else { "ies" }
    );
    Ok(())
}

/// `topology` over an autotune spec: resolve every candidate in the
/// space, print each distinct *feasible* topology once, and account for
/// the pruned candidates by reason — the dry-run view of what a search
/// would actually simulate.
fn autotune_topology(text: &str) -> Result<(), String> {
    use crate::autotune::{AutotuneSpec, Infeasible};

    let spec = AutotuneSpec::parse_toml(text)?;
    let size = spec.space_size();
    let mut seen: Vec<String> = Vec::new();
    let (mut resource, mut fmax, mut invalid) = (0usize, 0usize, 0usize);
    for id in 0..size {
        match spec.candidate(id) {
            Ok(c) => {
                let cfg = c.spec.system_config()?;
                let key = render_topology(&cfg);
                if seen.contains(&key) {
                    continue;
                }
                println!(
                    "topology {} of autotune {} (first candidate: {})",
                    seen.len(),
                    spec.name,
                    c.name
                );
                print!("{key}");
                seen.push(key);
            }
            Err(Infeasible::Resource { .. }) => resource += 1,
            Err(Infeasible::Fmax { .. }) => fmax += 1,
            Err(Infeasible::Invalid { .. }) => invalid += 1,
        }
    }
    println!(
        "topology {}: {} candidates resolve to {} distinct feasible \
         topolog{}; {} pruned ({} resource, {} fmax, {} invalid)",
        spec.name,
        size,
        seen.len(),
        if seen.len() == 1 { "y" } else { "ies" },
        resource + fmax + invalid,
        resource,
        fmax,
        invalid
    );
    Ok(())
}

/// The `autotune` verb: load the spec, apply CLI overrides, run the
/// search, print the human report, write `BENCH_autotune.json`.
fn run_autotune(args: &Args) -> Result<(), String> {
    use crate::autotune::{Autotuner, AutotuneSpec, Objective};

    let path = args
        .positional
        .first()
        .ok_or("autotune: missing spec path (see configs/autotune_smoke.toml)")?;
    let spec = AutotuneSpec::load(std::path::Path::new(path))?;
    let mut tuner = Autotuner::new();
    if let Some(obj) = args.get("objective") {
        tuner = tuner.objective(Objective::parse(obj)?);
    }
    if let Some(budget) = args.get_parse::<usize>("budget")? {
        tuner = tuner.budget(budget);
    }
    if let Some(seed) = args.get_parse::<u64>("seed")? {
        tuner = tuner.seed(seed);
    }
    if let Some(threads) = args.get_parse::<usize>("threads")? {
        tuner = tuner.threads(threads);
    }
    eprintln!(
        "autotune {}: {} candidates in the space",
        spec.name,
        spec.space_size()
    );
    let t0 = std::time::Instant::now();
    let outcome = tuner
        .run(&spec)
        .map_err(|e| format!("autotune {}: {e}", spec.name))?;
    let wall = t0.elapsed();
    print!("{}", outcome.report());
    let out_path = args
        .get("out")
        .map(|s| s.to_string())
        .unwrap_or_else(|| spec.output_path());
    outcome.write_json(std::path::Path::new(&out_path))?;
    eprintln!(
        "autotune {}: {} evaluated, {} pruned in {:.2} s -> {out_path}",
        spec.name,
        outcome.evaluated.len(),
        outcome.pruned_total(),
        wall.as_secs_f64()
    );
    Ok(())
}

/// Tile map + per-fabric inventory + MMU assignment, as one string (also
/// the dedup key for `run_topology`).
fn render_topology(cfg: &crate::sim::SystemConfig) -> String {
    use std::fmt::Write as _;

    let mut out = String::new();
    out.push_str(&cfg.floorplan.render());
    for (f, spec) in cfg.fabrics.iter().enumerate() {
        let kind = match spec.kind {
            crate::sim::FabricKind::Buffered => "buffered".to_string(),
            crate::sim::FabricKind::SharedCache { cache_bytes } => {
                format!("shared_cache {} KiB", cache_bytes / 1024)
            }
        };
        let names: Vec<&str> =
            spec.specs.iter().map(|s| s.name).collect();
        let _ = writeln!(
            out,
            "  F{f} @ node {}: {kind}, {:.0} MHz, {} TBs, PR{}-PS{}, \
             {} HWA{}: {}",
            cfg.floorplan.fabric_nodes()[f],
            spec.iface_mhz,
            spec.n_tbs,
            spec.pr_group,
            spec.ps_group,
            names.len(),
            if names.len() == 1 { "" } else { "s" },
            names.join(" "),
        );
        // Device utilization of the declared inventory (interface +
        // cores), against the budget the constructor enforces for the
        // configured part (`system.device`; xc7vx690t by default).
        let cost = crate::synth::resource::inventory_cost(
            spec.pr_group,
            spec.ps_group,
            &spec.specs,
            !spec.chain_groups.is_empty(),
        );
        let _ = writeln!(
            out,
            "    device: {} — {} LUTs ({:.1}%), {} BRAMs ({:.1}%){}",
            cfg.device.name,
            cost.lut,
            cfg.device.lut_pct(&cost),
            cost.bram,
            cfg.device.bram_pct(&cost),
            if spec.reconfigurable.is_empty() {
                String::new()
            } else {
                format!(", PR slots {:?}", spec.reconfigurable)
            },
        );
        // The calibrated delay model's ceiling for this PR/PS strategy
        // — a configured clock above it won't close timing on hardware.
        let fmax = crate::synth::fabric_fmax_mhz(
            spec.pr_group,
            spec.ps_group,
            spec.specs.len(),
        );
        let _ = writeln!(
            out,
            "    modeled iface fmax: {:.1} MHz{}",
            fmax,
            if spec.iface_mhz > fmax + 1e-9 {
                format!(
                    " — WARNING: configured {:.0} MHz exceeds the model",
                    spec.iface_mhz
                )
            } else {
                String::new()
            },
        );
        for group in &spec.chain_groups {
            let _ = writeln!(out, "    chain group: {group:?}");
        }
    }
    let mmus = cfg.floorplan.mmu_nodes();
    let _ = writeln!(
        out,
        "  MMU tile{} at node{} {:?}, {} assignment",
        if mmus.len() == 1 { "" } else { "s" },
        if mmus.len() == 1 { "" } else { "s" },
        mmus,
        cfg.mmu_assign.name(),
    );
    out
}

fn selftest() -> Result<(), String> {
    use crate::accel::{AccelRuntime, Job};
    use crate::fpga::hwa::table3;
    use crate::sim::system::{FabricKind, NetKind, SystemConfig};

    for (name, net, fabric) in [
        ("noc+buffers", NetKind::Noc, FabricKind::Buffered),
        ("axi+buffers", NetKind::Axi, FabricKind::Buffered),
        (
            "noc+cache",
            NetKind::Noc,
            FabricKind::SharedCache {
                cache_bytes: 128 * 1024,
            },
        ),
    ] {
        let mut cfg = SystemConfig::paper(table3().into_iter().take(8).collect());
        cfg.net = net;
        cfg.fabrics[0].kind = fabric;
        let mut rt = AccelRuntime::new(cfg);
        let mut receipts = Vec::new();
        for core in 0..rt.n_cores() {
            let hwa = rt.accel((core % 8) as u8).expect("eight accelerators");
            let words: Vec<u32> = (0..hwa.in_words() as u32).collect();
            let receipt = rt
                .submit(core, Job::on(hwa).direct(words))
                .map_err(|e| e.to_string())?;
            receipts.push(receipt);
        }
        if !rt.run_until_done(100_000 * crate::clock::PS_PER_US) {
            return Err(format!("selftest {name}: did not complete"));
        }
        for receipt in receipts {
            if rt.poll(receipt).is_none() {
                return Err(format!(
                    "selftest {name}: unresolved receipt {receipt:?}"
                ));
            }
        }
        println!(
            "selftest {name}: OK ({} tasks executed)",
            rt.system().fabric().tasks_executed()
        );
    }
    // The driver-API demo (same scenario as examples/driver_api.rs):
    // chained + direct jobs through AccelRuntime with receipt breakdowns.
    let report = crate::accel::driver_api_demo().map_err(|e| e.to_string())?;
    print!("{report}");
    println!("selftest driver-api: OK");
    // The floorplan demo (same scenario as examples/multi_fpga.rs): two
    // fabrics, chained + direct jobs, per-fabric receipt breakdowns.
    let report = crate::accel::multi_fpga_demo().map_err(|e| e.to_string())?;
    print!("{report}");
    println!("selftest multi-fpga: OK");
    // The serving demo: multi-tenant bursty streams (mixed direct /
    // via-memory jobs) through admission control, end to end.
    {
        use crate::sweep::{serving_tenant_specs, ArrivalKind, ServingMix};
        use crate::workload::serving::DEFAULT_WATERMARK;

        let cfg = SystemConfig::paper(table3().into_iter().take(8).collect());
        let mut rt = AccelRuntime::new(cfg);
        let tenants = serving_tenant_specs(
            2.0,
            4,
            ArrivalKind::Bursty,
            20.0,
            ServingMix::Mixed,
        );
        rt.set_serving(&tenants, true, DEFAULT_WATERMARK, 17);
        rt.run_for(40 * crate::clock::PS_PER_US);
        let done = rt.serving_completions();
        if done == 0 {
            return Err("selftest serving: no completions".to_string());
        }
        for src in rt.system().serving_sources.iter().flatten() {
            if src.unmatched != 0 {
                return Err(format!(
                    "selftest serving: {} unmatched completions on proc {}",
                    src.unmatched, src.id
                ));
            }
        }
        println!("selftest serving: OK ({done} requests served)");
    }
    // The reconfiguration demo (same scenario as examples/reconfig.rs):
    // fence, drain, program, land — then the swapped slot serves again.
    let report = crate::accel::reconfig_demo().map_err(|e| e.to_string())?;
    print!("{report}");
    println!("selftest reconfig: OK");
    // The fault-recovery demo (same scenario as
    // examples/fault_recovery.rs): a dead slot's tasks hang, the
    // watchdogs detect them, retries fail, failover completes the job,
    // and the no-recovery policy surfaces the typed permanent failure.
    let report =
        crate::accel::fault_recovery_demo().map_err(|e| e.to_string())?;
    print!("{report}");
    println!("selftest fault-recovery: OK");
    // The autotuner: a small exhaustive search over floorplans and
    // inventories whose winner must beat the legacy single-FPGA default
    // plan (the baseline = the spec's fixed keys) on p99.
    {
        use crate::autotune::{Autotuner, AutotuneSpec};

        let space = AutotuneSpec::new("selftest")
            .axis(
                "system.floorplan",
                &["P P F0 / P M P / P P P", "P P F0 / P M P / P P F1"],
            )
            .axis("system.hwas", &["izigzag*4", "izigzag*8"])
            .set("workload.kind", "openloop")
            .set("workload.rate_per_us", "4")
            .set("workload.warmup_us", "2")
            .set("workload.window_us", "15");
        let out = Autotuner::new()
            .run(&space)
            .map_err(|e| format!("selftest autotune: {e}"))?;
        let base = out
            .baseline
            .as_ref()
            .and_then(|b| b.score)
            .ok_or("selftest autotune: baseline did not run")?;
        if !(out.winner.score < base) {
            return Err(format!(
                "selftest autotune: winner p99 {:.2} µs does not beat \
                 the default single-FPGA plan ({base:.2} µs)",
                out.winner.score
            ));
        }
        println!(
            "selftest autotune: OK (winner {} p99 {:.2} µs vs default \
             {base:.2} µs, {} evaluated / {} pruned)",
            out.winner.name,
            out.winner.score,
            out.evaluated.len(),
            out.pruned_total()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The `accnoc help` text must point at living documentation (the CI
    /// workflow greps the same string from the built binary).
    #[test]
    fn usage_points_at_architecture_doc() {
        assert!(USAGE.contains("docs/ARCHITECTURE.md"), "{USAGE}");
        assert!(!USAGE.contains("DESIGN.md"), "stale doc reference");
    }

    #[test]
    fn usage_lists_every_subcommand() {
        for verb in [
            "experiment",
            "sweep",
            "topology",
            "autotune",
            "run",
            "synth",
            "list",
            "selftest",
        ] {
            assert!(USAGE.contains(verb), "usage missing {verb}");
        }
    }

    /// The `topology` verb must resolve every shipped config without
    /// simulating (CI runs the binary over `configs/*.toml`; this pins
    /// the same property in-process).
    #[test]
    fn topology_verb_resolves_every_shipped_config() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../configs");
        let mut checked = 0;
        for entry in std::fs::read_dir(dir).expect("configs/ readable") {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) != Some("toml") {
                continue;
            }
            let text = std::fs::read_to_string(&path).unwrap();
            let mut rendered_all: Vec<String> = Vec::new();
            if crate::autotune::AutotuneSpec::is_autotune_text(&text) {
                // Autotune specs resolve their candidate space; the
                // infeasible candidates are pruned, not errors, but at
                // least one candidate must survive.
                let spec = crate::autotune::AutotuneSpec::parse_toml(&text)
                    .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                for id in 0..spec.space_size() {
                    if let Ok(c) = spec.candidate(id) {
                        let cfg = c.spec.system_config().unwrap();
                        rendered_all.push(render_topology(&cfg));
                    }
                }
                assert!(
                    !rendered_all.is_empty(),
                    "{}: every candidate infeasible",
                    path.display()
                );
            } else {
                let sweep = SweepSpec::load(&path)
                    .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                for s in sweep.expand().unwrap() {
                    let cfg = s
                        .system_config()
                        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                    rendered_all.push(render_topology(&cfg));
                }
            }
            for rendered in &rendered_all {
                assert!(rendered.contains("F0"), "{rendered}");
                assert!(rendered.contains("MMU tile"), "{rendered}");
                assert!(
                    rendered.contains("device:"),
                    "missing utilization line: {rendered}"
                );
                assert!(
                    rendered.contains("modeled iface fmax"),
                    "missing fmax line: {rendered}"
                );
            }
            checked += 1;
        }
        assert!(checked >= 8, "expected the shipped configs, saw {checked}");
    }
}
