//! Analytical synthesis model: fmax (Fig. 7) and resources (Tables 3, 4).

pub mod delay;
pub mod resource;

pub use delay::{
    fabric_fmax_mhz, fig7_grid, interface_fmax_mhz, pr_fmax_mhz, ps_fmax_mhz,
};
pub use resource::{
    channel_cost, interface_cost, lut_pct, pr_cost, ps_cost, Device,
};
