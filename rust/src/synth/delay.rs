//! Analytical fmax model for the interface strategies (paper Fig. 7).
//!
//! The paper reports Vivado post-P&R maximum frequencies for 32 HWA
//! channels under every combination of distributed-PR and hierarchical-PS
//! strategy. We cannot run Vivado (DESIGN.md substitution 2), so we model
//! the critical path as
//!
//! ```text
//! t = t_reg + t_logic(fan) + t_route(fan)
//! ```
//!
//! where the logic term grows with mux/arbiter depth (log2 of fan-in) and
//! the routing term grows super-linearly with fan-out/fan-in beyond the
//! device's comfortable net fan-out (congestion). Constants are calibrated
//! to the paper's anchors:
//!
//! * global PS lands near 130 MHz; every hierarchical PS is **more than
//!   2x** faster (§6.3.1);
//! * PS4 is the best PS; PR4 the best PR; PR8/PR16 close; PR32 worst;
//! * the winning PR4-PS4 design clears 300 MHz, the frequency the full
//!   prototype runs at (§6.1).

/// Register clock-to-out + setup (ns).
const T_REG: f64 = 0.6;
/// Per-level LUT delay (ns) for a mux/arbiter tree level.
const T_LUT: f64 = 0.45;
/// Baseline net routing delay (ns).
const T_NET: f64 = 0.5;
/// Routing delay added per unit fan (ns).
const T_FAN: f64 = 0.055;
/// Super-linear congestion once fan exceeds this knee.
const FAN_KNEE: f64 = 12.0;
const T_CONGEST: f64 = 0.0008;

fn log2c(x: f64) -> f64 {
    x.max(1.0).log2().ceil().max(1.0)
}

/// Critical-path delay (ns) of a block with the given worst fan.
fn path_delay(fan: f64) -> f64 {
    let congested = (fan - FAN_KNEE).max(0.0);
    T_REG + T_LUT * log2c(fan) + T_NET + T_FAN * fan + T_CONGEST * congested * congested
}

/// fmax (MHz) of the distributed-PR strategy with `k` channels per PR and
/// `n` channels total. The PR's worst net is the max of its dispatch
/// fan-out (k channels) and the input demux fan (n/k receivers).
pub fn pr_fmax_mhz(k: usize, n: usize) -> f64 {
    assert!(k >= 1 && k <= n);
    let n_prs = n.div_ceil(k) as f64;
    // Dispatch fan-out dominates (channel buffers spread across the die:
    // 1.25x wire-length weighting); the input demux fans over n/k PRs.
    let fan = (1.25 * k as f64).max(n_prs);
    1000.0 / path_delay(fan)
}

/// fmax (MHz) of the PS strategy: `group == n` is the global PS (single
/// level, fan-in n); otherwise two registered levels of fan-in `group`
/// and `n/group`.
pub fn ps_fmax_mhz(group: usize, n: usize) -> f64 {
    assert!(group >= 1 && group <= n);
    if group == n {
        // Global: one flat arbiter + mux over n channels, plus the
        // command/result merge doubling its effective fan.
        return 1000.0 / path_delay(2.0 * n as f64);
    }
    let level1 = path_delay(group as f64 * 1.25); // data mux + priority RR
    let level2 = path_delay(n.div_ceil(group) as f64);
    1000.0 / level1.max(level2)
}

/// Interface fmax for a (PR, PS) pair (the Fig. 7 bars).
pub fn interface_fmax_mhz(pr_k: usize, ps_group: usize, n: usize) -> f64 {
    pr_fmax_mhz(pr_k, n).min(ps_fmax_mhz(ps_group, n))
}

/// Modeled interface fmax of a fabric with `n` HWA channels under the
/// configured PR/PS group sizes. Unlike the raw [`interface_fmax_mhz`],
/// groups are clamped to the inventory the same way
/// [`crate::synth::resource::inventory_cost`] clamps them (a PS4 over 2
/// channels *is* a global 2-way PS), so this accepts any `FabricSpec`
/// verbatim. This is the timing half of the feasibility check: a
/// scenario's `iface_mhz` above this value asks the interface to run
/// faster than the modeled critical path allows.
pub fn fabric_fmax_mhz(pr_group: usize, ps_group: usize, n: usize) -> f64 {
    let n = n.max(1);
    interface_fmax_mhz(pr_group.clamp(1, n), ps_group.clamp(1, n), n)
}

/// The Fig. 7 sweep: PR in {4, 8, 16, 32} x PS in {global, 16, 8, 4, 2}.
pub fn fig7_grid(n: usize) -> Vec<(String, String, f64)> {
    let mut out = Vec::new();
    for ps in [n, 16, 8, 4, 2] {
        for pr in [4usize, 8, 16, 32] {
            let label_ps = if ps == n {
                "PSglobal".to_string()
            } else {
                format!("PS{ps}")
            };
            out.push((format!("PR{pr}"), label_ps, interface_fmax_mhz(pr, ps, n)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 32;

    #[test]
    fn ps4_is_best_ps() {
        let best = [2, 4, 8, 16, N]
            .into_iter()
            .max_by(|a, b| {
                ps_fmax_mhz(*a, N)
                    .partial_cmp(&ps_fmax_mhz(*b, N))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(best, 4, "paper §6.3.1: PS4 renders the highest fmax");
    }

    #[test]
    fn hierarchical_ps_more_than_2x_global() {
        let global = ps_fmax_mhz(N, N);
        for g in [2, 4, 8, 16] {
            assert!(
                ps_fmax_mhz(g, N) > 2.0 * global,
                "PS{g}: {} vs global {}",
                ps_fmax_mhz(g, N),
                global
            );
        }
    }

    #[test]
    fn pr4_is_best_pr_and_pr32_worst() {
        let f: Vec<f64> = [4, 8, 16, 32]
            .into_iter()
            .map(|k| pr_fmax_mhz(k, N))
            .collect();
        assert!(f[0] > f[1] && f[1] >= f[2] && f[2] > f[3], "{f:?}");
    }

    #[test]
    fn pr8_pr16_similar() {
        // Paper: "PR8 and PR16 provide similar results". Our analytical
        // model separates them slightly more than Vivado does; assert
        // they stay within 35% while PR32 falls far further behind.
        let r = pr_fmax_mhz(8, N) / pr_fmax_mhz(16, N);
        assert!((0.85..1.35).contains(&r), "ratio {r}");
        assert!(pr_fmax_mhz(16, N) / pr_fmax_mhz(32, N) > r);
    }

    #[test]
    fn winning_design_clears_300mhz() {
        assert!(interface_fmax_mhz(4, 4, N) >= 300.0);
    }

    #[test]
    fn global_ps_is_the_bottleneck_everywhere() {
        for pr in [4, 8, 16, 32] {
            let f = interface_fmax_mhz(pr, N, N);
            assert!(f < 160.0, "global PS must cap fmax, got {f}");
        }
    }

    #[test]
    fn grid_has_20_points() {
        assert_eq!(fig7_grid(N).len(), 20);
    }

    #[test]
    fn fabric_fmax_clamps_groups_to_inventory() {
        // Unclamped groups on the full grid agree with the raw model...
        assert_eq!(fabric_fmax_mhz(4, 4, N), interface_fmax_mhz(4, 4, N));
        // ...and oversized groups degrade to the global arrangement
        // instead of tripping the raw model's assertions.
        assert_eq!(fabric_fmax_mhz(4, 8, 4), interface_fmax_mhz(4, 4, 4));
        assert_eq!(fabric_fmax_mhz(32, 32, 8), interface_fmax_mhz(8, 8, 8));
        // The paper's winning operating point stays feasible at small n.
        assert!(fabric_fmax_mhz(4, 4, 8) >= 300.0);
        // A global PS over 8 channels cannot close 300 MHz.
        assert!(fabric_fmax_mhz(4, 8, 8) < 300.0);
    }
}
