//! Resource accounting for the interface architecture (paper Table 4,
//! §6.3.2, §6.6) on the Virtex-7 xc7vx690t.
//!
//! Per-HWA-channel component costs are Table 4 verbatim; PR/PS costs are
//! modelled from the strategy (calibrated so PR4-PS4 at 32 channels
//! reproduces Table 4's PR = 870 / PS = 5039 LUTs and the §6.3.2 headline
//! of ~10.6% total, 0.33% per channel).

use crate::fpga::hwa::{Resources, DEVICE_BRAMS, DEVICE_LUTS};
use crate::fpga::iface::pr::PrStrategy;
use crate::fpga::iface::ps::PsStrategy;

/// A named FPGA part's routable LUT/BRAM budget — the denominator of
/// every feasibility check and utilization print. The catalog is typed
/// (not config-file data) so a budget can never silently drift from the
/// part it claims to model; `system.device` selects an entry per
/// scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Device {
    pub name: &'static str,
    pub luts: u32,
    pub brams: u32,
}

impl Device {
    /// The paper's part (§6.1): Virtex-7 xc7vx690t. The numbers are the
    /// same `DEVICE_LUTS`/`DEVICE_BRAMS` constants every pre-`Device`
    /// budget check used, so the default is behavior-preserving.
    pub const XC7VX690T: Device = Device {
        name: "xc7vx690t",
        luts: DEVICE_LUTS,
        brams: DEVICE_BRAMS,
    };
    /// The VC707 eval board's smaller sibling (Virtex-7 485T).
    pub const XC7VX485T: Device = Device {
        name: "xc7vx485t",
        luts: 303_600,
        brams: 1_030,
    };
    /// An UltraScale+ datacenter part (VU9P), for headroom studies.
    pub const XCVU9P: Device = Device {
        name: "xcvu9p",
        luts: 1_182_240,
        brams: 2_160,
    };

    pub const CATALOG: [Device; 3] =
        [Device::XC7VX690T, Device::XC7VX485T, Device::XCVU9P];

    /// Look a part up by name (the `system.device` spec value).
    pub fn parse(name: &str) -> Result<Device, String> {
        Device::CATALOG
            .into_iter()
            .find(|d| d.name == name)
            .ok_or_else(|| {
                let known: Vec<&str> =
                    Device::CATALOG.iter().map(|d| d.name).collect();
                format!(
                    "unknown device {name:?} (known: {})",
                    known.join(", ")
                )
            })
    }

    /// Does `r` exceed this part's LUT or BRAM budget?
    pub fn exceeds(&self, r: &Resources) -> bool {
        r.lut > self.luts || r.bram > self.brams
    }

    pub fn lut_pct(&self, r: &Resources) -> f64 {
        100.0 * r.lut as f64 / self.luts as f64
    }

    pub fn bram_pct(&self, r: &Resources) -> f64 {
        100.0 * r.bram as f64 / self.brams as f64
    }
}

impl Default for Device {
    fn default() -> Self {
        Device::XC7VX690T
    }
}

/// Table 4 per-channel components (LUT, BRAM).
pub const TB_COST: Resources = Resources::new(100, 4, 0, 0);
pub const TA_COST: Resources = Resources::new(2, 0, 0, 0);
pub const HWAC_PG_COST: Resources = Resources::new(290, 0, 0, 0);
pub const POB_COST: Resources = Resources::new(231, 2, 0, 0);
pub const RB_COST: Resources = Resources::new(243, 0, 0, 0);
pub const LGC_COST: Resources = Resources::new(139, 0, 0, 0);
pub const LGB_COST: Resources = Resources::new(247, 0, 0, 0);
/// §6.6: chaining support per channel (CB + CC).
pub const CHAIN_COST: Resources = Resources::new(526, 2, 0, 0);

/// Per-channel interface cost, without/with chaining support.
pub fn channel_cost(with_chaining: bool) -> Resources {
    let base = TB_COST
        .add(&TA_COST)
        .add(&HWAC_PG_COST)
        .add(&POB_COST)
        .add(&RB_COST)
        .add(&LGC_COST)
        .add(&LGB_COST);
    if with_chaining {
        base.add(&CHAIN_COST)
    } else {
        base
    }
}

/// PR cost for a strategy over `n` channels. Calibrated: each PR instance
/// costs a base FSM plus per-served-channel decode; PR4 x 32 channels
/// => 8 instances x ~109 LUTs ~= 870 (Table 4).
pub fn pr_cost(strategy: PrStrategy, n: usize) -> Resources {
    let n_prs = strategy.n_prs(n) as u32;
    let k = strategy.group_size as u32;
    Resources::new(n_prs * (61 + 12 * k), 0, 0, n_prs * 96)
}

/// PS cost: first-level arbiters (per group) + second-level controller.
/// Calibrated: PS4 x 32 channels => 8 groups x ~600 + ~239 ~= 5039
/// (Table 4). The global PS is a single flat arbiter whose mux grows
/// super-linearly with fan-in.
pub fn ps_cost(strategy: PsStrategy, n: usize) -> Resources {
    let g = strategy.group_size as u32;
    let n_groups = strategy.n_groups(n) as u32;
    if strategy.group_size >= n {
        // Global: flat n-way priority mux + arbiter.
        let n = n as u32;
        return Resources::new(180 + 95 * n + n * n / 4, 0, 0, 150 + 30 * n);
    }
    let level1 = n_groups * (400 + 50 * g);
    let level2 = 79 + 20 * n_groups;
    Resources::new(level1 + level2, 0, 0, n_groups * 180 + 120)
}

/// Full interface cost for `n` channels under a strategy pair.
pub fn interface_cost(
    pr: PrStrategy,
    ps: PsStrategy,
    n: usize,
    with_chaining: bool,
) -> Resources {
    let mut total = pr_cost(pr, n).add(&ps_cost(ps, n));
    for _ in 0..n {
        total = total.add(&channel_cost(with_chaining));
    }
    total
}

/// Full device cost of one fabric: the interface (PR/PS strategies plus
/// per-channel buffers) plus every declared accelerator core. This is
/// what the topology budget check and the `accnoc topology` utilization
/// print account against [`DEVICE_LUTS`]/[`DEVICE_BRAMS`].
pub fn inventory_cost(
    pr_group: usize,
    ps_group: usize,
    specs: &[crate::fpga::hwa::HwaSpec],
    with_chaining: bool,
) -> Resources {
    let n = specs.len();
    let mut total = interface_cost(
        PrStrategy::distributed(pr_group),
        PsStrategy::hierarchical(ps_group.min(n.max(1))),
        n,
        with_chaining,
    );
    for s in specs {
        total = total.add(&s.resources);
    }
    total
}

/// Does `r` exceed the default (xc7vx690t) LUT or BRAM budget?
pub fn exceeds_device(r: &Resources) -> bool {
    Device::default().exceeds(r)
}

pub fn lut_pct(r: &Resources) -> f64 {
    Device::default().lut_pct(r)
}

pub fn bram_pct(r: &Resources) -> f64 {
    Device::default().bram_pct(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_device_preserves_legacy_budget() {
        let d = Device::default();
        assert_eq!(d.name, "xc7vx690t");
        assert_eq!(d.luts, DEVICE_LUTS);
        assert_eq!(d.brams, DEVICE_BRAMS);
        // The free functions are the same check as the typed default.
        let over = Resources::new(DEVICE_LUTS + 1, 0, 0, 0);
        assert!(exceeds_device(&over) && d.exceeds(&over));
        let under = Resources::new(DEVICE_LUTS, DEVICE_BRAMS, 0, 0);
        assert!(!exceeds_device(&under) && !d.exceeds(&under));
    }

    #[test]
    fn device_catalog_parses_by_name() {
        for d in Device::CATALOG {
            assert_eq!(Device::parse(d.name), Ok(d));
        }
        assert!(Device::parse("xc7z020").is_err());
        // A mix that drowns the 485t still fits the VU9P.
        let r = Resources::new(400_000, 0, 0, 0);
        assert!(Device::XC7VX485T.exceeds(&r));
        assert!(!Device::XCVU9P.exceeds(&r));
    }

    #[test]
    fn table4_pr_ps_anchor() {
        // PR4-PS4 at 32 channels: Table 4 reports PR 870, PS 5039 LUTs.
        let pr = pr_cost(PrStrategy::distributed(4), 32);
        assert_eq!(pr.lut, 872, "8 x (61 + 48)");
        let ps = ps_cost(PsStrategy::hierarchical(4), 32);
        assert_eq!(ps.lut, 5039, "8 x 600 + 239");
    }

    #[test]
    fn per_channel_within_paper_band() {
        // §6.3.2: ~0.33% LUTs per HWA channel (with its share of PR/PS).
        let n = 32;
        let total = interface_cost(
            PrStrategy::distributed(4),
            PsStrategy::hierarchical(4),
            n,
            false,
        );
        let per_channel_pct = lut_pct(&total) / n as f64;
        assert!(
            (0.25..0.40).contains(&per_channel_pct),
            "{per_channel_pct}"
        );
    }

    #[test]
    fn total_close_to_10_63_pct() {
        let total = interface_cost(
            PrStrategy::distributed(4),
            PsStrategy::hierarchical(4),
            32,
            false,
        );
        let pct = lut_pct(&total);
        assert!((9.5..11.5).contains(&pct), "total {pct}%");
    }

    #[test]
    fn chaining_overhead_matches_6_6() {
        // §6.6: +526 LUT (0.12%) and +2 BRAM per channel.
        let delta_lut = channel_cost(true).lut - channel_cost(false).lut;
        assert_eq!(delta_lut, 526);
        let pct = 100.0 * delta_lut as f64 / DEVICE_LUTS as f64;
        assert!((0.10..0.14).contains(&pct));
        assert_eq!(channel_cost(true).bram - channel_cost(false).bram, 2);
    }

    #[test]
    fn eight_channels_about_2_6_pct() {
        // §6.3.2: an 8-channel design uses ~2.6% of LUTs.
        let total = interface_cost(
            PrStrategy::distributed(4),
            PsStrategy::hierarchical(4),
            8,
            false,
        );
        let pct = lut_pct(&total);
        assert!((2.2..3.1).contains(&pct), "{pct}%");
    }

    #[test]
    fn strategy_range_small() {
        // §6.3.2: across strategies LUT use spans ~10.48%..10.78%.
        let mut pcts = Vec::new();
        for pr_k in [4usize, 8, 16, 32] {
            for ps_g in [2usize, 4, 8, 16] {
                let t = interface_cost(
                    PrStrategy::distributed(pr_k),
                    PsStrategy::hierarchical(ps_g),
                    32,
                    false,
                );
                pcts.push(lut_pct(&t));
            }
        }
        let min = pcts.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = pcts.iter().cloned().fold(0.0f64, f64::max);
        assert!(max - min < 1.5, "spread {min}..{max}");
    }
}
