//! `accnoc` CLI — see `coordinator::USAGE` and DESIGN.md.

fn main() {
    let args = match accnoc::util::cli::Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = accnoc::coordinator::main_with(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
