//! Summary statistics for experiment reporting.

/// Online accumulator (Welford) for mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Accum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample (nearest-rank; sorts a copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (xs.len() as f64 - 1.0)).round() as usize;
    xs[rank.min(xs.len() - 1)]
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Geometric mean (for speedup summaries).
pub fn geomean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let logsum: f64 = samples.iter().map(|x| x.ln()).sum();
    (logsum / samples.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_basic() {
        let mut a = Accum::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.push(x);
        }
        assert_eq!(a.count(), 4);
        assert!((a.mean() - 2.5).abs() < 1e-12);
        assert!((a.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
    }

    #[test]
    fn accum_empty_is_nan() {
        assert!(Accum::new().mean().is_nan());
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }
}
