//! Test-only counting allocator: proves the zero-copy hot path claim by
//! measuring, not by inspection. A `#[global_allocator]` wrapper over the
//! system allocator counts heap allocations per thread; the steady-state
//! test below runs a low-injection open loop and asserts the measurement
//! window after warmup performs **zero** heap allocations.
//!
//! Only compiled into the library's unit-test binary (`#[cfg(test)]` at
//! the module registration in `util/mod.rs`), so release builds and
//! integration tests keep the default allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static ALLOC_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Counts `alloc`/`realloc`/`alloc_zeroed` (the calls that can reach the
/// OS); `dealloc` is free and not counted. Counters are thread-local so
/// parallel test threads never see each other's traffic, and guarded
/// with `try_with` so allocation during TLS teardown cannot panic.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = ALLOC_BYTES.try_with(|c| c.set(c.get() + layout.size() as u64));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = ALLOC_BYTES.try_with(|c| c.set(c.get() + new_size as u64));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = ALLOC_BYTES.try_with(|c| c.set(c.get() + layout.size() as u64));
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Heap allocations performed by the calling thread so far.
pub fn thread_allocs() -> u64 {
    ALLOCS.try_with(Cell::get).unwrap_or(0)
}

/// Bytes requested by the calling thread so far.
pub fn thread_alloc_bytes() -> u64 {
    ALLOC_BYTES.try_with(Cell::get).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::PS_PER_US;
    use crate::fpga::hwa::spec_by_name;
    use crate::sim::system::{System as Sim, SystemConfig};

    #[test]
    fn counter_sees_a_boxed_allocation() {
        let before = thread_allocs();
        let b = std::hint::black_box(Box::new([0u8; 64]));
        assert!(thread_allocs() > before, "Box must be counted");
        drop(b);
    }

    #[test]
    fn steady_state_open_loop_allocates_nothing() {
        // The fig8 low-injection scenario: 8 izigzag channels, 0.25
        // requests/µs. Warmup grows every pool (arena slabs, rings,
        // scratch buffers, stats vectors) to the scenario's high-water
        // mark; the measured window after it must run entirely out of
        // recycled storage.
        let cfg =
            SystemConfig::paper(vec![spec_by_name("izigzag").unwrap(); 8]);
        let mut sys = Sim::new(cfg);
        sys.set_open_loop(0.25, 11);
        sys.run_for(100 * PS_PER_US);
        let live_before = sys.arena_live();
        let allocs_before = thread_allocs();
        let bytes_before = thread_alloc_bytes();
        sys.run_for(150 * PS_PER_US);
        let allocs = thread_allocs() - allocs_before;
        let bytes = thread_alloc_bytes() - bytes_before;
        assert_eq!(
            allocs, 0,
            "steady-state window heap-allocated {allocs} times \
             ({bytes} bytes); the zero-copy hot path must run out of \
             pooled storage (arena live before: {live_before:?}, \
             after: {:?}, stats: {:?})",
            sys.arena_live(),
            sys.arena_stats(),
        );
    }
}
