//! Tiny CLI argument parser (clap is not in the offline registry snapshot).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be written `--key=value` or `--key value`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminator: rest is positional.
                    out.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse {s:?}")),
        }
    }

    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["run", "scenario.cfg"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["scenario.cfg"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["run", "--rate=0.25", "--seed", "42"]);
        assert_eq!(a.get("rate"), Some("0.25"));
        assert_eq!(a.get("seed"), Some("42"));
    }

    #[test]
    fn bare_flag() {
        let a = parse(&["run", "--verbose"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["run", "--csv"]);
        assert!(a.has_flag("csv"));
    }

    #[test]
    fn double_dash_terminates() {
        let a = parse(&["run", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn get_parse_errors_nicely() {
        let a = parse(&["run", "--seed", "abc"]);
        assert!(a.get_parse::<u64>("seed").is_err());
        assert_eq!(a.get_parse_or("missing", 7u64).unwrap(), 7);
    }
}
