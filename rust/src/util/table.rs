//! Paper-style ASCII table / CSV renderer for experiment output.

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display<D: std::fmt::Display>(&mut self, cells: &[D]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("| {:width$} ", c, width = widths[i]))
                .collect::<String>()
                + "|"
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn render_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            let quoted: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            out.push_str(&quoted.join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `digits` decimals, trimming to a compact cell.
pub fn num(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(&["short".into(), "1".into()]);
        t.row(&["a-much-longer-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("a-much-longer-name"));
        let lines: Vec<&str> = s.lines().collect();
        // All body lines equal width.
        let body: Vec<&str> = lines[1..].iter().copied().collect();
        let w = body[0].len();
        assert!(body.iter().all(|l| l.len() == w));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["x,y".into()]);
        assert!(t.render_csv().contains("\"x,y\""));
    }
}
