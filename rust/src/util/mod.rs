//! In-repo substrates: PRNG, stats, property testing, bench harness, CLI,
//! config, tables. See DESIGN.md §Substrates — these replace crates that
//! are not available in the offline registry snapshot.

#[cfg(test)]
pub mod alloc_count;
pub mod bench;
pub mod cli;
pub mod config_text;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
