//! Micro-benchmark harness for `cargo bench` targets (criterion is not in
//! the offline registry snapshot — DESIGN.md §Substrates, substitution 6).
//!
//! Each bench target is a `harness = false` binary that calls
//! [`Bench::run`] per measured function and prints a table. Measurements:
//! warmup, then timed batches until both a minimum iteration count and a
//! minimum wall time are reached; reports mean/min/p50 per iteration.

use std::path::Path;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::percentile;

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub min_time: Duration,
    pub min_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            min_time: Duration::from_millis(800),
            min_iters: 10,
        }
    }
}

/// Quick config for heavyweight end-to-end simulation benches.
pub fn sim_config() -> BenchConfig {
    BenchConfig {
        warmup: Duration::from_millis(0),
        min_time: Duration::from_millis(100),
        min_iters: 3,
    }
}

#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub min: Duration,
    pub p50: Duration,
}

pub struct Bench {
    config: BenchConfig,
    results: Vec<Measurement>,
    /// Named scalar metrics reported alongside the timings (allocation
    /// rates, high-water marks, ...) — deterministic, unlike wall time.
    counters: Vec<(String, f64)>,
}

impl Bench {
    pub fn new(config: BenchConfig) -> Self {
        Self {
            config,
            results: Vec::new(),
            counters: Vec::new(),
        }
    }

    /// Record a named scalar metric (e.g. arena allocs per simulated µs).
    /// Counters land in the JSON artifact under `"counters"` so CI can
    /// track them as a trajectory next to the timings.
    pub fn counter(&mut self, name: &str, value: f64) {
        self.counters.push((name.to_string(), value));
    }

    /// Measure `f`, using its return value to defeat dead-code elimination.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.config.warmup {
            std::hint::black_box(f());
        }
        // Calibrate a batch size so per-sample timing overhead stays
        // negligible for nanosecond-scale functions.
        let probe = Instant::now();
        std::hint::black_box(f());
        let once = probe.elapsed().as_nanos().max(1);
        let batch = (1_000_000 / once).clamp(1, 4096) as u64;
        // Measure in batches.
        let mut samples: Vec<f64> = Vec::new();
        let mut iters = 0u64;
        let begin = Instant::now();
        while iters < self.config.min_iters || begin.elapsed() < self.config.min_time {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            iters += batch;
            if iters > 100_000_000 {
                break;
            }
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let p50 = percentile(&samples, 50.0);
        self.results.push(Measurement {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(mean),
            min: Duration::from_secs_f64(min),
            p50: Duration::from_secs_f64(p50),
        });
        self.results.last().unwrap()
    }

    /// Print the classic `name ... time` report.
    pub fn report(&self, title: &str) {
        println!("\n== bench: {title} ==");
        let width = self
            .results
            .iter()
            .map(|m| m.name.len())
            .max()
            .unwrap_or(8)
            .max(8);
        println!(
            "{:width$}  {:>12}  {:>12}  {:>12}  {:>8}",
            "name", "mean", "min", "p50", "iters"
        );
        for m in &self.results {
            println!(
                "{:width$}  {:>12}  {:>12}  {:>12}  {:>8}",
                m.name,
                fmt_duration(m.mean),
                fmt_duration(m.min),
                fmt_duration(m.p50),
                m.iters
            );
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Machine-readable results — the `BENCH_hotpath.json` perf-trajectory
    /// artifact CI uploads per run: `{"schema": 3, "name": ...,
    /// "results": [{"name": ..., "mean_ns": ..., "min_ns": ...,
    /// "p50_ns": ..., "iters": ...}, ...], "counters": {...}}`.
    ///
    /// Schema history: 1 = timings only; 3 = adds the additive
    /// `"counters"` object of named scalar metrics (existing fields
    /// unchanged, so schema-1 consumers still parse the timings).
    pub fn to_json(&self, name: &str) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::from(m.name.as_str())),
                    ("mean_ns", Json::Num(m.mean.as_secs_f64() * 1e9)),
                    ("min_ns", Json::Num(m.min.as_secs_f64() * 1e9)),
                    ("p50_ns", Json::Num(m.p50.as_secs_f64() * 1e9)),
                    ("iters", Json::from(m.iters)),
                ])
            })
            .collect();
        let counters = Json::obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.as_str(), Json::Num(*v)))
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::from(3u64)),
            ("name", Json::from(name)),
            ("results", Json::Arr(results)),
            ("counters", counters),
        ])
    }

    /// Write the machine-readable results next to the text report.
    pub fn write_json(&self, name: &str, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json(name).render())
            .map_err(|e| format!("{}: {e}", path.display()))
    }
}

pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new(BenchConfig {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(5),
            min_iters: 3,
        });
        let m = b.run("noop-ish", || (0..100u64).sum::<u64>());
        assert!(m.iters >= 3);
        assert!(m.min <= m.mean);
    }

    #[test]
    fn json_form_lists_every_measurement() {
        let mut b = Bench::new(BenchConfig {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(2),
            min_iters: 1,
        });
        b.run("alpha", || 1u64 + 1);
        b.run("beta", || (0..10u64).product::<u64>());
        b.counter("arena_packet_allocs", 12.0);
        let v = Json::parse(&b.to_json("micro").render()).unwrap();
        assert_eq!(v.get("schema").and_then(Json::as_f64), Some(3.0));
        assert_eq!(v.get("name").and_then(Json::as_str), Some("micro"));
        assert_eq!(
            v.get("counters")
                .and_then(|c| c.get("arena_packet_allocs"))
                .and_then(Json::as_f64),
            Some(12.0)
        );
        let results = v.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].get("name").and_then(Json::as_str),
            Some("alpha")
        );
        assert!(
            results[0]
                .get("mean_ns")
                .and_then(Json::as_f64)
                .is_some_and(|ns| ns >= 0.0),
            "mean_ns present and non-negative"
        );
    }

    #[test]
    fn fmt_covers_scales() {
        assert!(fmt_duration(Duration::from_nanos(5)).contains("ns"));
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).contains("s"));
    }
}
