//! Minimal JSON value, writer and parser (serde_json is not in the
//! offline registry snapshot — DESIGN.md §Substrates).
//!
//! Object member order is preserved (insertion order), so a value built
//! deterministically serializes byte-identically — the property the
//! `sweep` determinism tests rely on. Numbers are `f64`; integral values
//! print without a fractional part.
//!
//! ```
//! use accnoc::util::json::Json;
//!
//! let v = Json::parse(r#"{"name": "fig8", "rates": [0.5, 1.0]}"#).unwrap();
//! assert_eq!(v.get("name").and_then(Json::as_str), Some("fig8"));
//! assert_eq!(Json::parse(&v.render()).unwrap(), v);
//! ```

/// A JSON value. Objects are `Vec<(key, value)>` to keep member order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from key/value pairs (order preserved).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object member lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- writer -------------------------------------------------------------

    /// Pretty-print with two-space indentation and a trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&fmt_num(*x)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    v.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
        }
    }

    // -- parser -------------------------------------------------------------

    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

/// JSON number formatting: integral values print as integers; NaN/inf
/// (not representable in JSON) degrade to null.
pub fn fmt_num(x: f64) -> String {
    if !x.is_finite() {
        return "null".to_string();
    }
    if x.fract() == 0.0 && x.abs() < 9.007_199_254_740_992e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected {lit:?} at byte {pos}", pos = *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => expect(b, pos, "null").map(|_| Json::Null),
        Some(b't') => expect(b, pos, "true").map(|_| Json::Bool(true)),
        Some(b'f') => expect(b, pos, "false").map(|_| Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut members = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(members));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                let value = parse_value(b, pos)?;
                members.push((key, value));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(members));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        Some(_) => parse_number(b, pos).map(Json::Num),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or("truncated \\u escape")?;
                        let hex = std::str::from_utf8(hex)
                            .map_err(|_| "bad \\u escape")?;
                        let n = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                        out.push(char::from_u32(n).ok_or_else(|| {
                            format!("bad code point {n:#x}")
                        })?);
                        *pos += 4;
                    }
                    other => return Err(format!("bad escape {other:?}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 character (b is valid UTF-8: it came
                // from a &str).
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|e| e.to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<f64, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>()
        .map_err(|_| format!("bad number {s:?} at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_nested_value() {
        let text = r#"{"a": [1, 2.5, true, null, "x\"y"], "b": {"c": -3}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.render()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn preserves_member_order() {
        let v = Json::obj(vec![
            ("zeta", Json::from(1u64)),
            ("alpha", Json::from(2u64)),
        ]);
        let s = v.render();
        assert!(s.find("zeta").unwrap() < s.find("alpha").unwrap());
    }

    #[test]
    fn integral_floats_print_as_integers() {
        assert_eq!(fmt_num(3.0), "3");
        assert_eq!(fmt_num(3.5), "3.5");
        assert_eq!(fmt_num(f64::NAN), "null");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"s": "t", "n": 4, "a": []}"#).unwrap();
        assert_eq!(v.get("s").and_then(Json::as_str), Some("t"));
        assert_eq!(v.get("n").and_then(Json::as_f64), Some(4.0));
        assert_eq!(v.get("a").and_then(Json::as_arr).map(|a| a.len()), Some(0));
        assert!(v.get("missing").is_none());
    }
}
