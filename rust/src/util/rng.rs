//! Deterministic PRNGs for workload generation and property tests.
//!
//! The offline registry snapshot has no `rand` crate, so we carry our own:
//! SplitMix64 (seeding / stream splitting) and PCG32 (bulk generation).
//! Both are well-known public-domain generators; determinism across runs is
//! a hard requirement for reproducible experiments, so all experiment
//! drivers take explicit seeds.

/// SplitMix64: tiny, fast, passes BigCrush when used for seeding.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): the workhorse generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const MULT: u64 = 6_364_136_223_846_793_005;

    /// Seed a generator; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    /// Seed from a single value with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0) is meaningless");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed inter-arrival (mean = `mean`), for Poisson
    /// request processes in the throughput experiments (§6.4).
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let a: Vec<u64> = (0..4).map(|_| 0).collect::<Vec<_>>();
        let mut s1 = SplitMix64::new(42);
        let mut s2 = SplitMix64::new(42);
        for _ in a {
            assert_eq!(s1.next_u64(), s2.next_u64());
        }
    }

    #[test]
    fn splitmix_differs_by_seed() {
        let mut s1 = SplitMix64::new(1);
        let mut s2 = SplitMix64::new(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn pcg_below_is_in_range() {
        let mut rng = Pcg32::seeded(7);
        for bound in [1u32, 2, 3, 10, 1000, u32::MAX] {
            for _ in 0..100 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn pcg_f64_unit_interval() {
        let mut rng = Pcg32::seeded(9);
        for _ in 0..1000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn pcg_uniformity_coarse() {
        let mut rng = Pcg32::seeded(11);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[rng.below(10) as usize] += 1;
        }
        for b in buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b} out of range");
        }
    }

    #[test]
    fn exp_mean_converges() {
        let mut rng = Pcg32::seeded(13);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| rng.exp(5.0)).sum();
        let mean = sum / n as f64;
        assert!((4.9..5.1).contains(&mean), "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(17);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::new(5, 1);
        let mut b = Pcg32::new(5, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }
}
