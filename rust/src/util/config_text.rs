//! Minimal `key = value` config-file loader (serde/toml are not in the
//! offline registry snapshot).
//!
//! Grammar: one `key = value` per line; `#` comments; optional `[section]`
//! headers which prefix keys as `section.key`. Values are strings; typed
//! accessors parse on demand.

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct ConfigText {
    values: BTreeMap<String, String>,
}

impl ConfigText {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(Self { values })
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| format!("{key}: cannot parse {s:?}")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let c = ConfigText::parse(
            "# comment\n\
             seed = 42\n\
             [noc]\n\
             width = 3   # inline comment\n\
             height = 3\n",
        )
        .unwrap();
        assert_eq!(c.get("seed"), Some("42"));
        assert_eq!(c.get_or::<u32>("noc.width", 0).unwrap(), 3);
        assert_eq!(c.get_or::<u32>("noc.height", 0).unwrap(), 3);
    }

    #[test]
    fn missing_key_uses_default() {
        let c = ConfigText::parse("").unwrap();
        assert_eq!(c.get_or::<u64>("nope", 9).unwrap(), 9);
    }

    #[test]
    fn rejects_garbage() {
        assert!(ConfigText::parse("not a kv line").is_err());
        assert!(ConfigText::parse("[unterminated").is_err());
    }

    #[test]
    fn bad_parse_reports_key() {
        let c = ConfigText::parse("x = abc").unwrap();
        let err = c.get_or::<u32>("x", 0).unwrap_err();
        assert!(err.contains("x"));
    }
}
