//! Minimal property-testing framework (proptest is not in the offline
//! registry snapshot — DESIGN.md §Substrates, substitution 6).
//!
//! Shape: a [`Gen`] draws random inputs from a PRNG; [`check`] runs a
//! property over many cases and, on failure, greedily shrinks the failing
//! input via the generator's [`Gen::shrink`] candidates before panicking
//! with the minimal counterexample.
//!
//! ```no_run
//! use accnoc::util::prop::{check, VecGen, IntGen};
//! check("sorted twice is idempotent", VecGen::new(IntGen::below(100), 0, 20), |xs| {
//!     let mut a = xs.clone();
//!     a.sort();
//!     let mut b = a.clone();
//!     b.sort();
//!     a == b
//! });
//! ```

use super::rng::Pcg32;

/// Number of cases per property (tuned for CI-speed full runs).
pub const DEFAULT_CASES: usize = 256;

pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    fn generate(&self, rng: &mut Pcg32) -> Self::Value;

    /// Candidate strictly-smaller values; empty when fully shrunk.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` over `cases` random draws (seeded deterministically from the
/// property name so failures reproduce), shrinking on failure.
pub fn check_with<G: Gen>(
    name: &str,
    gen: G,
    cases: usize,
    prop: impl Fn(&G::Value) -> bool,
) {
    let seed = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
        });
    let mut rng = Pcg32::seeded(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(&gen, value, &prop);
            panic!(
                "property '{name}' failed at case {case}; minimal \
                 counterexample: {minimal:?}"
            );
        }
    }
}

/// [`check_with`] at [`DEFAULT_CASES`].
pub fn check<G: Gen>(name: &str, gen: G, prop: impl Fn(&G::Value) -> bool) {
    check_with(name, gen, DEFAULT_CASES, prop);
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    // Greedy descent, bounded to avoid pathological generators looping.
    for _ in 0..10_000 {
        let mut advanced = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// Uniform `u64` in `[lo, hi)` with shrinking toward `lo`.
#[derive(Clone)]
pub struct IntGen {
    pub lo: u64,
    pub hi: u64,
}

impl IntGen {
    pub fn below(hi: u64) -> Self {
        Self { lo: 0, hi }
    }

    pub fn range(lo: u64, hi: u64) -> Self {
        assert!(lo < hi);
        Self { lo, hi }
    }
}

impl Gen for IntGen {
    type Value = u64;

    fn generate(&self, rng: &mut Pcg32) -> u64 {
        self.lo + rng.next_u64() % (self.hi - self.lo)
    }

    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Vectors of an inner generator with length in `[min_len, max_len]`;
/// shrinks by halving length, dropping elements, then shrinking elements.
#[derive(Clone)]
pub struct VecGen<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G> VecGen<G> {
    pub fn new(inner: G, min_len: usize, max_len: usize) -> Self {
        assert!(min_len <= max_len);
        Self {
            inner,
            min_len,
            max_len,
        }
    }
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Pcg32) -> Self::Value {
        let len = self.min_len + rng.range(0, self.max_len - self.min_len + 1);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let half = (v.len() / 2).max(self.min_len);
            out.push(v[..half].to_vec());
            let mut drop_last = v.clone();
            drop_last.pop();
            out.push(drop_last);
            let mut drop_first = v.clone();
            drop_first.remove(0);
            out.push(drop_first);
        }
        // Shrink one element at a time (first shrinkable).
        for (i, elem) in v.iter().enumerate() {
            for cand in self.inner.shrink(elem) {
                let mut next = v.clone();
                next[i] = cand;
                out.push(next);
                break;
            }
            if !out.is_empty() && i > 8 {
                break; // bound candidate fan-out
            }
        }
        out
    }
}

/// Pair generator.
#[derive(Clone)]
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Pcg32) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Map a generator through a function (no shrinking across the map).
pub struct MapGen<G, F> {
    pub inner: G,
    pub f: F,
}

impl<G: Gen, T: Clone + std::fmt::Debug, F: Fn(G::Value) -> T> Gen for MapGen<G, F> {
    type Value = T;

    fn generate(&self, rng: &mut Pcg32) -> T {
        (self.f)(self.inner.generate(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is commutative", PairGen(IntGen::below(1000), IntGen::below(1000)), |(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        check("all values below 50", IntGen::below(1000), |v| *v < 50);
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Catch the panic and confirm the shrunk value is near-minimal.
        let result = std::panic::catch_unwind(|| {
            check("no vec longer than 3", VecGen::new(IntGen::below(10), 0, 32), |v| {
                v.len() <= 3
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Minimal counterexample should be a 4-element vector.
        let count = msg.matches(',').count();
        assert!(count <= 4, "not shrunk enough: {msg}");
    }

    #[test]
    fn intgen_respects_bounds() {
        let g = IntGen::range(10, 20);
        let mut rng = Pcg32::seeded(3);
        for _ in 0..1000 {
            let v = g.generate(&mut rng);
            assert!((10..20).contains(&v));
        }
    }
}
