//! Machine-readable sweep results: the `BENCH_*.json` trajectory format
//! plus a CSV flattening and a human summary table.
//!
//! The JSON layout is `{"schema": 3, "name": ..., "scenarios": [{"spec":
//! {flat key map}, "stats": {...}}, ...]}` — each scenario embeds its
//! fully-resolved spec, so an artifact is self-describing and can be
//! re-run (`ScenarioSpec::from_map`) without the original TOML.
//! Schema 2 added the per-domain `edges_skipped_{noc,iface,hwa}`
//! breakdown (ISSUE 4); every schema-1 field is unchanged. Schema 3
//! adds the per-tenant `stats.tenants` array for serving workloads;
//! the array is omitted for every other workload, so schema-2 stats
//! objects are unchanged byte-for-byte (a pinned test below proves it).
//! Schema 4 adds the `reconfig_*` swap counters — emitted only when the
//! run actually reconfigured — and the per-tenant `downgraded_chained`
//! column, so frozen-inventory artifacts keep their schema-3 bytes.
//! Schema 5 adds the `fault_*` injection/recovery counters — emitted
//! only when the run saw any fault activity — and the per-tenant
//! `fault_failures` column (likewise only when nonzero), so fault-free
//! artifacts keep their schema-4 bytes.

use std::path::Path;

use crate::util::json::{fmt_num, Json};
use crate::util::table::Table;

use super::runner::{RunStats, SweepReport};

impl RunStats {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("total_us", Json::Num(self.total_us)),
            ("tasks_executed", Json::from(self.tasks_executed)),
            (
                "injection_flits_per_us",
                Json::Num(self.injection_flits_per_us),
            ),
            (
                "throughput_flits_per_us",
                Json::Num(self.throughput_flits_per_us),
            ),
            ("completions_per_us", Json::Num(self.completions_per_us)),
            ("busy_fraction", Json::Num(self.busy_fraction)),
            ("rejected_flits", Json::from(self.rejected_flits)),
            ("edges_stepped", Json::from(self.edges_stepped)),
            ("edges_skipped", Json::from(self.edges_skipped)),
            ("edges_skipped_noc", Json::from(self.edges_skipped_noc)),
            ("edges_skipped_iface", Json::from(self.edges_skipped_iface)),
            ("edges_skipped_hwa", Json::from(self.edges_skipped_hwa)),
            (
                "latency_us",
                Json::obj(vec![
                    ("count", Json::from(self.latency.count)),
                    ("mean", Json::Num(self.latency.mean_us)),
                    ("p50", Json::Num(self.latency.p50_us)),
                    ("p90", Json::Num(self.latency.p90_us)),
                    ("p99", Json::Num(self.latency.p99_us)),
                    ("min", Json::Num(self.latency.min_us)),
                    ("max", Json::Num(self.latency.max_us)),
                ]),
            ),
            ("processor_us", Json::Num(self.processor_us)),
            ("fpga_us", Json::Num(self.fpga_us)),
            ("transmission_us", Json::Num(self.transmission_us)),
        ];
        // Swap counters are additive and only emitted when the run
        // actually reconfigured: frozen-inventory artifacts (every
        // static-policy run) keep their exact schema-3 bytes.
        if self.reconfig_swaps != 0
            || self.reconfig_drain_cycles != 0
            || self.reconfig_blocked_cycles != 0
        {
            fields.push(("reconfig_swaps", Json::from(self.reconfig_swaps)));
            fields.push((
                "reconfig_drain_cycles",
                Json::from(self.reconfig_drain_cycles),
            ));
            fields.push((
                "reconfig_blocked_cycles",
                Json::from(self.reconfig_blocked_cycles),
            ));
        }
        // Fault counters are additive and only emitted when the run saw
        // fault activity: fault-free artifacts (every `fault.spec =
        // none` run) keep their exact schema-4 bytes.
        if self.fault_injected != 0
            || self.fault_detected != 0
            || self.fault_retried != 0
            || self.fault_failed_over != 0
            || self.fault_permanently_failed != 0
        {
            fields.push(("fault_injected", Json::from(self.fault_injected)));
            fields.push(("fault_detected", Json::from(self.fault_detected)));
            fields.push(("fault_retried", Json::from(self.fault_retried)));
            fields.push((
                "fault_failed_over",
                Json::from(self.fault_failed_over),
            ));
            fields.push((
                "fault_permanently_failed",
                Json::from(self.fault_permanently_failed),
            ));
        }
        // Per-fabric rows are additive and only emitted for multi-fabric
        // scenarios: single-fabric artifacts stay byte-identical to the
        // pre-floorplan schema-2 layout.
        if self.per_fabric.len() > 1 {
            let rows: Vec<Json> = self
                .per_fabric
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("fabric", Json::from(r.fabric as u64)),
                        ("node", Json::from(r.node as u64)),
                        ("tasks_executed", Json::from(r.tasks_executed)),
                        (
                            "injection_flits_per_us",
                            Json::Num(r.injection_flits_per_us),
                        ),
                        (
                            "throughput_flits_per_us",
                            Json::Num(r.throughput_flits_per_us),
                        ),
                        ("busy_fraction", Json::Num(r.busy_fraction)),
                        ("rejected_flits", Json::from(r.rejected_flits)),
                    ])
                })
                .collect();
            fields.push(("fabrics", Json::Arr(rows)));
        }
        // Tenant rows are additive and only present for serving
        // workloads: every other workload's stats object keeps its exact
        // schema-2 bytes.
        if !self.tenants.is_empty() {
            let rows: Vec<Json> = self
                .tenants
                .iter()
                .map(|r| {
                    let mut row = vec![
                        ("tenant", Json::from(r.tenant as u64)),
                        ("priority", Json::from(r.priority as u64)),
                        ("arrivals", Json::from(r.arrivals)),
                        ("admitted", Json::from(r.admitted)),
                        ("completed", Json::from(r.completed)),
                        ("shed_bucket", Json::from(r.shed_bucket)),
                        ("shed_watermark", Json::from(r.shed_watermark)),
                        ("dropped", Json::from(r.dropped)),
                        (
                            "downgraded_chained",
                            Json::from(r.downgraded_chained),
                        ),
                    ];
                    // Additive like the scalar fault_* counters: only
                    // faulty runs carry the column, so fault-free
                    // serving artifacts keep their schema-4 bytes.
                    if r.fault_failures != 0 {
                        row.push((
                            "fault_failures",
                            Json::from(r.fault_failures),
                        ));
                    }
                    row.extend([
                        ("slo_violations", Json::from(r.slo_violations)),
                        ("count", Json::from(r.count)),
                        ("mean_us", Json::Num(r.mean_us)),
                        ("p50_us", Json::Num(r.p50_us)),
                        ("p99_us", Json::Num(r.p99_us)),
                        ("p999_us", Json::Num(r.p999_us)),
                        ("max_us", Json::Num(r.max_us)),
                    ]);
                    Json::obj(row)
                })
                .collect();
            fields.push(("tenants", Json::Arr(rows)));
        }
        Json::obj(fields)
    }
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        let scenarios: Vec<Json> = self
            .scenarios
            .iter()
            .map(|s| {
                let spec: Vec<(String, Json)> = s
                    .spec
                    .to_map()
                    .into_iter()
                    .map(|(k, v)| (k, Json::Str(v)))
                    .collect();
                Json::obj(vec![
                    ("scenario", Json::from(s.spec.name.as_str())),
                    ("spec", Json::Obj(spec)),
                    ("stats", s.stats.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::from(5u64)),
            ("name", Json::from(self.name.as_str())),
            ("scenarios", Json::Arr(scenarios)),
        ])
    }

    /// The `BENCH_*.json` artifact text. Byte-identical for identical
    /// specs regardless of runner thread count.
    pub fn render_json(&self) -> String {
        self.to_json().render()
    }

    /// One CSV row per scenario: every spec key that appears anywhere in
    /// the grid (blank when absent), then the stats columns.
    pub fn render_csv(&self) -> String {
        let mut spec_keys: Vec<String> = Vec::new();
        for s in &self.scenarios {
            for (k, _) in s.spec.to_map() {
                if !spec_keys.contains(&k) {
                    spec_keys.push(k);
                }
            }
        }
        let stat_cols = [
            "total_us",
            "tasks_executed",
            "injection_flits_per_us",
            "throughput_flits_per_us",
            "completions_per_us",
            "busy_fraction",
            "rejected_flits",
            "edges_stepped",
            "edges_skipped",
            "edges_skipped_noc",
            "edges_skipped_iface",
            "edges_skipped_hwa",
            "latency_count",
            "latency_mean_us",
            "latency_p50_us",
            "latency_p90_us",
            "latency_p99_us",
            "latency_min_us",
            "latency_max_us",
            "processor_us",
            "fpga_us",
            "transmission_us",
            "reconfig_swaps",
            "reconfig_drain_cycles",
            "reconfig_blocked_cycles",
            "fault_injected",
            "fault_detected",
            "fault_retried",
            "fault_failed_over",
            "fault_permanently_failed",
        ];
        let mut out = String::new();
        out.push_str("scenario");
        for k in &spec_keys {
            out.push(',');
            out.push_str(k);
        }
        for c in stat_cols {
            out.push(',');
            out.push_str(c);
        }
        out.push('\n');
        for s in &self.scenarios {
            let map: std::collections::BTreeMap<String, String> =
                s.spec.to_map().into_iter().collect();
            out.push_str(&csv_cell(&s.spec.name));
            for k in &spec_keys {
                out.push(',');
                out.push_str(&csv_cell(
                    map.get(k).map(|v| v.as_str()).unwrap_or(""),
                ));
            }
            let t = &s.stats;
            let nums = [
                fmt_num(t.total_us),
                t.tasks_executed.to_string(),
                fmt_num(t.injection_flits_per_us),
                fmt_num(t.throughput_flits_per_us),
                fmt_num(t.completions_per_us),
                fmt_num(t.busy_fraction),
                t.rejected_flits.to_string(),
                t.edges_stepped.to_string(),
                t.edges_skipped.to_string(),
                t.edges_skipped_noc.to_string(),
                t.edges_skipped_iface.to_string(),
                t.edges_skipped_hwa.to_string(),
                t.latency.count.to_string(),
                fmt_num(t.latency.mean_us),
                fmt_num(t.latency.p50_us),
                fmt_num(t.latency.p90_us),
                fmt_num(t.latency.p99_us),
                fmt_num(t.latency.min_us),
                fmt_num(t.latency.max_us),
                fmt_num(t.processor_us),
                fmt_num(t.fpga_us),
                fmt_num(t.transmission_us),
                t.reconfig_swaps.to_string(),
                t.reconfig_drain_cycles.to_string(),
                t.reconfig_blocked_cycles.to_string(),
                t.fault_injected.to_string(),
                t.fault_detected.to_string(),
                t.fault_retried.to_string(),
                t.fault_failed_over.to_string(),
                t.fault_permanently_failed.to_string(),
            ];
            for n in nums {
                out.push(',');
                out.push_str(&n);
            }
            out.push('\n');
        }
        out
    }

    pub fn write_json(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.render_json())
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn write_csv(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.render_csv())
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Human summary (one row per scenario) for CLI output.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!("sweep {} — {} scenarios", self.name, self.scenarios.len()),
            &[
                "scenario",
                "total (µs)",
                "inj (fl/µs)",
                "thr (fl/µs)",
                "busy",
                "done/µs",
                "lat p50 (µs)",
                "lat p99 (µs)",
            ],
        );
        for s in &self.scenarios {
            let st = &s.stats;
            t.row(&[
                s.spec.name.clone(),
                format!("{:.2}", st.total_us),
                format!("{:.2}", st.injection_flits_per_us),
                format!("{:.2}", st.throughput_flits_per_us),
                format!("{:.0}%", 100.0 * st.busy_fraction),
                format!("{:.2}", st.completions_per_us),
                format!("{:.3}", st.latency.p50_us),
                format!("{:.3}", st.latency.p99_us),
            ]);
        }
        t
    }
}

/// Quote a CSV cell only when it needs it.
fn csv_cell(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::runner::{
        FabricStatsRow, LatencySummary, ScenarioResult,
    };
    use crate::sweep::spec::{ScenarioSpec, WorkloadSpec};

    fn dummy_report() -> SweepReport {
        let spec = ScenarioSpec::new("d[net=noc,rate_per_us=1]")
            .hwas("izigzag*2")
            .workload(WorkloadSpec::OpenLoop { rate_per_us: 1.0 });
        let stats = RunStats {
            total_us: 10.0,
            tasks_executed: 3,
            injection_flits_per_us: 1.5,
            throughput_flits_per_us: 1.25,
            completions_per_us: 0.3,
            busy_fraction: 0.5,
            rejected_flits: 0,
            edges_stepped: 100,
            edges_skipped: 50,
            edges_skipped_noc: 30,
            edges_skipped_iface: 12,
            edges_skipped_hwa: 8,
            latency: LatencySummary::from_us_samples(&[1.0, 2.0, 3.0]),
            processor_us: 0.0,
            fpga_us: 0.0,
            transmission_us: 0.0,
            reconfig_swaps: 0,
            reconfig_drain_cycles: 0,
            reconfig_blocked_cycles: 0,
            fault_injected: 0,
            fault_detected: 0,
            fault_retried: 0,
            fault_failed_over: 0,
            fault_permanently_failed: 0,
            per_fabric: vec![FabricStatsRow {
                fabric: 0,
                node: 8,
                tasks_executed: 3,
                injection_flits_per_us: 1.5,
                throughput_flits_per_us: 1.25,
                busy_fraction: 0.5,
                rejected_flits: 0,
            }],
            tenants: Vec::new(),
        };
        SweepReport {
            name: "d".to_string(),
            scenarios: vec![ScenarioResult { spec, stats }],
        }
    }

    #[test]
    fn json_is_parseable_and_self_describing() {
        let r = dummy_report();
        let v = Json::parse(&r.render_json()).unwrap();
        assert_eq!(v.get("schema").and_then(Json::as_f64), Some(5.0));
        let sc = &v.get("scenarios").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(
            sc.get("spec")
                .and_then(|s| s.get("workload.kind"))
                .and_then(Json::as_str),
            Some("openloop")
        );
        assert_eq!(
            sc.get("stats")
                .and_then(|s| s.get("tasks_executed"))
                .and_then(Json::as_f64),
            Some(3.0)
        );
        // Schema 2: per-domain skipped-edge breakdown.
        assert_eq!(
            sc.get("stats")
                .and_then(|s| s.get("edges_skipped_noc"))
                .and_then(Json::as_f64),
            Some(30.0)
        );
    }

    #[test]
    fn per_fabric_rows_are_emitted_only_for_multi_fabric_scenarios() {
        // Single-fabric (the dummy report): no "fabrics" key — legacy
        // BENCH_*.json artifacts stay byte-identical.
        let single = dummy_report();
        assert!(!single.render_json().contains("\"fabrics\""));
        // Two rows: the additive array appears.
        let mut multi = dummy_report();
        let mut extra = multi.scenarios[0].stats.per_fabric[0];
        extra.fabric = 1;
        extra.node = 0;
        multi.scenarios[0].stats.per_fabric.push(extra);
        let parsed = Json::parse(&multi.render_json()).unwrap();
        let rows = parsed.get("scenarios").and_then(Json::as_arr).unwrap()[0]
            .get("stats")
            .and_then(|s| s.get("fabrics"))
            .and_then(Json::as_arr)
            .expect("fabrics array present");
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[1].get("node").and_then(Json::as_f64),
            Some(0.0)
        );
    }

    #[test]
    fn legacy_stats_json_bytes_are_pinned() {
        // Byte-exact pin of a non-serving stats object: the serving /
        // tenants work must never perturb existing BENCH_*.json
        // artifacts (no "tenants" key, same field order, same number
        // formatting). Any diff here is a schema regression.
        let rendered = dummy_report().scenarios[0].stats.to_json().render();
        let expected = "{\n\
                        \x20 \"total_us\": 10,\n\
                        \x20 \"tasks_executed\": 3,\n\
                        \x20 \"injection_flits_per_us\": 1.5,\n\
                        \x20 \"throughput_flits_per_us\": 1.25,\n\
                        \x20 \"completions_per_us\": 0.3,\n\
                        \x20 \"busy_fraction\": 0.5,\n\
                        \x20 \"rejected_flits\": 0,\n\
                        \x20 \"edges_stepped\": 100,\n\
                        \x20 \"edges_skipped\": 50,\n\
                        \x20 \"edges_skipped_noc\": 30,\n\
                        \x20 \"edges_skipped_iface\": 12,\n\
                        \x20 \"edges_skipped_hwa\": 8,\n\
                        \x20 \"latency_us\": {\n\
                        \x20   \"count\": 3,\n\
                        \x20   \"mean\": 2,\n\
                        \x20   \"p50\": 2,\n\
                        \x20   \"p90\": 3,\n\
                        \x20   \"p99\": 3,\n\
                        \x20   \"min\": 1,\n\
                        \x20   \"max\": 3\n\
                        \x20 },\n\
                        \x20 \"processor_us\": 0,\n\
                        \x20 \"fpga_us\": 0,\n\
                        \x20 \"transmission_us\": 0\n\
                        }\n";
        assert_eq!(rendered, expected);
    }

    #[test]
    fn tenant_rows_are_emitted_only_when_present() {
        use crate::sweep::runner::{TenantCounters, TenantStatsRow};
        // Empty tenants (every non-serving workload): no "tenants" key.
        let legacy = dummy_report();
        assert!(!legacy.render_json().contains("\"tenants\""));
        // Serving stats: the additive array appears with one row per
        // tenant and the SLO/shed counters intact.
        let mut serving = dummy_report();
        serving.scenarios[0].stats.tenants = vec![
            TenantStatsRow::from_window(
                0,
                3,
                TenantCounters {
                    arrivals: 40,
                    admitted: 38,
                    completed: 38,
                    shed_bucket: 2,
                    shed_watermark: 0,
                    dropped: 0,
                    slo_violations: 5,
                    downgraded_chained: 1,
                    fault_failures: 3,
                },
                &[1.0, 2.0, 4.0],
            ),
            TenantStatsRow::from_window(
                1,
                0,
                TenantCounters::default(),
                &[],
            ),
        ];
        let parsed = Json::parse(&serving.render_json()).unwrap();
        let rows = parsed.get("scenarios").and_then(Json::as_arr).unwrap()[0]
            .get("stats")
            .and_then(|s| s.get("tenants"))
            .and_then(Json::as_arr)
            .expect("tenants array present");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("priority").and_then(Json::as_f64), Some(3.0));
        assert_eq!(
            rows[0].get("slo_violations").and_then(Json::as_f64),
            Some(5.0)
        );
        assert_eq!(rows[0].get("shed_bucket").and_then(Json::as_f64), Some(2.0));
        assert_eq!(
            rows[0].get("downgraded_chained").and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            rows[0].get("fault_failures").and_then(Json::as_f64),
            Some(3.0)
        );
        assert_eq!(rows[0].get("p999_us").and_then(Json::as_f64), Some(4.0));
        // The empty row stays NaN-free — and, having lost no work to
        // faults, carries no fault_failures key at all.
        assert_eq!(rows[1].get("count").and_then(Json::as_f64), Some(0.0));
        assert_eq!(rows[1].get("p99_us").and_then(Json::as_f64), Some(0.0));
        assert!(rows[1].get("fault_failures").is_none());
    }

    #[test]
    fn reconfig_counters_are_emitted_only_when_the_run_reconfigured() {
        // Frozen inventory (all counters zero): no reconfig keys — the
        // pinned-bytes test above is the byte-exact form of this claim.
        let frozen = dummy_report();
        assert!(!frozen.render_json().contains("reconfig_swaps"));
        // A run that swapped: the additive counters appear.
        let mut swapped = dummy_report();
        swapped.scenarios[0].stats.reconfig_swaps = 2;
        swapped.scenarios[0].stats.reconfig_drain_cycles = 17;
        swapped.scenarios[0].stats.reconfig_blocked_cycles = 4_000;
        let parsed = Json::parse(&swapped.render_json()).unwrap();
        let scenarios = parsed.get("scenarios").and_then(Json::as_arr).unwrap();
        let stats = scenarios[0].get("stats").expect("stats present");
        assert_eq!(
            stats.get("reconfig_swaps").and_then(Json::as_f64),
            Some(2.0)
        );
        assert_eq!(
            stats.get("reconfig_drain_cycles").and_then(Json::as_f64),
            Some(17.0)
        );
        assert_eq!(
            stats.get("reconfig_blocked_cycles").and_then(Json::as_f64),
            Some(4000.0)
        );
    }

    #[test]
    fn fault_counters_are_emitted_only_when_the_run_saw_faults() {
        // Fault-free (all counters zero): no fault keys — the pinned-
        // bytes test above is the byte-exact form of this claim.
        let clean = dummy_report();
        assert!(!clean.render_json().contains("fault_injected"));
        // A faulty run: the additive counters appear, in order.
        let mut faulty = dummy_report();
        faulty.scenarios[0].stats.fault_injected = 9;
        faulty.scenarios[0].stats.fault_detected = 9;
        faulty.scenarios[0].stats.fault_retried = 6;
        faulty.scenarios[0].stats.fault_failed_over = 2;
        faulty.scenarios[0].stats.fault_permanently_failed = 1;
        let parsed = Json::parse(&faulty.render_json()).unwrap();
        let scenarios = parsed.get("scenarios").and_then(Json::as_arr).unwrap();
        let stats = scenarios[0].get("stats").expect("stats present");
        assert_eq!(
            stats.get("fault_injected").and_then(Json::as_f64),
            Some(9.0)
        );
        assert_eq!(
            stats.get("fault_failed_over").and_then(Json::as_f64),
            Some(2.0)
        );
        assert_eq!(
            stats.get("fault_permanently_failed").and_then(Json::as_f64),
            Some(1.0)
        );
        // Detection alone (e.g. recovery = none sweeping losses) is
        // enough to surface the whole block.
        let mut detected_only = dummy_report();
        detected_only.scenarios[0].stats.fault_detected = 1;
        assert!(detected_only.render_json().contains("fault_retried"));
    }

    #[test]
    fn csv_has_header_plus_one_row_per_scenario() {
        let r = dummy_report();
        let csv = r.render_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("scenario,"));
        assert!(lines[0].contains("latency_p99_us"));
        // CSV columns are unconditional (a rectangular table can't be
        // additive); only the JSON is gated on activity.
        assert!(lines[0].contains("fault_permanently_failed"));
        // The scenario name contains a comma and must be quoted.
        assert!(lines[1].starts_with("\"d[net=noc,rate_per_us=1]\""));
    }

    #[test]
    fn table_renders() {
        assert!(dummy_report().table().render().contains("d[net=noc,rate_per_us=1]"));
    }
}
