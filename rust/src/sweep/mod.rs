//! Parallel scenario-sweep harness — the substrate behind every paper
//! figure regeneration and scaling experiment.
//!
//! Three pieces (see `docs/EXPERIMENTS.md` for the figure-by-figure
//! recipes):
//!
//! * [`ScenarioSpec`] / [`SweepSpec`] (`spec`) — declarative description
//!   of one simulation (interconnect, mesh, accelerator mix, workload,
//!   injection rate, buffer depths, chaining, seed) and of a parameter
//!   grid that cartesian-expands into many;
//! * [`SweepRunner`] (`runner`) — shards the expanded grid across host
//!   threads; every scenario is an independent `sim::System` with its
//!   seed in the spec, so results are bit-identical on any thread count;
//! * [`SweepReport`] (`report`) — ordered per-scenario [`RunStats`]
//!   (latency percentiles, throughput, rejected flits, skipped edges)
//!   serializing to `BENCH_*.json` and CSV.
//!
//! The `accnoc sweep <spec.toml>` CLI verb drives all three; the
//! `fig6`/`fig8`/`fig9`/`fig10`/`fig13_14` experiments and benches are
//! thin grids over this module.

pub mod report;
pub mod runner;
pub mod spec;

pub use runner::{
    run_scenario, run_scenario_with_idle_skip, serving_tenant_specs,
    FabricStatsRow, LatencySummary, RunStats, ScenarioResult, SweepReport,
    SweepRunner, TenantCounters, TenantStatsRow,
};
pub use spec::{
    AppKind, ArrivalKind, HwaMix, ScenarioSpec, ServingMix, SweepSpec,
    WorkloadSpec,
};
