//! Declarative scenario description: what to simulate (interconnect,
//! mesh, fabric, accelerator mix, chaining) and how to drive it
//! (workload kind, injection rate, warmup/window, seeds).
//!
//! A [`ScenarioSpec`] describes exactly one `sim::System` run. A
//! [`SweepSpec`] is a scenario template whose values may be lists; it
//! cartesian-expands into a grid of `ScenarioSpec`s (one per
//! combination) for `SweepRunner` to shard across host threads.
//!
//! Specs load from a TOML subset (via `util::config_text`, a list is a
//! comma-separated value) or JSON (via `util::json`, a list is an
//! array), and can be built programmatically:
//!
//! ```
//! use accnoc::sweep::{ScenarioSpec, WorkloadSpec};
//!
//! let spec = ScenarioSpec::new("smoke")
//!     .hwas("izigzag*8")
//!     .workload(WorkloadSpec::OpenLoop { rate_per_us: 2.0 })
//!     .seed(42);
//! assert_eq!(spec.system_config().unwrap().fabrics[0].specs.len(), 8);
//!
//! // Topology axes: an explicit floorplan with two fabric tiles.
//! let multi = ScenarioSpec::new("multi")
//!     .floorplan("P P F0 / P M P / P P F1")
//!     .hwas("izigzag*4");
//! assert_eq!(multi.system_config().unwrap().fabrics.len(), 2);
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::clock::PS_PER_US;
use crate::cmp::apps::{app_specs, gsm_app, jpeg_app, App};
use crate::fault::{FaultConfig, FaultSpec, RecoveryPolicy};
use crate::fpga::hwa::{spec_by_name, table3, HwaSpec};
use crate::noc::mesh::MeshConfig;
use crate::reconfig::{LatencyModel, ProvisionPolicy};
use crate::sim::floorplan::{Floorplan, MmuAssign};
use crate::sim::system::{FabricKind, FabricSpec, NetKind, SystemConfig};
use crate::util::config_text::ConfigText;
use crate::util::json::Json;

/// Per-fabric `hwas_f<k>` override keys accepted in specs (plans may
/// have more fabrics — those use the shared `system.hwas` default).
pub const MAX_FABRIC_HWA_KEYS: u8 = 4;

/// Accelerator mix: which Table 3 HWA specs populate the fabric.
#[derive(Debug, Clone, PartialEq)]
pub enum HwaMix {
    /// The first `n` Table 3 benchmarks (`"first8"`).
    First(usize),
    /// `n` copies of one benchmark (`"izigzag*8"`).
    Repeat(String, usize),
    /// An explicit `+`-separated list (`"izigzag+idct"`).
    Named(Vec<String>),
    /// The four-stage JPEG decode set (`"jpeg"`):
    /// izigzag, iquantize, idct, shiftbound.
    Jpeg,
}

impl HwaMix {
    pub fn parse(text: &str) -> Result<HwaMix, String> {
        let text = text.trim();
        if text == "jpeg" {
            return Ok(HwaMix::Jpeg);
        }
        if let Some(n) = text.strip_prefix("first") {
            let n: usize = n
                .parse()
                .map_err(|_| format!("bad hwa mix {text:?}"))?;
            return Ok(HwaMix::First(n));
        }
        if let Some((name, n)) = text.split_once('*') {
            let n: usize = n
                .trim()
                .parse()
                .map_err(|_| format!("bad hwa repeat count in {text:?}"))?;
            return Ok(HwaMix::Repeat(name.trim().to_string(), n));
        }
        Ok(HwaMix::Named(
            text.split('+').map(|s| s.trim().to_string()).collect(),
        ))
    }

    /// Resolve to concrete HWA specs (error on unknown names or an
    /// empty/oversized mix — `hwa_id` is 5 bits, so at most 32).
    pub fn to_specs(&self) -> Result<Vec<HwaSpec>, String> {
        let specs = match self {
            HwaMix::First(n) => {
                let all = table3();
                if *n == 0 || *n > all.len() {
                    return Err(format!(
                        "first{n}: need 1..={} benchmarks",
                        all.len()
                    ));
                }
                all.into_iter().take(*n).collect()
            }
            HwaMix::Repeat(name, n) => {
                let spec = spec_by_name(name)
                    .ok_or_else(|| format!("unknown HWA {name:?}"))?;
                if *n == 0 || *n > 32 {
                    return Err(format!("{name}*{n}: need 1..=32 copies"));
                }
                vec![spec; *n]
            }
            HwaMix::Named(names) => names
                .iter()
                .map(|n| {
                    spec_by_name(n)
                        .ok_or_else(|| format!("unknown HWA {n:?}"))
                })
                .collect::<Result<Vec<_>, _>>()?,
            HwaMix::Jpeg => ["izigzag", "iquantize", "idct", "shiftbound"]
                .iter()
                .map(|n| spec_by_name(n).unwrap())
                .collect(),
        };
        if specs.is_empty() {
            return Err("empty HWA mix".to_string());
        }
        Ok(specs)
    }
}

impl std::fmt::Display for HwaMix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HwaMix::First(n) => write!(f, "first{n}"),
            HwaMix::Repeat(name, n) => write!(f, "{name}*{n}"),
            HwaMix::Named(names) => write!(f, "{}", names.join("+")),
            HwaMix::Jpeg => write!(f, "jpeg"),
        }
    }
}

/// Which application the `app_partition` workload runs (paper Fig. 9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppKind {
    Gsm,
    Jpeg,
}

impl AppKind {
    pub fn app(&self) -> App {
        match self {
            AppKind::Gsm => gsm_app(0),
            AppKind::Jpeg => jpeg_app(0),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AppKind::Gsm => "gsm",
            AppKind::Jpeg => "jpeg",
        }
    }
}

/// Arrival-process family for the `serving` workload (every tenant in
/// the scenario uses the same family; the runner derives per-tenant
/// parameters deterministically from the tenant index).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    Poisson,
    /// MMPP on/off bursts (4x rate inside bursts, 2 µs mean on-phase).
    Bursty,
    /// Sinusoidal rate envelope (20 µs period, 0.8 depth).
    Diurnal,
}

impl ArrivalKind {
    pub fn parse(text: &str) -> Result<ArrivalKind, String> {
        match text {
            "poisson" => Ok(ArrivalKind::Poisson),
            "bursty" => Ok(ArrivalKind::Bursty),
            "diurnal" => Ok(ArrivalKind::Diurnal),
            other => Err(format!(
                "workload.arrival: {other:?} (poisson|bursty|diurnal)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Diurnal => "diurnal",
        }
    }
}

/// Job-mix family for the `serving` workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingMix {
    /// Every tenant issues direct (processor -> HWA) jobs only.
    Direct,
    /// Tenants cycle through direct / via-memory / chained profiles by
    /// tenant index (chained jobs need `system.chain = true` to stay
    /// chained; otherwise they downgrade to direct at admission).
    Mixed,
    /// Direct jobs with a hard phase change: every tenant wants `gsm`
    /// for the first 30 simulated µs, then `dfmul` — the demand shift a
    /// reconfigurable inventory can follow and a static one cannot.
    Phased,
}

impl ServingMix {
    pub fn parse(text: &str) -> Result<ServingMix, String> {
        match text {
            "direct" => Ok(ServingMix::Direct),
            "mixed" => Ok(ServingMix::Mixed),
            "phased" => Ok(ServingMix::Phased),
            other => Err(format!(
                "workload.mix: {other:?} (direct|mixed|phased)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServingMix::Direct => "direct",
            ServingMix::Mixed => "mixed",
            ServingMix::Phased => "phased",
        }
    }
}

/// How the scenario drives the system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorkloadSpec {
    /// §6.4: every processor becomes an open-loop source at the given
    /// aggregate rate; stats are measured over warmup+window.
    OpenLoop { rate_per_us: f64 },
    /// §6.2 (Fig. 6): every processor issues `requests_per_proc`
    /// back-to-back invocations of HWA 0, then the system drains.
    Burst { requests_per_proc: usize },
    /// §6.6 (Fig. 10): one processor decodes `blocks` JPEG blocks at the
    /// given chaining depth (0 = full round trips).
    JpegChain { depth: u8, blocks: usize },
    /// §6.5 (Fig. 9): one processor runs partition `partition` of `app`,
    /// reporting the processor/FPGA/transmission latency breakdown.
    AppPartition { app: AppKind, partition: usize },
    /// Multi-tenant serving: `tenants` traffic streams at an aggregate
    /// `rate_per_us` share the accelerators through admission control
    /// and priority-aware arbitration; the report gains a per-tenant
    /// `stats.tenants` table (p50/p99/p99.9, SLO violations, sheds).
    Serving {
        rate_per_us: f64,
        tenants: u16,
        arrival: ArrivalKind,
        admission: bool,
        slo_us: f64,
        mix: ServingMix,
    },
}

impl WorkloadSpec {
    pub fn kind(&self) -> &'static str {
        match self {
            WorkloadSpec::OpenLoop { .. } => "openloop",
            WorkloadSpec::Burst { .. } => "burst",
            WorkloadSpec::JpegChain { .. } => "jpeg_chain",
            WorkloadSpec::AppPartition { .. } => "app_partition",
            WorkloadSpec::Serving { .. } => "serving",
        }
    }
}

/// One fully-resolved simulation scenario. Every field that shapes the
/// simulated hardware or workload lives here; two runs of the same spec
/// produce bit-identical statistics on any thread count, because the
/// seed is part of the spec itself.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub net: NetKind,
    /// `buffered` or `shared_cache` (see `cache_kib`); applies to every
    /// fabric tile.
    pub fabric: FabricKind,
    pub mesh: (u8, u8),
    /// Explicit tile map (`"P P F0 / P M P / P P F1"`). `None` lowers
    /// `mesh` to the legacy single-FPGA floorplan.
    pub floorplan: Option<String>,
    /// Processor → MMU assignment for multi-MMU floorplans.
    pub mmu_assign: MmuAssign,
    /// Per-fabric accelerator-mix overrides (`hwas_f<k>` keys); fabrics
    /// without an entry use `hwas`.
    pub fabric_hwas: BTreeMap<u8, HwaMix>,
    /// Task buffers per channel (the Fig. 6 independent variable).
    pub n_tbs: usize,
    pub pr_group: usize,
    pub ps_group: usize,
    pub iface_mhz: f64,
    /// FPGA part the per-fabric inventory is budgeted against
    /// (`system.device`; the xc7vx690t default preserves every legacy
    /// budget check byte-for-byte).
    pub device: crate::synth::Device,
    pub hwas: HwaMix,
    /// Chain all HWAs into one group (Fig. 10 setup).
    pub chain: bool,
    pub workload: WorkloadSpec,
    pub seed: u64,
    pub warmup_us: u64,
    pub window_us: u64,
    /// Closed-loop runs failing to drain by this simulated time error out.
    pub deadline_us: u64,
    /// Dynamic-reconfiguration policy. `Static` (the default) freezes
    /// the inventory and keeps every run bit-identical to pre-reconfig
    /// builds; anything else marks every slot reconfigurable and runs
    /// the provisioner each epoch.
    pub reconfig_policy: ProvisionPolicy,
    /// Provisioner decision period (simulated µs).
    pub reconfig_epoch_us: f64,
    /// Bitstream-programming latency model for swaps.
    pub reconfig_latency: LatencyModel,
    /// Fault-injection class and rate (`fault.spec`). The `None`
    /// default installs nothing, keeping every run byte-identical to
    /// pre-fault builds.
    pub fault_spec: FaultSpec,
    /// What the system does about detected faults (`fault.recovery`).
    pub fault_recovery: RecoveryPolicy,
    /// Source/watchdog deadline in simulated µs (`fault.timeout_us`):
    /// work invisible for this long is declared lost.
    pub fault_timeout_us: f64,
    /// Scrubber period in simulated µs (`fault.scrub_us`): how often
    /// upset (dead) slots are re-programmed.
    pub fault_scrub_us: f64,
}

impl ScenarioSpec {
    /// Paper defaults (3x3 NoC mesh, buffered fabric, 2 TBs, PR4-PS4,
    /// first eight Table 3 HWAs, 1 req/µs open loop).
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            net: NetKind::Noc,
            fabric: FabricKind::Buffered,
            mesh: (3, 3),
            floorplan: None,
            mmu_assign: MmuAssign::Nearest,
            fabric_hwas: BTreeMap::new(),
            n_tbs: 2,
            pr_group: 4,
            ps_group: 4,
            iface_mhz: 300.0,
            device: crate::synth::Device::default(),
            hwas: HwaMix::First(8),
            chain: false,
            workload: WorkloadSpec::OpenLoop { rate_per_us: 1.0 },
            seed: 7,
            warmup_us: 5,
            window_us: 40,
            deadline_us: 100_000,
            reconfig_policy: ProvisionPolicy::Static,
            reconfig_epoch_us: 5.0,
            reconfig_latency: LatencyModel::default(),
            fault_spec: FaultSpec::None,
            fault_recovery: RecoveryPolicy::None,
            fault_timeout_us: 20.0,
            fault_scrub_us: 50.0,
        }
    }

    /// Arm fault injection under `spec` with recovery `policy` (timeout
    /// and scrub period keep their defaults; set the `fault_*` fields
    /// directly for full control).
    pub fn faults(
        mut self,
        spec: FaultSpec,
        recovery: RecoveryPolicy,
    ) -> Self {
        self.fault_spec = spec;
        self.fault_recovery = recovery;
        self
    }

    /// The lowered fault configuration this scenario arms (the runner
    /// hands it to `System::set_faults`; a `None` spec arms nothing).
    pub fn fault_config(&self) -> FaultConfig {
        FaultConfig {
            spec: self.fault_spec,
            recovery: self.fault_recovery,
            timeout_ps: (self.fault_timeout_us * PS_PER_US as f64) as u64,
            scrub_ps: (self.fault_scrub_us * PS_PER_US as f64) as u64,
            seed: self.seed,
        }
    }

    /// Enable demand-driven reconfiguration under `policy` (epoch and
    /// latency model keep their defaults; set the fields directly for
    /// full control).
    pub fn reconfig(mut self, policy: ProvisionPolicy) -> Self {
        self.reconfig_policy = policy;
        self
    }

    pub fn net(mut self, net: NetKind) -> Self {
        self.net = net;
        self
    }

    pub fn fabric(mut self, fabric: FabricKind) -> Self {
        self.fabric = fabric;
        self
    }

    pub fn mesh(mut self, width: u8, height: u8) -> Self {
        self.mesh = (width, height);
        self
    }

    /// Explicit floorplan in [`Floorplan::parse`] grammar; the plan is
    /// authoritative for the mesh dimensions (`mesh` is updated to
    /// match). Panics on a syntax error (use the field +
    /// `system_config()` for fallible input).
    pub fn floorplan(mut self, plan: &str) -> Self {
        let parsed = Floorplan::parse(plan).expect("valid floorplan");
        self.mesh = (parsed.mesh.width, parsed.mesh.height);
        self.floorplan = Some(plan.to_string());
        self
    }

    pub fn mmu_assign(mut self, assign: MmuAssign) -> Self {
        self.mmu_assign = assign;
        self
    }

    /// Accelerator mix for one fabric (others keep the `hwas` default);
    /// panics on a syntax error.
    pub fn hwas_on(mut self, fabric: u8, mix: &str) -> Self {
        self.fabric_hwas
            .insert(fabric, HwaMix::parse(mix).expect("valid hwa mix"));
        self
    }

    pub fn task_buffers(mut self, n: usize) -> Self {
        self.n_tbs = n;
        self
    }

    /// Budget the inventory against a different FPGA part.
    pub fn device(mut self, device: crate::synth::Device) -> Self {
        self.device = device;
        self
    }

    /// Accelerator mix, in [`HwaMix::parse`] syntax; panics on a syntax
    /// error (use `HwaMix::parse` + field assignment for fallible input).
    pub fn hwas(mut self, mix: &str) -> Self {
        self.hwas = HwaMix::parse(mix).expect("valid hwa mix");
        self
    }

    pub fn chain(mut self, on: bool) -> Self {
        self.chain = on;
        self
    }

    pub fn workload(mut self, workload: WorkloadSpec) -> Self {
        self.workload = workload;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn warmup_us(mut self, us: u64) -> Self {
        self.warmup_us = us;
        self
    }

    pub fn window_us(mut self, us: u64) -> Self {
        self.window_us = us;
        self
    }

    pub fn deadline_us(mut self, us: u64) -> Self {
        self.deadline_us = us;
        self
    }

    /// The floorplan this scenario lowers to: the explicit plan text, or
    /// the legacy single-FPGA lowering of `mesh`. Syntax errors surface
    /// here; the full semantic validation runs in [`Self::system_config`].
    pub fn plan(&self) -> Result<Floorplan, String> {
        // The floorplan, when present, is authoritative for the mesh
        // dimensions (`from_map` rejects a conflicting explicit
        // `system.mesh` at load time, where set-ness is knowable).
        match &self.floorplan {
            Some(text) => Floorplan::parse(text).map_err(|e| e.to_string()),
            None => Ok(Floorplan::single_fpga(MeshConfig {
                width: self.mesh.0,
                height: self.mesh.1,
                ..MeshConfig::default()
            })),
        }
    }

    /// One `FabricSpec` per fabric tile of `plan`, with this scenario's
    /// per-fabric mix overrides resolved — but WITHOUT the construction-
    /// time budget/topology validation `system_config` runs. The
    /// autotuner uses this to cost candidates it may never build.
    pub fn fabric_specs(
        &self,
        plan: &Floorplan,
    ) -> Result<Vec<FabricSpec>, String> {
        if self.n_tbs == 0 {
            return Err("task_buffers must be >= 1".to_string());
        }
        for f in self.fabric_hwas.keys() {
            if (*f as usize) >= plan.n_fabrics() {
                return Err(format!(
                    "hwas_f{f}: the floorplan has {} fabric(s)",
                    plan.n_fabrics()
                ));
            }
        }
        let mut fabrics = Vec::with_capacity(plan.n_fabrics());
        for f in 0..plan.n_fabrics() {
            let specs = match &self.workload {
                // Fig. 9 scenarios derive their specs from the app's
                // function list (hwa_id = function index).
                WorkloadSpec::AppPartition { app, .. } => {
                    app_specs(&app.app())
                }
                _ => self
                    .fabric_hwas
                    .get(&(f as u8))
                    .unwrap_or(&self.hwas)
                    .to_specs()?,
            };
            let chain_groups = if self.chain {
                vec![(0..specs.len()).collect()]
            } else {
                Vec::new()
            };
            // A non-static policy puts every slot in a PR region; the
            // static default declares none, freezing the inventory.
            let reconfigurable =
                if self.reconfig_policy == ProvisionPolicy::Static {
                    Vec::new()
                } else {
                    (0..specs.len()).collect()
                };
            fabrics.push(FabricSpec {
                kind: self.fabric,
                n_tbs: self.n_tbs,
                pr_group: self.pr_group,
                ps_group: self.ps_group,
                iface_mhz: self.iface_mhz,
                specs,
                chain_groups,
                reconfigurable,
            });
        }
        Ok(fabrics)
    }

    /// Resolve into the `sim::System` configuration this scenario runs:
    /// the floorplan (explicit, or the legacy single-FPGA lowering of
    /// `mesh`) plus one `FabricSpec` per fabric tile. Every topology
    /// defect surfaces here as an error, never as a mid-sweep panic.
    pub fn system_config(&self) -> Result<SystemConfig, String> {
        let plan = self.plan()?;
        // (cfg.validate() below runs the full floorplan validation.)
        let fabrics = self.fabric_specs(&plan)?;
        let cfg = SystemConfig {
            floorplan: plan,
            net: self.net,
            fabrics,
            mmu_assign: self.mmu_assign,
            device: self.device,
        };
        cfg.validate().map_err(|e| e.to_string())?;
        Ok(cfg)
    }

    /// Flatten to the canonical `section.key -> value` map (the TOML/JSON
    /// wire format; also embedded per scenario in `BENCH_*.json`).
    pub fn to_map(&self) -> Vec<(String, String)> {
        let mut m: Vec<(String, String)> = Vec::new();
        let mut put = |k: &str, v: String| m.push((k.to_string(), v));
        put("system.net", net_name(self.net).to_string());
        match self.fabric {
            FabricKind::Buffered => {
                put("system.fabric", "buffered".to_string());
            }
            FabricKind::SharedCache { cache_bytes } => {
                put("system.fabric", "shared_cache".to_string());
                put("system.cache_kib", (cache_bytes / 1024).to_string());
            }
        }
        // Topology keys are emitted only when non-default, so legacy
        // single-FPGA specs keep their exact pre-floorplan map (and
        // BENCH_*.json stays byte-identical through the compat path).
        // Floorplanned specs emit the plan INSTEAD of `system.mesh` —
        // the plan's rows fix the dimensions.
        match &self.floorplan {
            Some(plan) => put("system.floorplan", plan.clone()),
            None => {
                put("system.mesh", format!("{}x{}", self.mesh.0, self.mesh.1))
            }
        }
        if self.mmu_assign != MmuAssign::Nearest {
            put("system.mmu_assign", self.mmu_assign.name().to_string());
        }
        for (f, mix) in &self.fabric_hwas {
            put(&format!("system.hwas_f{f}"), mix.to_string());
        }
        // The device key is emitted only when non-default, so legacy
        // specs keep their exact pre-`Device` map.
        if self.device != crate::synth::Device::default() {
            put("system.device", self.device.name.to_string());
        }
        put("system.task_buffers", self.n_tbs.to_string());
        put("system.pr_group", self.pr_group.to_string());
        put("system.ps_group", self.ps_group.to_string());
        put("system.iface_mhz", format!("{}", self.iface_mhz));
        put("system.hwas", self.hwas.to_string());
        put("system.chain", self.chain.to_string());
        put("workload.kind", self.workload.kind().to_string());
        match &self.workload {
            WorkloadSpec::OpenLoop { rate_per_us } => {
                put("workload.rate_per_us", format!("{rate_per_us}"));
            }
            WorkloadSpec::Burst { requests_per_proc } => {
                put(
                    "workload.requests_per_proc",
                    requests_per_proc.to_string(),
                );
            }
            WorkloadSpec::JpegChain { depth, blocks } => {
                put("workload.depth", depth.to_string());
                put("workload.blocks", blocks.to_string());
            }
            WorkloadSpec::AppPartition { app, partition } => {
                put("workload.app", app.name().to_string());
                put("workload.partition", partition.to_string());
            }
            WorkloadSpec::Serving {
                rate_per_us,
                tenants,
                arrival,
                admission,
                slo_us,
                mix,
            } => {
                put("workload.rate_per_us", format!("{rate_per_us}"));
                put("workload.tenants", tenants.to_string());
                put("workload.arrival", arrival.name().to_string());
                put("workload.admission", admission.to_string());
                put("workload.slo_us", format!("{slo_us}"));
                put("workload.mix", mix.name().to_string());
            }
        }
        put("workload.seed", self.seed.to_string());
        put("workload.warmup_us", self.warmup_us.to_string());
        put("workload.window_us", self.window_us.to_string());
        put("workload.deadline_us", self.deadline_us.to_string());
        // Reconfig keys are emitted only when non-default, so legacy
        // specs keep their exact pre-reconfig map.
        if self.reconfig_policy != ProvisionPolicy::Static {
            put(
                "reconfig.policy",
                self.reconfig_policy.name().to_string(),
            );
        }
        if self.reconfig_epoch_us != 5.0 {
            put("reconfig.epoch_us", format!("{}", self.reconfig_epoch_us));
        }
        if self.reconfig_latency != LatencyModel::default() {
            put("reconfig.latency_model", self.reconfig_latency.name());
        }
        // Fault keys are likewise emitted only when non-default, so
        // legacy specs keep their exact pre-fault map.
        if self.fault_spec != FaultSpec::None {
            put("fault.spec", self.fault_spec.name());
        }
        if self.fault_recovery != RecoveryPolicy::None {
            put("fault.recovery", self.fault_recovery.name().to_string());
        }
        if self.fault_timeout_us != 20.0 {
            put("fault.timeout_us", format!("{}", self.fault_timeout_us));
        }
        if self.fault_scrub_us != 50.0 {
            put("fault.scrub_us", format!("{}", self.fault_scrub_us));
        }
        m
    }

    /// Parse from a flat `section.key -> value` map. Unknown keys and
    /// unparsable values are errors (specs are hand-written; silently
    /// ignoring a typo would quietly run the wrong experiment).
    pub fn from_map(
        name: &str,
        map: &BTreeMap<String, String>,
    ) -> Result<Self, String> {
        let spec = Self::from_map_unvalidated(name, map)?;
        spec.system_config()?; // validate the whole shape eagerly
        Ok(spec)
    }

    /// [`Self::from_map`] without the eager `system_config()`
    /// validation: field syntax is still checked, but a spec whose
    /// *shape* is unbuildable (over-budget inventory, bad floorplan
    /// semantics) parses fine. The autotuner needs this — its
    /// feasibility filter must inspect and cost candidates that the
    /// construction-time budget check would reject outright.
    pub fn from_map_unvalidated(
        name: &str,
        map: &BTreeMap<String, String>,
    ) -> Result<Self, String> {
        for k in map.keys() {
            if !KNOWN_KEYS.contains(&k.as_str()) {
                return Err(format!(
                    "unknown spec key {k:?} (known: {})",
                    KNOWN_KEYS.join(", ")
                ));
            }
        }
        let mut spec = ScenarioSpec::new(name);
        if let Some(v) = map.get("system.net") {
            spec.net = match v.as_str() {
                "noc" => NetKind::Noc,
                "axi" => NetKind::Axi,
                other => return Err(format!("system.net: {other:?} (noc|axi)")),
            };
        }
        let cache_kib: u32 = get_parse(map, "system.cache_kib")?.unwrap_or(128);
        if let Some(v) = map.get("system.fabric") {
            spec.fabric = match v.as_str() {
                "buffered" => FabricKind::Buffered,
                "shared_cache" => FabricKind::SharedCache {
                    cache_bytes: cache_kib * 1024,
                },
                other => {
                    return Err(format!(
                        "system.fabric: {other:?} (buffered|shared_cache)"
                    ))
                }
            };
        }
        if let Some(v) = map.get("system.mesh") {
            let (w, h) = v
                .split_once('x')
                .ok_or_else(|| format!("system.mesh: {v:?} (want WxH)"))?;
            spec.mesh = (
                w.trim().parse().map_err(|_| format!("bad mesh width {w:?}"))?,
                h.trim()
                    .parse()
                    .map_err(|_| format!("bad mesh height {h:?}"))?,
            );
        }
        if let Some(v) = map.get("system.floorplan") {
            let plan = Floorplan::parse(v).map_err(|e| e.to_string())?;
            let dims = (plan.mesh.width, plan.mesh.height);
            // The plan's rows ARE the mesh; an explicitly-written
            // `system.mesh` must agree exactly (any mismatch — even one
            // that happens to equal the 3x3 default — is a typo).
            if map.contains_key("system.mesh") && spec.mesh != dims {
                return Err(format!(
                    "system.mesh {}x{} conflicts with the floorplan's \
                     {}x{} (drop system.mesh)",
                    spec.mesh.0, spec.mesh.1, dims.0, dims.1
                ));
            }
            spec.mesh = dims;
            spec.floorplan = Some(v.clone());
        }
        if let Some(v) = map.get("system.mmu_assign") {
            spec.mmu_assign = MmuAssign::parse(v)?;
        }
        for f in 0..MAX_FABRIC_HWA_KEYS {
            if let Some(v) = map.get(&format!("system.hwas_f{f}")) {
                let mix = HwaMix::parse(v)?;
                mix.to_specs()?; // validate names eagerly
                spec.fabric_hwas.insert(f, mix);
            }
        }
        spec.n_tbs = get_parse(map, "system.task_buffers")?.unwrap_or(spec.n_tbs);
        spec.pr_group = get_parse(map, "system.pr_group")?.unwrap_or(spec.pr_group);
        spec.ps_group = get_parse(map, "system.ps_group")?.unwrap_or(spec.ps_group);
        spec.iface_mhz =
            get_parse(map, "system.iface_mhz")?.unwrap_or(spec.iface_mhz);
        if let Some(v) = map.get("system.device") {
            spec.device = crate::synth::Device::parse(v)?;
        }
        if let Some(v) = map.get("system.hwas") {
            spec.hwas = HwaMix::parse(v)?;
            spec.hwas.to_specs()?; // validate names eagerly
        }
        if let Some(v) = map.get("system.chain") {
            spec.chain = v
                .parse()
                .map_err(|_| format!("system.chain: {v:?} (true|false)"))?;
        }
        let kind = map
            .get("workload.kind")
            .map(|s| s.as_str())
            .unwrap_or("openloop");
        spec.workload = match kind {
            "openloop" => WorkloadSpec::OpenLoop {
                rate_per_us: get_parse(map, "workload.rate_per_us")?
                    .unwrap_or(1.0),
            },
            "burst" => WorkloadSpec::Burst {
                requests_per_proc: get_parse(map, "workload.requests_per_proc")?
                    .unwrap_or(8),
            },
            "jpeg_chain" => WorkloadSpec::JpegChain {
                depth: get_parse(map, "workload.depth")?.unwrap_or(0),
                blocks: get_parse(map, "workload.blocks")?.unwrap_or(12),
            },
            "app_partition" => WorkloadSpec::AppPartition {
                app: match map
                    .get("workload.app")
                    .map(|s| s.as_str())
                    .unwrap_or("jpeg")
                {
                    "gsm" => AppKind::Gsm,
                    "jpeg" => AppKind::Jpeg,
                    other => {
                        return Err(format!(
                            "workload.app: {other:?} (gsm|jpeg)"
                        ))
                    }
                },
                partition: get_parse(map, "workload.partition")?.unwrap_or(0),
            },
            "serving" => WorkloadSpec::Serving {
                rate_per_us: get_parse(map, "workload.rate_per_us")?
                    .unwrap_or(1.0),
                tenants: get_parse(map, "workload.tenants")?.unwrap_or(4),
                arrival: match map.get("workload.arrival") {
                    Some(v) => ArrivalKind::parse(v)?,
                    None => ArrivalKind::Poisson,
                },
                admission: get_parse(map, "workload.admission")?
                    .unwrap_or(true),
                slo_us: get_parse(map, "workload.slo_us")?.unwrap_or(20.0),
                mix: match map.get("workload.mix") {
                    Some(v) => ServingMix::parse(v)?,
                    None => ServingMix::Direct,
                },
            },
            other => {
                return Err(format!(
                    "workload.kind: {other:?} \
                     (openloop|burst|jpeg_chain|app_partition|serving)"
                ))
            }
        };
        let rate = match spec.workload {
            WorkloadSpec::OpenLoop { rate_per_us } => Some(rate_per_us),
            WorkloadSpec::Serving { rate_per_us, .. } => Some(rate_per_us),
            _ => None,
        };
        if let Some(rate_per_us) = rate {
            if !rate_per_us.is_finite() || rate_per_us <= 0.0 {
                return Err(format!(
                    "workload.rate_per_us must be > 0, got {rate_per_us}"
                ));
            }
        }
        if let WorkloadSpec::Serving {
            tenants, slo_us, ..
        } = spec.workload
        {
            if tenants == 0 {
                return Err("workload.tenants must be >= 1".to_string());
            }
            if !slo_us.is_finite() || slo_us <= 0.0 {
                return Err(format!(
                    "workload.slo_us must be > 0, got {slo_us}"
                ));
            }
        }
        if let WorkloadSpec::JpegChain { depth, .. } = spec.workload {
            if depth > 3 {
                return Err(format!("workload.depth {depth} > 3"));
            }
        }
        if let WorkloadSpec::AppPartition { app, partition } = spec.workload {
            let n = app.app().n_partitions();
            if partition >= n {
                return Err(format!(
                    "workload.partition {partition} out of range for {} \
                     (has {n} partitions)",
                    app.name()
                ));
            }
        }
        if let Some(v) = map.get("reconfig.policy") {
            spec.reconfig_policy = ProvisionPolicy::parse(v)?;
        }
        spec.reconfig_epoch_us = get_parse(map, "reconfig.epoch_us")?
            .unwrap_or(spec.reconfig_epoch_us);
        if !spec.reconfig_epoch_us.is_finite() || spec.reconfig_epoch_us <= 0.0
        {
            return Err(format!(
                "reconfig.epoch_us must be > 0, got {}",
                spec.reconfig_epoch_us
            ));
        }
        if let Some(v) = map.get("reconfig.latency_model") {
            spec.reconfig_latency = LatencyModel::parse(v)?;
        }
        if let Some(v) = map.get("fault.spec") {
            spec.fault_spec = FaultSpec::parse(v)?;
        }
        if let Some(v) = map.get("fault.recovery") {
            spec.fault_recovery = RecoveryPolicy::parse(v)?;
        }
        spec.fault_timeout_us = get_parse(map, "fault.timeout_us")?
            .unwrap_or(spec.fault_timeout_us);
        if !spec.fault_timeout_us.is_finite() || spec.fault_timeout_us <= 0.0
        {
            return Err(format!(
                "fault.timeout_us must be > 0, got {}",
                spec.fault_timeout_us
            ));
        }
        spec.fault_scrub_us =
            get_parse(map, "fault.scrub_us")?.unwrap_or(spec.fault_scrub_us);
        if !spec.fault_scrub_us.is_finite() || spec.fault_scrub_us <= 0.0 {
            return Err(format!(
                "fault.scrub_us must be > 0, got {}",
                spec.fault_scrub_us
            ));
        }
        spec.seed = get_parse(map, "workload.seed")?.unwrap_or(spec.seed);
        spec.warmup_us =
            get_parse(map, "workload.warmup_us")?.unwrap_or(spec.warmup_us);
        spec.window_us =
            get_parse(map, "workload.window_us")?.unwrap_or(spec.window_us);
        spec.deadline_us =
            get_parse(map, "workload.deadline_us")?.unwrap_or(spec.deadline_us);
        Ok(spec)
    }
}

fn net_name(net: NetKind) -> &'static str {
    match net {
        NetKind::Noc => "noc",
        NetKind::Axi => "axi",
    }
}

fn get_parse<T: std::str::FromStr>(
    map: &BTreeMap<String, String>,
    key: &str,
) -> Result<Option<T>, String> {
    match map.get(key) {
        None => Ok(None),
        Some(s) => s
            .parse()
            .map(Some)
            .map_err(|_| format!("{key}: cannot parse {s:?}")),
    }
}

/// Is `key` one `ScenarioSpec::from_map` accepts? (The autotune spec
/// parser vets its search-space keys against the same list.)
pub(crate) fn known_spec_key(key: &str) -> bool {
    KNOWN_KEYS.contains(&key)
}

/// Every key `ScenarioSpec::from_map` accepts (anything else is a typo).
const KNOWN_KEYS: &[&str] = &[
    "system.net",
    "system.fabric",
    "system.cache_kib",
    "system.mesh",
    "system.floorplan",
    "system.mmu_assign",
    "system.hwas_f0",
    "system.hwas_f1",
    "system.hwas_f2",
    "system.hwas_f3",
    "system.task_buffers",
    "system.pr_group",
    "system.ps_group",
    "system.iface_mhz",
    "system.device",
    "system.hwas",
    "system.chain",
    "workload.kind",
    "workload.rate_per_us",
    "workload.requests_per_proc",
    "workload.depth",
    "workload.blocks",
    "workload.app",
    "workload.partition",
    "workload.tenants",
    "workload.arrival",
    "workload.admission",
    "workload.slo_us",
    "workload.mix",
    "workload.seed",
    "workload.warmup_us",
    "workload.window_us",
    "workload.deadline_us",
    "reconfig.policy",
    "reconfig.epoch_us",
    "reconfig.latency_model",
    "fault.spec",
    "fault.recovery",
    "fault.timeout_us",
    "fault.scrub_us",
];

/// A scenario template whose values may be lists: the cartesian product
/// over all list-valued keys is the sweep grid.
///
/// ```
/// use accnoc::sweep::SweepSpec;
///
/// let sweep = SweepSpec::parse_toml(
///     "name = demo\n\
///      [system]\n\
///      net = noc,axi\n\
///      hwas = izigzag*8\n\
///      [workload]\n\
///      kind = openloop\n\
///      rate_per_us = 0.5,1.0,2.0\n",
/// )
/// .unwrap();
/// let grid = sweep.expand().unwrap();
/// assert_eq!(grid.len(), 6); // 2 nets x 3 rates
/// assert_eq!(grid[0].name, "demo[net=noc,rate_per_us=0.5]");
/// ```
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    /// Default output path for the JSON report (`BENCH_<name>.json`).
    pub output: Option<String>,
    /// `section.key` -> one or more candidate values.
    values: BTreeMap<String, Vec<String>>,
}

impl SweepSpec {
    /// Start an empty template (programmatic alternative to TOML/JSON).
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            output: None,
            values: BTreeMap::new(),
        }
    }

    /// Set a single value (replacing any previous entry for the key).
    pub fn set(mut self, key: &str, value: &str) -> Self {
        self.values
            .insert(key.to_string(), vec![value.to_string()]);
        self
    }

    /// Set a sweep axis: one scenario per value.
    pub fn axis<S: std::fmt::Display>(mut self, key: &str, values: &[S]) -> Self {
        self.values.insert(
            key.to_string(),
            values.iter().map(|v| v.to_string()).collect(),
        );
        self
    }

    /// Parse the TOML subset: `[system]`/`[workload]` sections, one
    /// `key = value` per line, comma-separated values forming axes.
    pub fn parse_toml(text: &str) -> Result<Self, String> {
        let cfg = ConfigText::parse(text)?;
        let mut spec = SweepSpec::new("sweep");
        for key in cfg.keys() {
            let raw = cfg.get(key).unwrap();
            match key {
                "name" => spec.name = raw.to_string(),
                "output" => spec.output = Some(raw.to_string()),
                _ => {
                    let vals = split_list(raw);
                    if vals.is_empty() {
                        return Err(format!("{key}: empty value"));
                    }
                    spec.values.insert(key.to_string(), vals);
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Parse the JSON form: `{"name": ..., "system": {...}, "workload":
    /// {...}}`; arrays are sweep axes.
    pub fn parse_json(text: &str) -> Result<Self, String> {
        let root = Json::parse(text)?;
        let members = root
            .as_obj()
            .ok_or("sweep spec JSON must be an object")?;
        let mut spec = SweepSpec::new("sweep");
        for (key, value) in members {
            match key.as_str() {
                "name" => {
                    spec.name = value
                        .as_str()
                        .ok_or("name must be a string")?
                        .to_string();
                }
                "output" => {
                    spec.output = Some(
                        value
                            .as_str()
                            .ok_or("output must be a string")?
                            .to_string(),
                    );
                }
                section => {
                    let fields = value.as_obj().ok_or_else(|| {
                        format!("{section}: expected an object")
                    })?;
                    for (k, v) in fields {
                        let key = format!("{section}.{k}");
                        let vals = json_scalar_list(v)
                            .map_err(|e| format!("{key}: {e}"))?;
                        spec.values.insert(key, vals);
                    }
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load from a path, dispatching on the `.json` extension (anything
    /// else parses as TOML).
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        if path.extension().and_then(|e| e.to_str()) == Some("json") {
            Self::parse_json(&text)
        } else {
            Self::parse_toml(&text)
        }
    }

    fn validate(&self) -> Result<(), String> {
        // Expanding validates every combination; a tiny grid is cheap to
        // check eagerly, and load-time errors beat mid-sweep panics.
        self.expand().map(|_| ())
    }

    /// The list-valued keys, in deterministic (sorted-key) order.
    pub fn axes(&self) -> Vec<(&str, &[String])> {
        self.values
            .iter()
            .filter(|(_, v)| v.len() > 1)
            .map(|(k, v)| (k.as_str(), v.as_slice()))
            .collect()
    }

    /// Cartesian-expand into the scenario grid. Scenario order (and thus
    /// report order) is deterministic: axes iterate in sorted-key order,
    /// last axis fastest.
    pub fn expand(&self) -> Result<Vec<ScenarioSpec>, String> {
        let keys: Vec<&String> = self.values.keys().collect();
        let mut grid = vec![BTreeMap::new()];
        for key in keys {
            let vals = &self.values[key];
            if vals.is_empty() {
                return Err(format!("{key}: empty value list"));
            }
            let mut next = Vec::with_capacity(grid.len() * vals.len());
            for base in &grid {
                for v in vals {
                    let mut m = base.clone();
                    m.insert(key.clone(), v.clone());
                    next.push(m);
                }
            }
            grid = next;
        }
        let axis_keys: Vec<String> =
            self.axes().iter().map(|(k, _)| k.to_string()).collect();
        grid.iter()
            .map(|m| {
                let name = if axis_keys.is_empty() {
                    self.name.clone()
                } else {
                    let parts: Vec<String> = axis_keys
                        .iter()
                        .map(|k| {
                            let short =
                                k.rsplit('.').next().unwrap_or(k.as_str());
                            format!("{short}={}", m[k])
                        })
                        .collect();
                    format!("{}[{}]", self.name, parts.join(","))
                };
                ScenarioSpec::from_map(&name, m)
            })
            .collect()
    }

    /// Default report path: the spec's `output` or `BENCH_<name>.json`.
    pub fn output_path(&self) -> String {
        self.output
            .clone()
            .unwrap_or_else(|| format!("BENCH_{}.json", self.name))
    }
}

pub(crate) fn split_list(raw: &str) -> Vec<String> {
    raw.split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

fn json_scalar_list(v: &Json) -> Result<Vec<String>, String> {
    let scalar = |v: &Json| -> Result<String, String> {
        match v {
            Json::Str(s) => Ok(s.clone()),
            Json::Num(x) => Ok(crate::util::json::fmt_num(*x)),
            Json::Bool(b) => Ok(b.to_string()),
            other => Err(format!("expected a scalar, got {other:?}")),
        }
    };
    match v {
        Json::Arr(items) => items.iter().map(scalar).collect(),
        other => Ok(vec![scalar(other)?]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_round_trips_through_map() {
        let spec = ScenarioSpec::new("rt")
            .net(NetKind::Axi)
            .fabric(FabricKind::SharedCache {
                cache_bytes: 64 * 1024,
            })
            .mesh(4, 4)
            .task_buffers(3)
            .hwas("izigzag*4")
            .workload(WorkloadSpec::OpenLoop { rate_per_us: 2.5 })
            .seed(99)
            .warmup_us(1)
            .window_us(2)
            .deadline_us(3);
        let map: BTreeMap<String, String> =
            spec.to_map().into_iter().collect();
        let back = ScenarioSpec::from_map("rt", &map).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn every_workload_kind_round_trips() {
        for wl in [
            WorkloadSpec::Burst {
                requests_per_proc: 5,
            },
            WorkloadSpec::JpegChain {
                depth: 2,
                blocks: 6,
            },
            WorkloadSpec::AppPartition {
                app: AppKind::Gsm,
                partition: 1,
            },
            WorkloadSpec::Serving {
                rate_per_us: 3.5,
                tenants: 6,
                arrival: ArrivalKind::Bursty,
                admission: false,
                slo_us: 15.0,
                mix: ServingMix::Mixed,
            },
        ] {
            let spec = ScenarioSpec::new("w")
                .hwas("jpeg")
                .chain(true)
                .workload(wl);
            let map: BTreeMap<String, String> =
                spec.to_map().into_iter().collect();
            let back = ScenarioSpec::from_map("w", &map).unwrap();
            assert_eq!(spec, back);
        }
    }

    #[test]
    fn device_key_round_trips_and_gates_the_budget() {
        // Non-default devices survive the map round trip...
        let spec = ScenarioSpec::new("dev")
            .device(crate::synth::Device::XCVU9P)
            .hwas("izigzag*4");
        let map: BTreeMap<String, String> =
            spec.to_map().into_iter().collect();
        assert_eq!(map.get("system.device").map(String::as_str), Some("xcvu9p"));
        assert_eq!(ScenarioSpec::from_map("dev", &map).unwrap(), spec);
        // ...the default emits no key (legacy maps stay byte-identical)...
        let legacy = ScenarioSpec::new("legacy");
        assert!(legacy
            .to_map()
            .iter()
            .all(|(k, _)| k != "system.device"));
        // ...and the selected part is the budget actually enforced:
        // four `prime` cores blow the 690t but fit the VU9P.
        let over = ScenarioSpec::new("over").hwas("prime*4");
        assert!(over.system_config().is_err());
        let roomy = over.device(crate::synth::Device::XCVU9P);
        assert!(roomy.system_config().is_ok());
        assert!(ScenarioSpec::new("typo")
            .to_map()
            .iter()
            .all(|(k, _)| known_spec_key(k)));
    }

    #[test]
    fn unvalidated_parse_accepts_unbuildable_shapes() {
        // `prime*4` exceeds the default budget: the validated parser
        // rejects it, the unvalidated one hands the autotuner a spec it
        // can cost and prune with a typed reason instead.
        let map: BTreeMap<String, String> = ScenarioSpec::new("x")
            .hwas("prime*4")
            .to_map()
            .into_iter()
            .collect();
        assert!(ScenarioSpec::from_map("x", &map).is_err());
        let spec = ScenarioSpec::from_map_unvalidated("x", &map).unwrap();
        let plan = spec.plan().unwrap();
        let fabrics = spec.fabric_specs(&plan).unwrap();
        assert_eq!(fabrics.len(), 1);
        assert_eq!(fabrics[0].specs.len(), 4);
        // Field-level typos still fail even unvalidated.
        let mut bad = map.clone();
        bad.insert("system.device".into(), "not_a_part".into());
        assert!(ScenarioSpec::from_map_unvalidated("x", &bad).is_err());
    }

    #[test]
    fn toml_grid_expands_in_sorted_axis_order() {
        let sweep = SweepSpec::parse_toml(
            "name = g\n\
             [system]\n\
             task_buffers = 1,2\n\
             hwas = dfdiv*1\n\
             [workload]\n\
             kind = burst\n\
             requests_per_proc = 2\n",
        )
        .unwrap();
        let grid = sweep.expand().unwrap();
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0].n_tbs, 1);
        assert_eq!(grid[1].n_tbs, 2);
        assert_eq!(grid[0].name, "g[task_buffers=1]");
    }

    #[test]
    fn json_form_matches_toml_form() {
        let toml = SweepSpec::parse_toml(
            "name = j\n\
             [workload]\n\
             kind = openloop\n\
             rate_per_us = 0.5,1\n",
        )
        .unwrap();
        let json = SweepSpec::parse_json(
            r#"{"name": "j",
                "workload": {"kind": "openloop", "rate_per_us": [0.5, 1]}}"#,
        )
        .unwrap();
        assert_eq!(toml.expand().unwrap(), json.expand().unwrap());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(SweepSpec::parse_toml("[system]\ntypo_key = 1\n").is_err());
        assert!(SweepSpec::parse_toml("[system]\nnet = tokenring\n").is_err());
        assert!(SweepSpec::parse_toml("[system]\nhwas = nonsense99\n").is_err());
        assert!(SweepSpec::parse_toml("[system]\nmesh = 1x1\n").is_err());
        assert!(SweepSpec::parse_toml("[system]\ntask_buffers = 0\n").is_err());
        assert!(
            SweepSpec::parse_toml("[workload]\nkind = openloop\nrate_per_us = 0\n")
                .is_err()
        );
        assert!(
            SweepSpec::parse_toml("[workload]\nkind = jpeg_chain\ndepth = 7\n")
                .is_err()
        );
        assert!(
            SweepSpec::parse_toml("[workload]\nkind = serving\ntenants = 0\n")
                .is_err()
        );
        assert!(SweepSpec::parse_toml(
            "[workload]\nkind = serving\narrival = lognormal\n"
        )
        .is_err());
        assert!(SweepSpec::parse_toml(
            "[workload]\nkind = serving\nmix = weird\n"
        )
        .is_err());
        assert!(SweepSpec::parse_toml(
            "[workload]\nkind = serving\nslo_us = 0\n"
        )
        .is_err());
        assert!(SweepSpec::parse_toml("[reconfig]\npolicy = magic\n").is_err());
        assert!(SweepSpec::parse_toml("[reconfig]\nepoch_us = 0\n").is_err());
        assert!(
            SweepSpec::parse_toml("[reconfig]\nlatency_model = warp\n")
                .is_err()
        );
    }

    #[test]
    fn reconfig_keys_round_trip_and_stay_off_legacy_maps() {
        // Byte-compat: a pre-reconfig spec's map must not change.
        let legacy = ScenarioSpec::new("legacy").hwas("izigzag*4");
        assert!(legacy
            .to_map()
            .iter()
            .all(|(k, _)| !k.starts_with("reconfig.")));
        assert!(
            legacy.system_config().unwrap().fabrics[0]
                .reconfigurable
                .is_empty(),
            "static policy declares no PR regions"
        );

        let mut spec = ScenarioSpec::new("rc")
            .hwas("gsm*4")
            .reconfig(ProvisionPolicy::QueueDepth)
            .workload(WorkloadSpec::Serving {
                rate_per_us: 2.0,
                tenants: 4,
                arrival: ArrivalKind::Poisson,
                admission: true,
                slo_us: 20.0,
                mix: ServingMix::Phased,
            });
        spec.reconfig_epoch_us = 2.0;
        spec.reconfig_latency = LatencyModel::Fixed { us: 8.0 };
        let map: BTreeMap<String, String> =
            spec.to_map().into_iter().collect();
        assert_eq!(
            map.get("reconfig.policy").map(String::as_str),
            Some("queue_depth")
        );
        assert_eq!(
            map.get("workload.mix").map(String::as_str),
            Some("phased")
        );
        let back = ScenarioSpec::from_map("rc", &map).unwrap();
        assert_eq!(spec, back);
        let cfg = back.system_config().unwrap();
        assert_eq!(
            cfg.fabrics[0].reconfigurable,
            vec![0, 1, 2, 3],
            "adaptive policies mark every slot reconfigurable"
        );
    }

    #[test]
    fn fault_keys_round_trip_and_stay_off_legacy_maps() {
        // Byte-compat: a pre-fault spec's map must not change.
        let legacy = ScenarioSpec::new("legacy").hwas("izigzag*4");
        assert!(legacy
            .to_map()
            .iter()
            .all(|(k, _)| !k.starts_with("fault.")));
        assert!(legacy.fault_config().spec.is_none());

        let mut spec = ScenarioSpec::new("f")
            .hwas("izigzag*4")
            .faults(FaultSpec::Mixed(0.01), RecoveryPolicy::RetryFailover);
        spec.fault_timeout_us = 10.0;
        spec.fault_scrub_us = 25.0;
        let map: BTreeMap<String, String> =
            spec.to_map().into_iter().collect();
        assert_eq!(
            map.get("fault.spec").map(String::as_str),
            Some("mixed:0.01")
        );
        assert_eq!(
            map.get("fault.recovery").map(String::as_str),
            Some("retry_failover")
        );
        let back = ScenarioSpec::from_map("f", &map).unwrap();
        assert_eq!(spec, back);
        let cfg = back.fault_config();
        assert_eq!(cfg.timeout_ps, 10 * PS_PER_US);
        assert_eq!(cfg.scrub_ps, 25 * PS_PER_US);
        assert_eq!(cfg.seed, back.seed);

        // An explicit `fault.spec = none` is accepted and normalizes
        // back to the key-free legacy map.
        let mut none = BTreeMap::new();
        none.insert("fault.spec".to_string(), "none".to_string());
        let parsed = ScenarioSpec::from_map("n", &none).unwrap();
        assert!(parsed
            .to_map()
            .iter()
            .all(|(k, _)| !k.starts_with("fault.")));
    }

    #[test]
    fn bad_fault_values_are_rejected_at_load_time() {
        assert!(SweepSpec::parse_toml("[fault]\nspec = gamma:0.1\n").is_err());
        assert!(SweepSpec::parse_toml("[fault]\nspec = link:2\n").is_err());
        assert!(SweepSpec::parse_toml("[fault]\nrecovery = panic\n").is_err());
        assert!(SweepSpec::parse_toml("[fault]\ntimeout_us = 0\n").is_err());
        assert!(SweepSpec::parse_toml("[fault]\nscrub_us = -1\n").is_err());
        assert!(SweepSpec::parse_toml(
            "[fault]\nspec = hwa:0.01\nrecovery = retry\n"
        )
        .is_ok());
    }

    #[test]
    fn floorplanned_spec_round_trips_through_map() {
        let spec = ScenarioSpec::new("fp")
            .floorplan("F0 P P / P M P / P P F1")
            .mmu_assign(MmuAssign::Hashed)
            .hwas("izigzag*2")
            .hwas_on(1, "dfadd*1")
            .workload(WorkloadSpec::OpenLoop { rate_per_us: 1.5 });
        let map: BTreeMap<String, String> =
            spec.to_map().into_iter().collect();
        assert_eq!(
            map.get("system.floorplan").map(String::as_str),
            Some("F0 P P / P M P / P P F1")
        );
        assert_eq!(
            map.get("system.mmu_assign").map(String::as_str),
            Some("hashed")
        );
        assert!(
            !map.contains_key("system.mesh"),
            "the plan's rows fix the mesh; no separate key is emitted"
        );
        let back = ScenarioSpec::from_map("fp", &map).unwrap();
        assert_eq!(spec, back);
        let cfg = back.system_config().unwrap();
        assert_eq!(cfg.fabrics.len(), 2);
        assert_eq!(cfg.fabrics[0].specs.len(), 2, "hwas default");
        assert_eq!(cfg.fabrics[1].specs.len(), 1, "hwas_f1 override");
        assert_eq!(cfg.mmu_assign, MmuAssign::Hashed);
    }

    #[test]
    fn legacy_specs_emit_no_topology_keys() {
        // Byte-compat: a pre-floorplan spec's map must not change.
        let spec = ScenarioSpec::new("legacy").hwas("izigzag*4");
        let map = spec.to_map();
        assert!(map.iter().all(|(k, _)| !k.contains("floorplan")
            && !k.contains("mmu_assign")
            && !k.contains("hwas_f")));
    }

    #[test]
    fn bad_topology_specs_are_rejected_at_load_time() {
        // Bad grammar.
        assert!(SweepSpec::parse_toml(
            "[system]\nfloorplan = P Q / M F0\n"
        )
        .is_err());
        // Structurally invalid plan (no processors).
        assert!(SweepSpec::parse_toml(
            "[system]\nfloorplan = M F0 / F1 .\n"
        )
        .is_err());
        // AXI with two fabrics.
        assert!(SweepSpec::parse_toml(
            "[system]\nnet = axi\nfloorplan = F0 P P / P M P / P P F1\n"
        )
        .is_err());
        // Mesh conflicting with the plan's dimensions.
        assert!(SweepSpec::parse_toml(
            "[system]\nmesh = 4x4\nfloorplan = P P F0 / P M P / P P P\n"
        )
        .is_err());
        // ... including an explicit 3x3 against a smaller plan (the
        // default value gets no special treatment when written out).
        assert!(SweepSpec::parse_toml(
            "[system]\nmesh = 3x3\nfloorplan = P M / F0 P\n"
        )
        .is_err());
        // A matching explicit mesh is fine.
        assert!(SweepSpec::parse_toml(
            "[system]\nmesh = 2x2\nfloorplan = P M / F0 P\n"
        )
        .is_ok());
        // Per-fabric override for a fabric the plan does not have.
        assert!(SweepSpec::parse_toml(
            "[system]\nhwas_f2 = izigzag*2\n"
        )
        .is_err());
        // Unknown assignment policy.
        assert!(SweepSpec::parse_toml(
            "[system]\nmmu_assign = roundrobin\n"
        )
        .is_err());
    }

    #[test]
    fn floorplan_values_survive_toml_axes() {
        // Floorplan strings contain spaces and slashes but no commas, so
        // they compose with comma-separated sweep axes.
        let sweep = SweepSpec::parse_toml(
            "name = topo\n\
             [system]\n\
             floorplan = P P F0 / P M P / P P P , P P F0 / P M P / P P F1\n\
             hwas = izigzag*2\n\
             [workload]\n\
             kind = openloop\n\
             rate_per_us = 1\n",
        )
        .unwrap();
        let grid = sweep.expand().unwrap();
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0].system_config().unwrap().fabrics.len(), 1);
        assert_eq!(grid[1].system_config().unwrap().fabrics.len(), 2);
    }

    #[test]
    fn hwa_mix_syntax() {
        assert_eq!(HwaMix::parse("first8").unwrap(), HwaMix::First(8));
        assert_eq!(
            HwaMix::parse("izigzag*3").unwrap(),
            HwaMix::Repeat("izigzag".to_string(), 3)
        );
        assert_eq!(HwaMix::parse("jpeg").unwrap(), HwaMix::Jpeg);
        assert_eq!(HwaMix::Jpeg.to_specs().unwrap().len(), 4);
        assert_eq!(HwaMix::First(8).to_specs().unwrap().len(), 8);
        assert!(HwaMix::Named(vec!["bogus".to_string()])
            .to_specs()
            .is_err());
        assert!(HwaMix::First(0).to_specs().is_err());
    }
}
